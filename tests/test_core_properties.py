"""Hypothesis property tests: simulator invariants that must hold for any
workload shape (monotonicity, conservation, bound-respecting)."""
import dataclasses

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (BufferConfig, Dataflow, Gemm, best_logical_shape,
                        mactree_gemm, mactree_system, mode_candidates,
                        sa_gemm, schedule_projection, snake_system)
from repro.core.hw import FP16_BYTES

SNAKE = snake_system()
SA = SNAKE.substrate
BIG = BufferConfig(weight=1 << 30, act=1 << 30, out=1 << 30)

dims = st.integers(min_value=1, max_value=1 << 15)
small_m = st.integers(min_value=1, max_value=256)
flows = st.sampled_from(list(Dataflow))


@given(m=small_m, n=dims, k=dims, df=flows)
@settings(max_examples=200, deadline=None)
def test_cycles_cover_macs(m, n, k, df):
    """Array can never do more than rows*cols MACs per cycle."""
    g = Gemm("g", m, n, k)
    rows, cols = best_logical_shape(SA, m)
    e = sa_gemm(g, rows, cols, df, BIG)
    assert e.array_cycles * rows * cols >= g.m * g.n * g.k


@given(m=small_m, n=dims, k=dims, df=flows)
@settings(max_examples=200, deadline=None)
def test_dram_at_least_compulsory(m, n, k, df):
    g = Gemm("g", m, n, k)
    rows, cols = best_logical_shape(SA, m)
    e = sa_gemm(g, rows, cols, df, BIG)
    assert e.dram_bytes >= g.min_dram_bytes
    assert e.sram_bytes >= g.min_dram_bytes  # every DRAM byte staged once


@given(m=small_m, n=dims, k=dims)
@settings(max_examples=100, deadline=None)
def test_bigger_buffers_never_increase_traffic(m, n, k):
    g = Gemm("g", m, n, k)
    small = BufferConfig(weight=32 * 1024, act=8 * 1024, out=16 * 1024)
    for df in Dataflow:
        e_small = sa_gemm(g, 8, 512, df, small)
        e_big = sa_gemm(g, 8, 512, df, BIG)
        assert e_big.dram_bytes <= e_small.dram_bytes


@given(m=small_m, n=dims, k=dims)
@settings(max_examples=100, deadline=None)
def test_mactree_util_le_1_and_cycles_cover(m, n, k):
    g = Gemm("g", m, n, k)
    mt = mactree_system().substrate
    e = mactree_gemm(g, mt)
    assert 0 < e.util <= 1.0
    assert e.array_cycles * mt.pes >= g.m * g.n * g.k


@given(m=st.integers(1, 64), scale=st.integers(1, 8))
@settings(max_examples=60, deadline=None)
def test_more_work_more_time(m, scale):
    """Scaling N by an integer factor never reduces scheduled op time."""
    g1 = Gemm("g", m, 4096, 4096)
    g2 = Gemm("g", m, 4096 * scale, 4096)
    t1 = schedule_projection(SNAKE, g1).time_s
    t2 = schedule_projection(SNAKE, g2).time_s
    assert t2 >= t1 * 0.999


@given(m=st.integers(1, 64), n=st.integers(256, 1 << 14),
       k=st.integers(256, 1 << 14))
@settings(max_examples=80, deadline=None)
def test_schedule_time_bounded_by_roofline(m, n, k):
    """Scheduled time must respect the device roofline (with a modest
    scheduling-inefficiency allowance) and never beat it."""
    g = Gemm("g", m, n, k)
    ex = schedule_projection(SNAKE, g)
    t_roofline = max(g.flops / SNAKE.peak_flops,
                     g.min_dram_bytes / SNAKE.effective_dram_bw)
    assert ex.time_s >= t_roofline * 0.999


@given(m=st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_shape_selection_total_pes_constant(m):
    r, c = best_logical_shape(SA, m)
    assert r * c == SA.pes
    assert r % SA.reconfig_granularity == 0


@given(b=st.integers(1, 64), ratio=st.floats(0.5, 2.0))
@settings(max_examples=30, deadline=None)
def test_energy_scales_with_work(b, ratio):
    g1 = Gemm("g", b, 8192, 8192)
    g2 = Gemm("g", b, int(8192 * ratio) or 1, 8192)
    e1 = schedule_projection(SNAKE, g1).energy
    e2 = schedule_projection(SNAKE, g2).energy
    assert e1.mac_j > 0 and e2.mac_j > 0
    if ratio > 1.05:
        assert e2.mac_j > e1.mac_j
