"""Invariant checker suite (PR 7).

The three passes must land clean on the repo, and each regression
fixture — a reproduction of a historical bug — must be flagged with an
actionable location.  The CLI contract (exit 0 clean / non-zero on
findings) is what CI gates on.
"""
import time

import pytest

from repro.analysis.checks import (FIXTURE_NAMES, run_fixture, run_pass)
from repro.analysis.checks.__main__ import main as checks_main


# --- the repo itself is clean ------------------------------------------
def test_repo_clean_kernel_aliasing():
    assert run_pass("kernel-aliasing") == []


def test_repo_clean_allocator_model_under_budget():
    t0 = time.time()
    assert run_pass("allocator-model") == []
    assert time.time() - t0 < 60          # the CI budget, with margin


def test_repo_clean_mirror_drift():
    assert run_pass("mirror-drift") == []


# --- seeded regressions are flagged with actionable locations ----------
def test_scatter_clip_fixture_flags_all_three_invariants():
    findings = run_fixture("pr2-scatter-clip")
    invariants = {f.invariant for f in findings}
    assert {"scatter-window-guard", "scatter-scratch-route",
            "scatter-active-guard"} <= invariants
    for f in findings:
        assert f.file and f.file.endswith("pr2_scatter_clip.py")
        assert f.line and f.line > 0
        assert "pr2_scatter_clip.py" in f.location


def test_inactive_lane_fixture_flagged_at_function():
    findings = run_fixture("pr2-inactive-lane")
    assert findings
    assert all(f.invariant == "host-inactive-lane" for f in findings)
    f = findings[0]
    assert f.file.endswith("pr2_inactive_lane.py") and f.line > 0
    assert "_decode_paged_pallas" in f.message


def test_refcount_fixture_yields_minimal_counterexample_traces():
    findings = run_fixture("pr2-refcount-free")
    assert findings
    shared_free = [f for f in findings
                   if "reference(s) remain" in f.message]
    assert shared_free, "the shared-page free was not caught"
    f = shared_free[0]
    assert f.file.endswith("pr2_refcount_free.py")
    assert "minimal op trace" in f.detail
    # BFS order: the very shortest reproduction is alloc/incref/decref
    steps = [ln for ln in f.detail.splitlines()
             if ln.strip() and ln.strip()[0].isdigit()]
    assert len(steps) == 3
    cross = [f for f in findings if "cross-region" in f.message]
    assert cross, "the cross-region defrag move was not caught"
    assert "defrag()" in cross[0].detail


def test_metrics_drift_fixture_flags_dropped_key():
    findings = run_fixture("pr6-metrics-drift")
    assert findings
    f = findings[0]
    assert f.invariant == "cluster-aggregation"
    assert "substrate_configs" in f.message
    assert f.file.endswith("pr6_metrics_drift.py") and f.line > 0


def test_fused_double_count_fixture_flagged():
    findings = run_fixture("pr8-fused-double-count")
    assert findings
    assert all(f.invariant == "fused-emit-guard" for f in findings)
    f = findings[0]
    assert f.file.endswith("pr8_fused_double_count.py") and f.line > 0
    assert "_apply_fused" in f.message


def test_metrics_unregistered_fixture_flagged():
    findings = run_fixture("pr9-metrics-unregistered")
    assert findings
    assert all(f.invariant == "unregistered-metric" for f in findings)
    f = findings[0]
    assert "decode_watts" in f.message
    assert f.file.endswith("pr9_metrics_unregistered.py") and f.line > 0


def test_ship_trie_drop_fixture_flagged():
    findings = run_fixture("pr10-ship-trie-drop")
    assert findings
    assert all(f.invariant == "ship-integrity" for f in findings)
    assert "trie" in findings[0].message
    assert findings[0].file.endswith("pr10_ship_trie_drop.py")


def test_metric_contract_clean_and_stale_entry_flagged(monkeypatch):
    """The real Scheduler/Router surfaces match the metric-name contract
    exactly; a contract entry without an emitter is a stale-contract
    finding."""
    from repro.analysis.checks import mirror_drift, mirror_spec
    assert mirror_drift.check_metrics_registered() == []
    monkeypatch.setattr(
        mirror_spec, "SCHEDULER_METRIC_CONTRACT",
        tuple(mirror_spec.SCHEDULER_METRIC_CONTRACT) + ("decode_watts",))
    findings = mirror_drift.check_metrics_registered()
    assert any(f.invariant == "stale-contract"
               and "decode_watts" in f.message for f in findings)


def test_stale_contract_entries_are_findings(monkeypatch):
    """The contract file itself is checked: an entry naming a metric
    that no longer exists must surface, not rot silently."""
    from repro.analysis.checks import mirror_drift, mirror_spec
    monkeypatch.setattr(
        mirror_spec, "ROUTER_MUST_AGGREGATE",
        list(mirror_spec.ROUTER_MUST_AGGREGATE) + ["modeled_flops"])
    findings = mirror_drift.check_router_aggregation()
    assert any(f.invariant == "stale-contract"
               and "modeled_flops" in f.message for f in findings)


# --- CLI contract -------------------------------------------------------
def test_cli_exit_codes(capsys):
    assert checks_main(["--pass", "mirror-drift", "-q"]) == 0
    out = capsys.readouterr().out
    assert "OK (0 findings)" in out
    for name in ("pr6-metrics-drift", "pr2-scatter-clip"):
        assert checks_main(["--fixture", name, "-q"]) == 1
        out = capsys.readouterr().out
        assert "finding(s)" in out


def test_cli_rejects_unknown_fixture():
    with pytest.raises(SystemExit):
        checks_main(["--fixture", "no-such-fixture"])
    assert set(FIXTURE_NAMES) == {"pr2-scatter-clip", "pr2-inactive-lane",
                                  "pr2-refcount-free", "pr6-metrics-drift",
                                  "pr8-fused-double-count",
                                  "pr9-metrics-unregistered",
                                  "pr10-ship-trie-drop"}
