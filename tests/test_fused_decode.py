"""Fused decode loop tests (PR 8).

The fused engine scans K decode steps on device (``lax.scan`` over
``decode_step_paged``) and must be *bit-identical* to the per-tick
engine: same tokens, same finish reasons, under every cache feature
combination (prefix sharing, chunked prefill, placement, the Pallas
read-through path, eos stops).  Also covered: the horizon-selection
rule (page-window and budget cutoffs), the incrementally maintained
device block-table mirror, and the analytic sim's fused clock.
"""
import numpy as np
import pytest

from repro.core.hw import snake_system
from repro.core.operators import PAPER_MODELS
from repro.core.serving_sim import nmp_latency_model, simulate_serving
from repro.models import registry
from repro.serving.engine import (EngineConfig, RequestState, make_engine,
                                  make_shared_prefix_trace, make_trace)
from repro.serving.paged_cache import PagedCache

# skewed prompt lengths: ragged tails, different page phases, one prompt
# spanning four pages — the horizon must keep collapsing and recovering
SKEWED_LENS = np.array([9, 17, 5, 30, 12, 24])


def _entry():
    return registry.get("yi-6b", reduced=True)


def _run(entry, trace=None, **over):
    base = dict(max_batch=3, max_seq=48, max_new_tokens=5,
                paged=True, page_size=8)
    base.update(over)
    ecfg = EngineConfig(**base)
    eng = make_engine(entry, ecfg)
    reqs = trace if trace is not None else make_trace(
        entry.config.vocab, rate_req_s=100.0, n_requests=6,
        prompt_len=8, prompt_lens=SKEWED_LENS, seed=3)
    m = eng.run_trace(reqs)
    toks = {r.rid: list(r.tokens_out) for r in eng.completed}
    reasons = {r.rid: r.finish_reason for r in eng.completed}
    return eng, m, toks, reasons


# ---------------------------------------------------------------------------
# token exactness: fused == per-tick, across horizons and cache features
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_fused_token_exact_across_horizons():
    entry = _entry()
    _, _, base_t, base_r = _run(entry, fuse_steps=1)
    for fuse in (2, 8, 64):
        eng, m, toks, reasons = _run(entry, fuse_steps=fuse)
        assert toks == base_t, f"fuse_steps={fuse} diverged"
        assert reasons == base_r
        if fuse >= 8:
            assert m["fused_ticks"] > 0
            assert m["fused_steps_mean"] > 1.0
    # fuse_steps=1 never routes through the fused path at all
    eng1, m1, _, _ = _run(entry, fuse_steps=1)
    assert m1["fused_ticks"] == 0 and m1["fused_host_frac"] == 0.0


@pytest.mark.slow
def test_fused_token_exact_with_sharing_chunking_placement():
    """The full feature stack under one fused engine: shared prefixes
    (horizon-boundary CoW), chunked prefill (inactive lanes hold live
    shared pages mid-chunk), and region placement."""
    entry = _entry()
    trace = lambda: make_shared_prefix_trace(     # noqa: E731
        entry.config.vocab, rate_req_s=500.0, n_requests=6,
        prefix_len=16, tail_len=5, seed=2)
    over = dict(max_seq=64, prefix_sharing=True, prefill_chunk=4,
                placement="affinity", placement_regions=2)
    _, _, base_t, base_r = _run(entry, trace=trace(), fuse_steps=1, **over)
    eng, _, toks, reasons = _run(entry, trace=trace(), fuse_steps=16,
                                 **over)
    assert toks == base_t and reasons == base_r
    assert eng.paged.pages_in_use() == 0


@pytest.mark.slow
def test_fused_token_exact_pallas_readthrough():
    entry = _entry()
    _, _, base_t, _ = _run(entry, fuse_steps=1, use_pallas_decode=True)
    _, _, toks, _ = _run(entry, fuse_steps=8, use_pallas_decode=True)
    assert toks == base_t


@pytest.mark.slow
def test_fused_eos_budget_reason_parity():
    """Sampled eos budgets: requests finish at staggered lengths, so the
    horizon is budget-capped per wave and finish reasons must agree."""
    entry = _entry()
    trace = lambda: make_trace(                   # noqa: E731
        entry.config.vocab, rate_req_s=100.0, n_requests=6,
        prompt_len=8, prompt_lens=SKEWED_LENS, seed=3, eos_rate=0.4)
    _, _, base_t, base_r = _run(entry, trace=trace(), fuse_steps=1)
    _, _, toks, reasons = _run(entry, trace=trace(), fuse_steps=64)
    assert toks == base_t and reasons == base_r


@pytest.mark.slow
def test_fused_token_level_eos_freezes_lane_mid_horizon():
    """A token-level eos_id cannot be predicted from host state: the lane
    must freeze *inside* the scan (emit mask) and the finish reason must
    still match the per-tick engine."""
    entry = _entry()
    _, _, base_t, _ = _run(entry, fuse_steps=1)
    # pick a token some request actually emits mid-stream as the eos id
    eos_id = next(t[2] for t in base_t.values() if len(t) > 3)
    _, _, b_t, b_r = _run(entry, fuse_steps=1, eos_id=eos_id,
                          max_new_tokens=8)
    _, _, f_t, f_r = _run(entry, fuse_steps=64, eos_id=eos_id,
                          max_new_tokens=8)
    assert f_t == b_t and f_r == b_r
    assert "eos" in set(b_r.values())   # the stop actually triggered


# ---------------------------------------------------------------------------
# horizon selection: page-window and budget cutoffs
# ---------------------------------------------------------------------------
def test_fused_horizon_page_and_budget_cutoffs():
    entry = _entry()
    ecfg = EngineConfig(max_batch=2, max_seq=64, max_new_tokens=32,
                        paged=True, page_size=8, fuse_steps=64)
    eng = make_engine(entry, ecfg)
    assert eng.submit(RequestState(0, np.arange(9, dtype=np.int32)))
    # 9 prompt tokens resident after submit; growth maps a 2nd page so
    # the slot covers 16 positions: 7 decode writes (9..15) fit before
    # the window edge, and the budget allows 31 more -> the page binds
    eng._pre_decode_grow()
    assert eng._fused_horizon() == 7
    slot, req = next(iter(eng.active.items()))
    eng.tick()
    assert int(eng._lengths_host[slot]) == 16
    # fresh page granted on the next tick boundary: full page of 8 steps
    eng._pre_decode_grow()
    assert eng._fused_horizon() == min(8, 32 - len(req.tokens_out)) == 8
    while eng.active:
        eng.tick()
    assert eng.completed[0].finish_reason == "budget"
    assert len(eng.completed[0].tokens_out) == 32
    # budget cutoff: with 12 total the 2nd horizon is capped at the 4
    # remaining steps (page window would have allowed a full 8)
    eng2 = make_engine(entry, EngineConfig(
        max_batch=2, max_seq=64, max_new_tokens=12, paged=True,
        page_size=8, fuse_steps=64))
    assert eng2.submit(RequestState(0, np.arange(9, dtype=np.int32)))
    eng2.tick()                                  # 7 steps: page-capped
    eng2._pre_decode_grow()
    assert eng2._fused_horizon() == 4
    while eng2.active:
        eng2.tick()
    assert len(eng2.completed[0].tokens_out) == 12
    assert eng2.completed[0].finish_reason == "budget"


def test_fused_tick_counters_in_metrics():
    entry = _entry()
    eng, m, _, _ = _run(entry, fuse_steps=8)
    fr = eng.fused_report()
    assert fr["fused_ticks"] == m["fused_ticks"] > 0
    assert 0.0 <= fr["host_frac"] <= 1.0
    assert m["fused_steps_mean"] == pytest.approx(fr["fused_steps_mean"])


# ---------------------------------------------------------------------------
# device block-table mirror: incrementally maintained
# ---------------------------------------------------------------------------
def test_paged_cache_table_mirror_incremental():
    entry = _entry()
    pc = PagedCache(entry, max_batch=3, max_seq=32, page_size=8,
                    num_pages=12, share=True)
    ref = lambda: np.where(pc.tables < 0, pc.num_pages,    # noqa: E731
                           pc.tables)
    pc.tables_device()                          # build the mirror once
    prompt = np.arange(20, dtype=np.int32)
    assert pc.alloc_slot(0, 21, tokens=prompt)
    assert pc._tables_dev is not None           # refreshed, not dropped
    np.testing.assert_array_equal(np.asarray(pc.tables_device()), ref())
    assert pc.alloc_slot(1, 21, tokens=prompt)  # maps nothing yet (no KV)
    assert pc.extend_slot(0, 25)
    np.testing.assert_array_equal(np.asarray(pc.tables_device()), ref())
    assert pc.fork_page(0, 0) in (True, False)  # exercise _mirror_set
    np.testing.assert_array_equal(np.asarray(pc.tables_device()), ref())
    pc.free_slot(0)
    assert pc._tables_dev is not None
    np.testing.assert_array_equal(np.asarray(pc.tables_device()), ref())
    assert pc.mirror_consistent()
    pc.defrag()                                 # wholesale renumber: drop
    np.testing.assert_array_equal(np.asarray(pc.tables_device()), ref())


# ---------------------------------------------------------------------------
# analytic mirror: fused sim clock
# ---------------------------------------------------------------------------
def _sim(**kw):
    spec = PAPER_MODELS["LLaMA3-70B"]
    lat = nmp_latency_model(snake_system(), spec, tp=8)
    return simulate_serving(lat, spec, 0.5, system="SNAKE",
                            n_requests=16, cache_mode="paged", **kw)


def test_sim_fused_clock_matches_per_tick():
    base = _sim()
    fused = _sim(fuse_steps=8)
    assert fused.fused_ticks > 0 and fused.fused_steps_mean > 1.0
    assert base.fused_ticks == 0 and base.fused_steps_mean == 0.0
    # fusing moves host boundaries, not modeled device work: token
    # counts agree exactly, and the clock only drifts by the admission
    # quantization (arrivals join at horizon boundaries, like the live
    # engine) — well under a percent at these horizons
    assert fused.decoded_tokens == base.decoded_tokens
    assert fused.completed == base.completed
    assert fused.makespan_s == pytest.approx(base.makespan_s, rel=0.01)
    assert fused.kv_peak_tokens == pytest.approx(base.kv_peak_tokens,
                                                 rel=0.01)
