"""Per-architecture smoke tests: reduced same-family config, one forward /
train-loss step and one prefill+decode step on CPU; asserts output shapes and
finiteness.  The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.models import layers as L

ARCHS = registry.ARCH_IDS


def _batch_for(cfg, b=2, s=32):
    key = jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch = {"embeds": jax.random.normal(key, (b, s, cfg.d_model),
                                             jnp.float32),
                 "labels": toks[:, 1:]}
    return batch


@pytest.fixture(scope="module", params=ARCHS)
def arch(request):
    entry = registry.get(request.param, reduced=True)
    params = entry.module.init(jax.random.PRNGKey(1), entry.config, tp=1)
    return request.param, entry, params


def test_full_config_matches_assignment(arch):
    name, entry, _ = arch
    full = registry.get_config(name)
    assigned = {
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "rwkv6-7b": (32, 4096, 1, 1, 14336, 65536),
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }[name]
    got = (full.num_layers, full.d_model, full.num_q_heads,
           full.num_kv_heads, full.d_ff, full.vocab)
    assert got == assigned


def test_train_loss_step(arch):
    name, entry, params = arch
    cfg = entry.config
    batch = _batch_for(cfg)
    loss = jax.jit(lambda p, b: entry.module.loss(p, cfg, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # a reasonable CE magnitude for random init over the reduced vocab
    assert 1.0 < float(loss) < 20.0


def test_grad_step_finite(arch):
    name, entry, params = arch
    cfg = entry.config
    batch = _batch_for(cfg)
    g = jax.jit(jax.grad(lambda p: entry.module.loss(p, cfg, batch)))(params)
    norms = [float(jnp.abs(x).max()) for x in jax.tree.leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert max(norms) > 0.0


def test_prefill_then_decode(arch):
    name, entry, params = arch
    cfg = entry.config
    b, s = 2, 16
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = jax.random.normal(
            key, (b, cfg.encoder_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        logits, cache = entry.module.prefill(
            params, cfg, None, embeds=jax.random.normal(
                key, (b, s, cfg.d_model), jnp.float32), max_seq=s + 8)
    elif cfg.family == "audio":
        logits, cache = entry.module.prefill(params, cfg, toks,
                                             max_seq=s + 8, **kw)
    elif cfg.family in ("ssm", "hybrid"):
        logits, cache = entry.module.prefill(params, cfg, toks)
    else:
        logits, cache = entry.module.prefill(params, cfg, toks,
                                             max_seq=s + 8)
    assert logits.shape == (b, cfg.padded_vocab(1))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    nxt = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
    step = jax.jit(lambda p, t, c: entry.module.decode_step(p, cfg, t, c))
    for _ in range(3):
        logits, cache = step(params, nxt, cache)
        assert logits.shape == (b, cfg.padded_vocab(1))
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        nxt = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)


def test_decode_matches_prefill(arch):
    """Consistency: prefill(t[:n]) then decode(t[n]) must equal
    prefill(t[:n+1]) logits — the cache path is exact, not approximate."""
    name, entry, params = arch
    cfg = entry.config
    if cfg.family == "vlm":
        pytest.skip("embeds-entry prefill covered above")
    if cfg.num_experts:
        # Capacity dropping is sequence-length dependent (a batched prefill
        # may drop a token that single-token decode never would) — the
        # exactness comparison needs a no-drop capacity.
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    b, s = 1, 12
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab)
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = jax.random.normal(
            key, (b, cfg.encoder_frames, cfg.d_model), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        lg_a, cache = entry.module.prefill(params, cfg, toks[:, :s], **kw)
        lg_step, _ = entry.module.decode_step(params, cfg, toks[:, s], cache)
        lg_b, _ = entry.module.prefill(params, cfg, toks, **kw)
    else:
        lg_a, cache = entry.module.prefill(params, cfg, toks[:, :s],
                                           max_seq=s + 4, **kw)
        lg_step, _ = entry.module.decode_step(params, cfg, toks[:, s], cache)
        lg_b, _ = entry.module.prefill(params, cfg, toks, max_seq=s + 5, **kw)
    np.testing.assert_allclose(np.asarray(lg_step, np.float32),
                               np.asarray(lg_b, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_input_specs_cover_all_cells(arch):
    name, entry, params = arch
    full_cfg = registry.get_config(name)
    from repro.models.config import SHAPES, shape_applicable
    for sname, cell in SHAPES.items():
        ok, why = shape_applicable(full_cfg, sname)
        if not ok:
            assert "SKIP" in why
            continue
        spec = registry.input_specs(full_cfg, cell)
        assert spec, f"{name} x {sname} produced empty input specs"
        for k, v in spec.items():
            assert isinstance(v, jax.ShapeDtypeStruct)


# ---------------------------------------------------------------------------
# The paper's own Table 1 models (extra pool, selectable via --arch)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", registry.EXTRA_ARCH_IDS)
def test_paper_model_smoke(arch):
    entry = registry.get(arch, reduced=True)
    cfg = entry.config
    params = entry.module.init(jax.random.PRNGKey(0), cfg, 1)
    toks = np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 32)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "labels": jnp.asarray(np.roll(toks, -1, 1))}
    loss = entry.module.loss(params, cfg, batch, tp=1)
    assert np.isfinite(float(loss))
    logits, cache = entry.module.prefill(params, cfg,
                                         jnp.asarray(toks[:, :16]),
                                         tp=1, max_seq=32)
    assert logits.shape[0] == 2
    logits2, _ = entry.module.decode_step(
        params, cfg, jnp.argmax(logits[:, : cfg.vocab], -1).astype(
            jnp.int32), cache, tp=1)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
