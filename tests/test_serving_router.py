"""Multi-replica router tests (PR 3): dispatch-policy determinism on stub
replicas, token-exactness of the 1-replica router vs. the bare engine,
prefix-affinity dedup compounding, the analytical cluster mirror, the
direct-to-pages chunked prefill, and eos-aware trace replay."""
import json

import numpy as np
import pytest

from repro.core.hw import snake_system
from repro.core.operators import PAPER_MODELS
from repro.core.serving_sim import (make_cluster_trace, nmp_latency_model,
                                    simulate_cluster)
from repro.models import registry
from repro.serving.engine import EngineConfig, make_engine
from repro.serving.replica_api import LoadReport, Replica
from repro.serving.router import Router, make_cluster
from repro.serving.scheduler import (RequestState, load_trace,
                                     make_grouped_prefix_trace, make_trace,
                                     save_trace)


# ---------------------------------------------------------------------------
# Policy unit tests on stub replicas
# ---------------------------------------------------------------------------
class _StubReplica:
    """Implements the ``replica_api.Replica`` protocol the router reads
    (the mirror-drift checker pins the method set)."""

    def __init__(self, free_pages=10, queue_depth=0, residency=None):
        class _E:
            page_size = 8
        self.ecfg = _E()
        self.role = "mixed"
        self.requeue = []
        self.completed = []
        self.preemption_count = 0
        self.free_pages = free_pages
        self.queue_depth = queue_depth
        self.residency = residency or (lambda prompt: 0)
        self.imported = []

    def admit(self, req):
        return True

    def tick(self):
        return 0

    def load_report(self):
        return LoadReport(active=self.queue_depth, prefilling=0,
                          queue_depth=self.queue_depth, free_slots=4,
                          free_pages=self.free_pages,
                          min_region_free=self.free_pages)

    def prefix_residency(self, prompt):
        return self.residency(prompt)

    def busy(self):
        return False

    def export_slot_pages(self, rid):
        raise KeyError(f"stub replica holds no request {rid}")

    def import_slot_pages(self, shipment):
        self.imported.append(shipment)
        return True


def _req(rid, prompt=None, session=None):
    if prompt is None:
        prompt = np.arange(rid, rid + 8, dtype=np.int32)
    return RequestState(rid, np.asarray(prompt, np.int32),
                        session=session)


def test_round_robin_cycles():
    router = Router([_StubReplica() for _ in range(3)],
                    policy="round_robin")
    picks = [router.dispatch(_req(i)) for i in range(5)]
    assert picks == [0, 1, 2, 0, 1]


def test_least_loaded_prefers_shallow_queue_then_free_pages():
    reps = [_StubReplica(queue_depth=2), _StubReplica(queue_depth=0),
            _StubReplica(queue_depth=1)]
    router = Router(reps, policy="least_loaded")
    assert router.select(_req(0)) == 1
    # queue depths equal -> most free pages wins
    reps2 = [_StubReplica(free_pages=3), _StubReplica(free_pages=9),
             _StubReplica(free_pages=6)]
    assert Router(reps2, policy="least_loaded").select(_req(0)) == 1
    # full tie -> lowest index (deterministic)
    reps3 = [_StubReplica(), _StubReplica()]
    assert Router(reps3, policy="least_loaded").select(_req(0)) == 0


def test_least_loaded_counts_undelivered_backlog():
    """Requests sitting in a replica's scheduler queue count as load even
    before the engine has admitted them."""
    router = Router([_StubReplica(), _StubReplica()],
                    policy="least_loaded")
    assert router.dispatch(_req(0)) == 0
    assert router.dispatch(_req(1)) == 1     # 0 now has backlog 1
    assert router.dispatch(_req(2)) == 0     # tie again -> lowest index


def test_session_affinity_sticks():
    router = Router([_StubReplica(), _StubReplica()],
                    policy="session_affinity")
    first = router.dispatch(_req(0, session=7))
    assert router.dispatch(_req(1, session=8)) != first  # balanced start
    assert router.dispatch(_req(2, session=7)) == first
    assert router.dispatch(_req(3, session=7)) == first
    # session defaults to rid when unset -> fresh placement per request
    r4 = router.dispatch(_req(4))
    assert r4 in (0, 1)


def test_prefix_affinity_follows_residency_then_hint():
    prompt_a = np.arange(16, dtype=np.int32)
    prompt_b = np.arange(100, 116, dtype=np.int32)
    key_a = prompt_a[:8].astype(np.int64).tobytes()
    reps = [_StubReplica(),
            _StubReplica(residency=lambda p, k=key_a:
                         2 if p[:8].astype(np.int64).tobytes() == k
                         else 0)]
    router = Router(reps, policy="prefix_affinity")
    # replica 1 already holds prompt_a's leading pages
    assert router.dispatch(_req(0, prompt_a)) == 1
    # no residency anywhere for b -> least-loaded fallback; then the hint
    # keeps the burst together even before any pages commit
    first_b = router.dispatch(_req(1, prompt_b))
    assert router.dispatch(_req(2, prompt_b)) == first_b
    assert router.dispatch(_req(3, prompt_a)) == 1


def test_router_rejects_unknown_policy_and_empty_cluster():
    with pytest.raises(ValueError):
        Router([_StubReplica()], policy="fastest_first")
    with pytest.raises(ValueError):
        Router([], policy="round_robin")


# ---------------------------------------------------------------------------
# eos-aware traces + recorded replay
# ---------------------------------------------------------------------------
def test_make_trace_eos_rate_samples_decode_budgets():
    t1 = make_trace(64, rate_req_s=10.0, n_requests=16, prompt_len=8,
                    seed=3, eos_rate=0.5)
    t2 = make_trace(64, rate_req_s=10.0, n_requests=16, prompt_len=8,
                    seed=3, eos_rate=0.5)
    assert all(r.decode_len >= 1 for r in t1)
    assert [r.decode_len for r in t1] == [r.decode_len for r in t2]
    assert len({r.decode_len for r in t1}) > 1    # actually sampled
    plain = make_trace(64, rate_req_s=10.0, n_requests=4, prompt_len=8)
    assert all(r.decode_len is None for r in plain)
    with pytest.raises(ValueError):
        make_trace(64, rate_req_s=10.0, n_requests=4, prompt_len=8,
                   eos_rate=1.5)


def test_trace_save_load_roundtrip(tmp_path):
    reqs = make_grouped_prefix_trace(64, rate_req_s=10.0, n_requests=6,
                                     n_groups=2, prefix_len=8, tail_len=4,
                                     seed=1, eos_rate=0.3)
    path = str(tmp_path / "trace.json")
    save_trace(reqs, path)
    back = load_trace(path)
    assert len(back) == len(reqs)
    for a, b in zip(reqs, back):
        assert a.rid == b.rid
        assert a.arrival_s == b.arrival_s
        assert a.decode_len == b.decode_len
        assert a.session == b.session
        np.testing.assert_array_equal(a.prompt, b.prompt)


def test_load_trace_prompt_len_needs_vocab(tmp_path):
    path = str(tmp_path / "trace.json")
    with open(path, "w") as f:
        json.dump([{"arrival_s": 0.0, "prompt_len": 8}], f)
    with pytest.raises(ValueError):
        load_trace(path)
    reqs = load_trace(path, vocab=32)
    assert len(reqs[0].prompt) == 8
    assert reqs[0].prompt.max() < 32


def test_grouped_trace_shares_prefix_within_group():
    reqs = make_grouped_prefix_trace(64, rate_req_s=10.0, n_requests=12,
                                     n_groups=3, prefix_len=8, tail_len=4,
                                     skew=0.8, seed=0)
    by_group = {}
    for r in reqs:
        by_group.setdefault(r.session, []).append(r)
    assert len(by_group) > 1
    for grp in by_group.values():
        heads = {g.prompt[:8].tobytes() for g in grp}
        assert len(heads) == 1          # one system prompt per group
    heads = {grp[0].prompt[:8].tobytes() for grp in by_group.values()}
    assert len(heads) == len(by_group)  # distinct across groups


# ---------------------------------------------------------------------------
# Analytical cluster mirror
# ---------------------------------------------------------------------------
def _cluster(**kw):
    spec = PAPER_MODELS["LLaMA3-70B"]
    lat = nmp_latency_model(snake_system(), spec, tp=8)
    return simulate_cluster(lat, spec, kw.pop("rate", 20.0), **kw)


def test_sim_cluster_throughput_scales_with_replicas():
    """Saturating arrivals, no sharing: 4 replicas deliver ~4x the tokens
    per second of one replica (each still fills its decode batch)."""
    kw = dict(rate=200.0, n_requests=64, input_len=512, output_len=256,
              max_batch=8, page_size=64, n_groups=4, skew=0.0, seed=0)
    one = _cluster(policy="round_robin", n_replicas=1, **kw)
    four = _cluster(policy="round_robin", n_replicas=4, **kw)
    assert one.completed == four.completed == 64
    ratio = four.throughput_tok_s / one.throughput_tok_s
    assert 3.0 <= ratio <= 4.5
    assert max(four.per_replica_completed) \
        - min(four.per_replica_completed) <= 1   # round robin balances


def test_sim_cluster_prefix_affinity_beats_round_robin():
    """Tight per-replica pools: fragmenting the communal prefixes across
    replicas (round robin) duplicates pages and preempts; affinity
    colocates, raising aggregate dedup without hurting the tail."""
    kw = dict(rate=20.0, n_replicas=2, n_requests=32, input_len=2048,
              output_len=512, max_batch=8, prefix_sharing=True,
              shared_prefix_len=1536, n_groups=4, skew=0.8,
              page_size=64, num_pages=120, seed=0)
    rr = _cluster(policy="round_robin", **kw)
    pa = _cluster(policy="prefix_affinity", **kw)
    assert rr.completed == pa.completed == 32
    assert pa.dedup_ratio > rr.dedup_ratio
    assert pa.e2e_p99_s <= rr.e2e_p99_s
    # session affinity (session == group here) matches prefix affinity
    sa = _cluster(policy="session_affinity", **kw)
    assert sa.dedup_ratio == pytest.approx(pa.dedup_ratio)


def test_sim_cluster_rejects_bad_config():
    from repro.core.serving_sim import Request
    with pytest.raises(ValueError):
        _cluster(policy="nope", n_replicas=2)
    with pytest.raises(ValueError):
        _cluster(policy="round_robin", n_replicas=1, num_pages=4,
                 input_len=2048, output_len=512)
    # explicit trace with prompts shorter than the claimed shared prefix
    # must raise, not drive page accounting negative
    with pytest.raises(ValueError):
        _cluster(policy="round_robin", n_replicas=1,
                 prefix_sharing=True, shared_prefix_len=1536,
                 trace=[Request(0, 0.0, 512, 8)])


def test_sim_cluster_trace_is_deterministic():
    a = make_cluster_trace(10.0, 16, 128, 32, n_groups=3, skew=1.0, seed=5)
    b = make_cluster_trace(10.0, 16, 128, 32, n_groups=3, skew=1.0, seed=5)
    assert [(r.arrival_s, r.group) for r in a] \
        == [(r.arrival_s, r.group) for r in b]
    assert all(r.session == r.group for r in a)


# ---------------------------------------------------------------------------
# Real engine: router end-to-end
# ---------------------------------------------------------------------------
ENG_KW = dict(max_batch=3, max_seq=64, max_new_tokens=6, paged=True,
              page_size=8, prefix_sharing=True, prefill_chunk=8)


def _grouped_trace(entry, n=8, seed=0):
    return make_grouped_prefix_trace(entry.config.vocab, rate_req_s=200.0,
                                     n_requests=n, n_groups=2,
                                     prefix_len=16, tail_len=6, skew=0.8,
                                     seed=seed)


@pytest.mark.slow
def test_router_single_replica_token_exact():
    entry = registry.get("yi-6b", reduced=True)
    eng = make_engine(entry, EngineConfig(**ENG_KW))
    eng.run_trace(_grouped_trace(entry))
    base = {r.rid: r.tokens_out for r in eng.completed}
    router = make_cluster(entry, EngineConfig(**ENG_KW), 1,
                          policy="round_robin")
    m = router.run_trace(_grouped_trace(entry))
    got = {r.rid: r.tokens_out
           for e in router.engines for r in e.completed}
    assert got == base
    assert m["requests"] == len(base)


@pytest.mark.slow
def test_router_prefix_affinity_dedup_ge_round_robin():
    """Identical grouped trace, 2 sharing replicas: affinity must colocate
    each group's pages and beat round robin's aggregate dedup without
    changing a single decoded token."""
    entry = registry.get("yi-6b", reduced=True)
    out = {}
    for policy in ("round_robin", "prefix_affinity"):
        router = make_cluster(entry, EngineConfig(**ENG_KW), 2,
                              policy=policy)
        out[policy] = router.run_trace(_grouped_trace(entry, n=10))
        out[policy]["tokens"] = {
            r.rid: r.tokens_out
            for e in router.engines for r in e.completed}
    assert out["round_robin"]["tokens"] == out["prefix_affinity"]["tokens"]
    assert out["prefix_affinity"]["dedup_ratio_agg"] \
        >= out["round_robin"]["dedup_ratio_agg"]


@pytest.mark.slow
def test_paged_chunked_prefill_writes_direct_and_matches():
    """The paged engine's chunk scheduler must bypass the dense staging
    buffer (direct page writes) and still decode the exact tokens of the
    unchunked sharing engine — including skipped writes on shared pages."""
    entry = registry.get("yi-6b", reduced=True)
    rng = np.random.default_rng(0)
    vocab = entry.config.vocab
    prefix = rng.integers(0, vocab, 16).astype(np.int32)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, vocab, 5 + i
                                            ).astype(np.int32)])
               for i in range(3)]

    def run(chunk):
        kw = dict(ENG_KW)
        kw["prefill_chunk"] = chunk
        eng = make_engine(entry, EngineConfig(**kw))
        reqs = [RequestState(i, p.copy(), arrival_s=0.0)
                for i, p in enumerate(prompts)]
        if chunk is not None:
            assert eng.admit(reqs[0])
            st = eng._prefilling
            assert st is not None and st.get("direct") \
                and "buf" not in st, "chunked prefill staged via buffer"
            while eng._prefilling is not None:
                eng._prefill_chunk_tick()
            for r in reqs[1:]:
                assert eng.admit(r)
                while eng._prefilling is not None:
                    eng._prefill_chunk_tick()
        else:
            for r in reqs:
                assert eng.submit(r)
        while eng.active:
            eng.step()
        return {r.rid: r.tokens_out for r in eng.completed}

    assert run(chunk=6) == run(chunk=None)


@pytest.mark.slow
def test_engine_eos_aware_finish_reasons():
    entry = registry.get("yi-6b", reduced=True)
    eng = make_engine(entry, EngineConfig(max_batch=3, max_seq=64,
                                          max_new_tokens=6, paged=True,
                                          page_size=8))
    m = eng.run_workload(rate_req_s=200.0, n_requests=6, prompt_len=10,
                        seed=2, eos_rate=0.5)
    assert m["finish_eos"] + m["finish_budget"] == m["requests"] == 6
    assert m["finish_eos"] > 0           # rate 0.5 stops most early
    for r in eng.completed:
        budget = min(6, max(1, r.decode_len))
        assert len(r.tokens_out) == budget
        assert r.finish_reason == ("eos" if budget < 6 else "budget")
