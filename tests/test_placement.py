"""Stack-aware page placement tests: PlacementMap partition geometry,
placement-policy allocation (co-location / striping / spill), the
gather DMA cost model, region-preserving defrag (deterministic +
hypothesis property, plus the prefix-trie renumbering regression), the
engine/report plumbing, and the analytical mirror."""
import jax
import numpy as np
import pytest

from repro.core.hw import snake_system
from repro.core.noc import page_gather
from repro.core.placement import (COMMUNAL, GatherCost, PlacementMap,
                                  default_system, gather_cost)
from repro.models import registry
from repro.serving.engine import EngineConfig, make_engine
from repro.serving.paged_cache import PageAllocator, PagedCache
from repro.serving.scheduler import make_grouped_prefix_trace

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")

SYS = snake_system()


# ---------------------------------------------------------------------------
# PlacementMap geometry
# ---------------------------------------------------------------------------
def test_map_partitions_every_page_once():
    pm = PlacementMap(48, 4, communal_pages=8)
    seen = []
    for r in pm.regions():
        seen.extend(pm.region_pages(r))
    assert sorted(seen) == list(range(48))
    for r in pm.regions():
        assert all(pm.region_of(p) == r for p in pm.region_pages(r))


def test_map_uneven_split_front_loads_remainder():
    pm = PlacementMap(10, 3, communal_pages=0)
    assert [pm.region_size(r) for r in range(3)] == [4, 3, 3]


def test_map_from_system_caps_regions():
    pm = PlacementMap.from_system(SYS, 8)
    assert pm.n_regions == 8            # 16 PUs capped by 8 pages
    pm = PlacementMap.from_system(SYS, 64, communal_frac=0.25)
    assert pm.n_regions == SYS.pus and pm.communal_pages == 16
    with pytest.raises(ValueError):
        PlacementMap(8, 9)
    with pytest.raises(ValueError):
        PlacementMap(8, 1, communal_pages=8)


# ---------------------------------------------------------------------------
# Placement-policy allocation
# ---------------------------------------------------------------------------
def _map48():
    return PlacementMap(48, 4, communal_pages=8)


def test_affinity_colocates_and_spills():
    pm = _map48()
    a = PageAllocator(48, placement=pm, policy="affinity")
    got = a.alloc(5, home=2)
    assert all(pm.region_of(p) == 2 for p in got)
    # home (10 pages) runs dry -> spill covers the rest, never fails
    more = a.alloc(8, home=2)
    assert sum(pm.region_of(p) == 2 for p in more) == 5
    assert all(pm.region_of(p) != COMMUNAL for p in more)


def test_communal_routing_prefers_communal_region():
    pm = _map48()
    a = PageAllocator(48, placement=pm, policy="affinity")
    got = a.alloc(5, home=1, communal=3)
    assert sum(pm.region_of(p) == COMMUNAL for p in got) == 3
    assert sum(pm.region_of(p) == 1 for p in got) == 2


def test_interleave_stripes_across_regions():
    pm = _map48()
    a = PageAllocator(48, placement=pm, policy="interleave")
    got = a.alloc(8)
    per = {r: sum(pm.region_of(p) == r for p in got) for r in range(4)}
    assert per == {0: 2, 1: 2, 2: 2, 3: 2}


def test_free_first_policy_keeps_legacy_layout():
    pm = _map48()
    a = PageAllocator(48, placement=pm, policy="free-first")
    b = PageAllocator(48)
    assert a.alloc(5, home=3) == b.alloc(5)


def test_placed_alloc_is_atomic_and_conserving():
    pm = PlacementMap(12, 3, communal_pages=0)
    a = PageAllocator(12, placement=pm, policy="affinity")
    held = a.alloc(10, home=0)
    before = (a.free_pages, a.used_pages)
    assert a.alloc(3, home=1) is None      # only 2 free
    assert (a.free_pages, a.used_pages) == before
    a.free(held)
    assert a.free_pages == 12


def test_region_accounting():
    pm = _map48()
    a = PageAllocator(48, placement=pm, policy="affinity")
    a.alloc(4, home=0)
    a.alloc(2, communal=2)
    used, free = a.region_used(), a.region_free()
    assert used[0] == 4 and used[COMMUNAL] == 2
    assert free[0] == pm.region_size(0) - 4
    assert sum(free.values()) + sum(used.values()) == 48


# ---------------------------------------------------------------------------
# Gather cost model
# ---------------------------------------------------------------------------
def test_gather_cost_local_beats_mixed_beats_striped():
    bpp = 4096
    local = gather_cost(SYS, {1: 8}, bpp)
    mixed = gather_cost(SYS, {1: 6, 2: 2}, bpp)
    striped = gather_cost(SYS, {0: 2, 1: 2, 2: 2, 3: 2}, bpp)
    assert local.time_s < mixed.time_s < striped.time_s
    assert local.concentration == 1.0 and local.remote_regions == 0
    assert mixed.home == 1 and mixed.concentration == 0.75
    assert striped.remote_regions == 3


def test_gather_cost_empty_table():
    gc = gather_cost(SYS, {}, 4096)
    assert gc.time_s == 0.0 and gc.concentration == 1.0


def test_page_gather_charges_injection_port_and_hops():
    a = page_gather(SYS, 1024, 0, 0)
    b = page_gather(SYS, 0, 1024, 1)
    # channel-internal bandwidth beats the NoC injection port
    assert a.time_s < b.time_s
    assert b.time_s >= SYS.noc_latency_cycles / SYS.freq_hz
    with pytest.raises(ValueError):
        page_gather(SYS, -1, 0, 0)


# ---------------------------------------------------------------------------
# Region-preserving defrag
# ---------------------------------------------------------------------------
def _cache(policy="affinity", share=False, num_pages=24, n_regions=3,
           communal=6):
    entry = registry.get("yi-6b", reduced=True)
    pm = PlacementMap(num_pages, n_regions,
                      communal_pages=communal if share else 0)
    return PagedCache(entry, max_batch=4, max_seq=32, page_size=4,
                      num_pages=num_pages, share=share, placement=pm,
                      placement_policy=policy)


def test_defrag_preserves_regions_and_refcounts():
    pc = _cache()
    for slot in range(4):
        assert pc.alloc_slot(slot, 12)
    pc.free_slot(1)
    pc.free_slot(2)
    before = {p: pc.alloc.refcount(p) for p in pc.alloc.live_pages()}
    regions_before = {p: pc.placement.region_of(p) for p in before}
    mapping = pc.defrag()
    after = {p: pc.alloc.refcount(p) for p in pc.alloc.live_pages()}
    # refcount multiset carried through the renumbering
    assert after == {mapping[p]: rc for p, rc in before.items()}
    for old, new in mapping.items():
        assert pc.placement.region_of(old) == pc.placement.region_of(new)
    # every region's live pages are compact at its lowest indices
    for r in pc.placement.regions():
        live_r = [p for p in pc.alloc.live_pages()
                  if pc.placement.region_of(p) == r]
        assert live_r == list(pc.placement.region_pages(r))[:len(live_r)]
    assert regions_before  # sanity: the scenario had live pages


def test_defrag_trie_renumbering_consistent_under_regions():
    """Regression (region-constrained compaction targets): a trie hit
    after defrag must map onto pages the allocator still considers live,
    in their original regions — stale trie pages would hand a new
    request another slot's storage."""
    pc = _cache(share=True)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 100, size=10).astype(np.int32)
    assert pc.alloc_slot(0, 11, tokens=prompt)
    pc.commit_prefix(0)
    other = rng.integers(100, 200, size=12).astype(np.int32)
    assert pc.alloc_slot(1, 13, tokens=other)
    pc.commit_prefix(1)
    pc.free_slot(1)                      # holes below the high-water mark
    hit_before = pc.prefix.match(prompt, pc.page_size)
    assert hit_before
    mapping = pc.defrag()
    hit_after = pc.prefix.match(prompt, pc.page_size)
    assert hit_after == [mapping.get(p, p) for p in hit_before]
    for p in hit_after:
        assert pc.alloc.refcount(p) > 0
        assert p in pc.blocks_of(0)


def test_defrag_migrates_spilled_pages_home():
    """A slot whose growth pages spilled out of its home region under
    pressure is repaired once the pool relaxes: defrag's migration pass
    copies the spilled pages home (a NoC DMA priced via ``page_gather``)
    and the slot's gather cost strictly decreases."""
    pc = _cache()                            # affinity, 3 regions x 8
    assert pc.alloc_slot(0, 8)               # 2 pages in its home region
    home = pc.home_region[0]
    for slot, n in ((1, 24), (2, 24), (3, 24)):
        assert pc.alloc_slot(slot, n)
    assert pc.alloc.region_free()[home] == 0   # slot 3 drained the home
    assert pc.extend_slot(0, 24)             # growth is forced to spill
    before = pc.gather_cost_slot(SYS, 0)
    assert before.remote_regions > 0         # the spill really happened
    # stamp slot 0's pages so migration provably moves the bytes
    seq_i = pc.is_seq.index(True)
    for k, page in enumerate(pc.blocks_of(0)):
        pc.store[seq_i] = pc.store[seq_i].at[:, page].set(float(k + 1))
    want = np.asarray(jax.tree.leaves(pc.gather())[seq_i][:, 0])
    pc.free_slot(3)                          # pressure relaxes
    pc.defrag(SYS)
    after = pc.gather_cost_slot(SYS, 0)
    assert pc.migrated_pages == 4 and pc.migration_cost_s > 0.0
    assert after.time_s < before.time_s
    assert after.remote_regions == 0 and after.concentration == 1.0
    assert set(pc.slot_region_counts(0)) == {home}
    # logical contents survived the copy + renumbering
    got = np.asarray(jax.tree.leaves(pc.gather())[seq_i][:, 0])
    np.testing.assert_array_equal(got, want)
    assert all(pc.alloc.refcount(p) == 1 for p in pc.alloc.live_pages())


@needs_hypothesis
@settings(max_examples=50, deadline=None) if HAS_HYPOTHESIS else (lambda f: f)
@given(st.lists(st.tuples(st.booleans(), st.integers(0, 3)), min_size=1,
                max_size=30),
       st.sampled_from(["free-first", "interleave", "affinity"])) \
    if HAS_HYPOTHESIS else (lambda f: f)
def test_defrag_region_property(ops, policy):
    """Any alloc/free interleaving followed by defrag keeps every live
    page in its original region with its refcount unchanged."""
    pm = PlacementMap(18, 3, communal_pages=3)
    a = PageAllocator(18, placement=pm, policy=policy)
    held = []
    for i, (is_alloc, n) in enumerate(ops):
        if is_alloc:
            got = a.alloc(n, home=i % 3, communal=n % 2)
            if got is not None:
                held.append(got)
        elif held:
            a.free(held.pop())
    live = {p: a.refcount(p) for p in a.live_pages()}
    # region-preserving renumbering through the public rebuild API
    mapping = {}
    for r in pm.regions():
        live_r = [p for p in sorted(live) if pm.region_of(p) == r]
        mapping.update(zip(live_r, pm.region_pages(r)))
    a.rebuild({mapping[p]: rc for p, rc in live.items()})
    assert {pm.region_of(p) for p in live} \
        == {pm.region_of(mapping[p]) for p in live}
    for p, rc in live.items():
        assert pm.region_of(mapping[p]) == pm.region_of(p)
        assert a.refcount(mapping[p]) == rc
    assert a.free_pages + a.used_pages == 18


# ---------------------------------------------------------------------------
# Engine integration + analytical mirror
# ---------------------------------------------------------------------------
def _trace(entry, n=6):
    return make_grouped_prefix_trace(
        entry.config.vocab, rate_req_s=100.0, n_requests=n, n_groups=2,
        prefix_len=8, tail_len=4, skew=0.8, seed=0)


@pytest.mark.parametrize("policy", ["free-first", "interleave", "affinity"])
def test_engine_placement_token_exact_and_reported(policy):
    entry = registry.get("yi-6b", reduced=True)
    base = make_engine(entry, EngineConfig(
        max_batch=3, max_seq=32, max_new_tokens=6, paged=True,
        page_size=4, prefix_sharing=True))
    base.run_trace(_trace(entry))
    want = {r.rid: r.tokens_out for r in base.completed}
    eng = make_engine(entry, EngineConfig(
        max_batch=3, max_seq=32, max_new_tokens=6, paged=True,
        page_size=4, prefix_sharing=True, placement=policy,
        placement_regions=4))
    m = eng.run_trace(_trace(entry))
    assert {r.rid: r.tokens_out for r in eng.completed} == want
    assert m["placement_policy"] == policy
    assert m["kv_gather_cost_mean_s"] > 0.0
    assert 0.0 < m["kv_gather_concentration"] <= 1.0
    rep = eng.load_report()
    assert rep.min_region_free == min(rep.region_free)
    # the JSON boundary keeps the legacy dict keys
    d = rep.to_dict()
    assert d["min_region_free"] == min(d["region_free"])


def test_engine_without_placement_reports_none():
    entry = registry.get("yi-6b", reduced=True)
    eng = make_engine(entry, EngineConfig(
        max_batch=3, max_seq=32, max_new_tokens=4, paged=True,
        page_size=4))
    m = eng.run_trace(_trace(entry, n=3))
    assert m["placement_policy"] == "none"
    assert m["kv_gather_cost_mean_s"] == 0.0
    rep = eng.load_report()
    assert rep.region_free == ()
    assert rep.min_region_free == rep.free_pages
    assert "region_free" not in rep.to_dict()


def test_sim_placement_scores_policies_without_changing_schedule():
    from repro.core.operators import PAPER_MODELS
    from repro.core.serving_sim import nmp_latency_model, simulate_serving
    spec = PAPER_MODELS["LLaMA3-70B"]
    lat = nmp_latency_model(SYS, spec, tp=8)
    reports = {}
    for policy in ("free-first", "interleave", "affinity"):
        reports[policy] = simulate_serving(
            lat, spec, 0.5, system="SNAKE", n_requests=12,
            cache_mode="paged", prefix_sharing=True,
            shared_prefix_len=1024, page_size=64, num_pages=1600,
            placement=policy, n_regions=8, hw=SYS)
    e2e = {rep.e2e_mean_s for rep in reports.values()}
    assert len(e2e) == 1                 # placement never changes latency
    aff, ff = reports["affinity"], reports["free-first"]
    assert aff.gather_cost_mean_s < ff.gather_cost_mean_s
    assert aff.gather_concentration > reports["interleave"] \
        .gather_concentration
    assert sum(rep.region_peak_pages[0] > 0 for rep in reports.values())


def test_sim_placement_requires_paged():
    from repro.core.operators import PAPER_MODELS
    from repro.core.serving_sim import nmp_latency_model, simulate_serving
    spec = PAPER_MODELS["LLaMA3-70B"]
    lat = nmp_latency_model(SYS, spec, tp=8)
    with pytest.raises(ValueError):
        simulate_serving(lat, spec, 0.5, system="SNAKE", n_requests=4,
                         cache_mode="dense", placement="affinity")
    with pytest.raises(ValueError):
        simulate_serving(lat, spec, 0.5, system="SNAKE", n_requests=4,
                         cache_mode="paged", placement="bogus")


def test_default_system_is_snake():
    assert default_system().name == "SNAKE"
    assert isinstance(gather_cost(default_system(), {0: 1}, 1), GatherCost)
