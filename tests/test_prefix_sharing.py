"""Prefix-sharing tests: refcounted allocator invariants (deterministic +
hypothesis property tests), the prompt-prefix trie, copy-on-write forks
under interleaved decode, token-exact dense/paged/shared equivalence on
shared-prefix traces, the out-of-window scatter regression, refcount-aware
defrag (public ``rebuild`` API + engine fragmentation trigger), and the
analytical sharing mirror in ``core.serving_sim``."""
import jax
import numpy as np
import pytest

from repro.core.hw import snake_system
from repro.core.operators import PAPER_MODELS
from repro.core.serving_sim import nmp_latency_model, simulate_serving
from repro.models import registry
from repro.serving.engine import (EngineConfig, RequestState, make_engine,
                                  make_shared_prefix_trace, make_trace)
from repro.serving.paged_cache import (PageAllocator, PagedCache,
                                       PrefixIndex, num_blocks,
                                       probe_seq_leaves)

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")


# ---------------------------------------------------------------------------
# PageAllocator refcounts: deterministic invariants
# ---------------------------------------------------------------------------
def test_refcount_shared_page_not_freed_until_last_ref():
    a = PageAllocator(4)
    [p] = a.alloc(1)
    a.incref(p)
    assert a.refcount(p) == 2 and a.shared_pages == 1
    assert not a.decref(p)              # one holder remains: not freed
    assert a.used_pages == 1 and a.free_pages == 3
    assert a.decref(p)                  # last reference frees
    assert a.used_pages == 0 and a.free_pages == 4
    with pytest.raises(ValueError):
        a.decref(p)                     # double free still rejected
    with pytest.raises(ValueError):
        a.incref(p)                     # incref needs a live page


def test_free_is_decref():
    """free() on a shared page drops one reference, never the page."""
    a = PageAllocator(4)
    pages = a.alloc(2)
    for p in pages:
        a.incref(p)
    a.free(pages)
    assert a.used_pages == 2            # second holder keeps them live
    a.free(pages)
    assert a.free_pages == 4


def test_rebuild_restores_lifo_order_and_refcounts():
    a = PageAllocator(8)
    a.alloc(8)
    a.rebuild({2: 1, 5: 3})
    assert a.used_pages == 2 and a.free_pages == 6
    assert a.refcount(5) == 3 and a.refcount(0) == 0
    # free list is rebuilt descending: allocation hands out the lowest
    # free indices first, same as a freshly constructed allocator
    assert a.alloc(3) == [0, 1, 3]
    with pytest.raises(ValueError):
        a.rebuild({99: 1})
    with pytest.raises(ValueError):
        a.rebuild({0: 0})


@needs_hypothesis
@settings(max_examples=100, deadline=None) if HAS_HYPOTHESIS else (lambda f: f)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 5)),
                max_size=60)) if HAS_HYPOTHESIS else (lambda f: f)
def test_allocator_refcount_invariants(ops):
    """Any alloc/incref/decref interleaving conserves pages, never frees a
    page while references remain, and returns pages exactly at refcount
    zero."""
    from collections import Counter
    a = PageAllocator(12)
    held = []                           # our reference multiset
    for kind, arg in ops:
        if kind == 0:
            got = a.alloc(arg)
            if got is not None:
                held.extend(got)
        elif kind == 1 and held:
            p = held[arg % len(held)]
            a.incref(p)
            held.append(p)
        elif kind == 2 and held:
            p = held.pop(arg % len(held))
            a.decref(p)
        model = Counter(held)
        assert a.used_pages == len(model)
        assert a.free_pages + a.used_pages == 12
        for p, rc in model.items():
            assert a.refcount(p) == rc
        # no held page is ever handed out again (i.e. on the free list)
        grabbed = a.alloc(a.free_pages)
        assert not (set(model) & set(grabbed))
        a.free(grabbed)
    for p in list(held):
        a.decref(p)
    assert a.free_pages == 12 and a.used_pages == 0


# ---------------------------------------------------------------------------
# PrefixIndex trie
# ---------------------------------------------------------------------------
def test_prefix_index_match_register_remove_remap():
    trie = PrefixIndex()
    toks = np.arange(20, dtype=np.int32)
    trie.register(toks, [4, 7, 9], 8)
    assert trie.match(toks, 8) == [4, 7, 9]     # full + exact partial tail
    assert trie.match(toks[:16], 8) == [4, 7]   # whole pages only
    assert trie.match(toks[:18], 8) == [4, 7]   # different tail: no hit
    other = np.concatenate([toks[:8], np.full(8, 99, np.int32)])
    assert trie.match(other, 8) == [4]          # diverges after page 0
    trie.remap({4: 0, 7: 1, 9: 2})              # defrag renumbering
    assert trie.match(toks, 8) == [0, 1, 2]
    trie.remove(1)
    assert trie.match(toks, 8) == [0]
    assert len(trie) == 2


def test_prefix_index_first_writer_wins():
    trie = PrefixIndex()
    toks = np.arange(16, dtype=np.int32)
    trie.register(toks, [3, 5], 8)
    trie.register(toks, [8, 9], 8)      # duplicate content stays private
    assert trie.match(toks, 8) == [3, 5]


# ---------------------------------------------------------------------------
# PagedCache: sharing, CoW, scatter regression, refcount-aware defrag
# ---------------------------------------------------------------------------
def _filled_cache(entry, n_tokens, fill):
    """Batch-1 cache whose sequence leaves are `fill` on the valid prefix."""
    import jax.numpy as jnp
    c = entry.cache_zeros(1, n_tokens, 1)
    leaves, treedef = jax.tree.flatten(c)
    seq = probe_seq_leaves(entry, 1)
    out = []
    for leaf, s in zip(leaves, seq):
        if s:
            out.append(jnp.full_like(leaf, fill))
        elif leaf.ndim == 1:
            out.append(jnp.full_like(leaf, n_tokens))  # lengths
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def _seq_leaves(pc, tree):
    return [leaf for leaf, s in zip(jax.tree.leaves(tree), pc.is_seq) if s]


def test_paged_cache_prefix_sharing_maps_and_isolates():
    entry = registry.get("yi-6b", reduced=True)
    pc = PagedCache(entry, max_batch=3, max_seq=32, page_size=8,
                    num_pages=12, share=True)
    prompt = (np.arange(20, dtype=np.int32) * 3 + 1) % 97
    assert pc.alloc_slot(0, 21, tokens=prompt)
    pc.write_slot(0, _filled_cache(entry, 20, 3), 20)
    assert pc.pages_in_use() == 3
    # identical prompt: all three prompt pages map onto slot 0's
    assert pc.alloc_slot(1, 21, tokens=prompt)
    assert pc.pages_in_use() == 3
    assert int(pc.shared_count[1]) == 3
    pc.write_slot(1, _filled_cache(entry, 20, 5), 20)   # skipped: shared
    for leaf in _seq_leaves(pc, pc.gather()):
        np.testing.assert_array_equal(np.asarray(leaf[:, 1, :20]), 3)
    rep = pc.sharing_report()
    assert rep["dedup_ratio"] == 2.0 and rep["shared_pages"] == 3
    # CoW: fork slot 1's tail page; a write there no longer aliases slot 0
    assert pc.fork_page(1, 2)
    assert pc.pages_in_use() == 4 and pc.alloc.shared_pages == 2
    assert pc.cow_forks == 1
    pc.scatter_token(pc.gather(), np.array([0, 20, 0]),
                     np.array([False, True, False]))
    for leaf in _seq_leaves(pc, pc.gather()):
        np.testing.assert_array_equal(np.asarray(leaf[:, 0, :20]), 3)
    pc.free_slot(0)
    assert pc.pages_in_use() == 3       # decref'd, still held by slot 1
    pc.free_slot(1)
    assert pc.pages_in_use() == 0


def test_cow_for_write_only_forks_shared_pages():
    entry = registry.get("yi-6b", reduced=True)
    pc = PagedCache(entry, max_batch=2, max_seq=32, page_size=8,
                    num_pages=8, share=True)
    prompt = np.arange(12, dtype=np.int32)
    assert pc.alloc_slot(0, 13, tokens=prompt)
    pc.write_slot(0, _filled_cache(entry, 12, 3), 12)
    assert pc.alloc_slot(1, 13, tokens=prompt)
    # exclusive page (slot 0 after slot 1 forks) and unmapped windows
    # are no-ops; the shared tail page forks exactly once per holder-write
    assert pc.cow_for_write(1, 12)
    assert pc.cow_forks == 1
    assert pc.cow_for_write(0, 12)      # now exclusive again: no fork
    assert pc.cow_forks == 1
    assert pc.cow_for_write(0, 10_000)  # out of window: scratch, no fork
    assert pc.cow_forks == 1


def test_scatter_out_of_window_goes_to_scratch():
    """Regression: a write whose position exceeds the mapped window used to
    be clipped onto the window's last *live* page, corrupting resident KV;
    it must land in the scratch page."""
    entry = registry.get("yi-6b", reduced=True)
    pc = PagedCache(entry, max_batch=2, max_seq=16, page_size=8,
                    num_pages=6)
    assert pc.alloc_slot(0, 16)
    pc.write_slot(0, _filled_cache(entry, 16, 3), 16)
    before = [np.asarray(x) for x in _seq_leaves(pc, pc.gather())]
    pc.scatter_token(pc.gather(), np.array([16, 0]),
                     np.array([True, False]))
    after = [np.asarray(x) for x in _seq_leaves(pc, pc.gather())]
    for b, a in zip(before, after):
        np.testing.assert_array_equal(b, a)


def test_defrag_refcount_aware_with_sharing():
    entry = registry.get("yi-6b", reduced=True)
    pc = PagedCache(entry, max_batch=3, max_seq=32, page_size=8,
                    num_pages=12, share=True)
    filler = np.arange(100, 116, dtype=np.int32)
    prompt = np.arange(20, dtype=np.int32)
    assert pc.alloc_slot(0, 17, tokens=filler)          # pages 0..2
    pc.write_slot(0, _filled_cache(entry, 16, 9), 16)
    assert pc.alloc_slot(1, 21, tokens=prompt)          # pages 3..5
    pc.write_slot(1, _filled_cache(entry, 20, 3), 20)
    assert pc.alloc_slot(2, 21, tokens=prompt)          # shares 3..5
    pc.write_slot(2, _filled_cache(entry, 20, 5), 20)
    pc.free_slot(0)                     # hole below the shared pages
    assert pc.fragmentation() > 0.4
    before = jax.tree.map(np.asarray, pc.gather())
    mapping = pc.defrag()
    after = jax.tree.map(np.asarray, pc.gather())
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(b, a)
    assert sorted(mapping.values())[:pc.pages_in_use()] == [0, 1, 2]
    assert pc.alloc.shared_pages == 3   # refcounts survive rebuild
    assert pc.fragmentation() == 0.0
    # the trie was renumbered with the pages: a third sharer still maps
    assert pc.alloc_slot(0, 21, tokens=prompt)
    assert int(pc.shared_count[0]) == 3
    assert pc.pages_in_use() == 3
    assert pc.alloc.refcount(int(pc.tables[0, 0])) == 3


# ---------------------------------------------------------------------------
# Engine: CoW under interleaved decode, token-exactness, defrag trigger
# ---------------------------------------------------------------------------
def test_engine_cow_fork_under_interleaved_decode():
    """Two identical prompts share even the ragged tail page; the first
    decode write forks it (CoW) and the decoded tokens still match the
    dense engine exactly."""
    entry = registry.get("yi-6b", reduced=True)
    prompt = ((np.arange(12, dtype=np.int32) * 7 + 3)
              % entry.config.vocab).astype(np.int32)

    def reqs():
        return [RequestState(0, prompt.copy()),
                RequestState(1, prompt.copy())]

    ecfg = EngineConfig(max_batch=2, max_seq=32, max_new_tokens=6,
                        paged=True, page_size=8, prefix_sharing=True)
    eng = make_engine(entry, ecfg)
    r0, r1 = reqs()
    assert eng.submit(r0)
    pages_one = eng.paged.pages_in_use()
    assert eng.submit(r1)
    assert eng.paged.pages_in_use() == pages_one    # fully deduplicated
    assert eng.paged.alloc.shared_pages == num_blocks(12, 8) == 2
    eng.step()      # both slots write position 12: shared tail page forks
    assert eng.paged.cow_forks == 1
    assert eng.paged.alloc.shared_pages == 1        # full page still shared
    while eng.active:
        eng.step()
    assert eng.paged.pages_in_use() == 0

    dense = make_engine(entry, EngineConfig(max_batch=2, max_seq=32,
                                            max_new_tokens=6))
    d0, d1 = reqs()
    assert dense.submit(d0) and dense.submit(d1)
    while dense.active:
        dense.step()
    assert (r0.tokens_out, r1.tokens_out) == (d0.tokens_out, d1.tokens_out)


@pytest.mark.slow
def test_shared_prefix_trace_token_exact_and_resident_below_paged():
    """Dense, paged, and paged+sharing engines emit identical tokens on a
    shared-prefix trace, while sharing keeps resident pages strictly below
    the unshared paged engine and reports dedup > 1."""
    entry = registry.get("yi-6b", reduced=True)

    def run(**over):
        ecfg = EngineConfig(max_batch=3, max_seq=64, max_new_tokens=5,
                            **over)
        eng = make_engine(entry, ecfg)
        reqs = make_shared_prefix_trace(
            entry.config.vocab, rate_req_s=500.0, n_requests=6,
            prefix_len=24, tail_len=5, seed=2)
        m = eng.run_trace(reqs)
        return eng, m

    dense_eng, _ = run()
    paged_eng, _ = run(paged=True, page_size=8)
    shared_eng, shared_m = run(paged=True, page_size=8,
                               prefix_sharing=True)

    def toks(e):
        return {r.rid: r.tokens_out for r in e.completed}

    assert toks(dense_eng) == toks(paged_eng) == toks(shared_eng)
    assert shared_eng.pages_peak < paged_eng.pages_peak
    assert shared_m["kv_dedup_ratio_peak"] > 1.0
    assert shared_m["kv_shared_pages"] == 0         # all released by now


@pytest.mark.slow
def test_shared_prefix_pallas_readthrough_matches():
    """The block-table Pallas decode path is token-exact under sharing
    (CoW forks happen before the kernel writes)."""
    entry = registry.get("yi-6b", reduced=True)

    def run(**over):
        ecfg = EngineConfig(max_batch=3, max_seq=64, max_new_tokens=4,
                            **over)
        eng = make_engine(entry, ecfg)
        reqs = make_shared_prefix_trace(
            entry.config.vocab, rate_req_s=500.0, n_requests=4,
            prefix_len=16, tail_len=0, seed=4)      # identical prompts
        eng.run_trace(reqs)
        return {r.rid: r.tokens_out for r in eng.completed}

    assert run() == run(paged=True, page_size=8, prefix_sharing=True,
                        use_pallas_decode=True)


@pytest.mark.slow
def test_shared_chunked_pallas_does_not_corrupt_shared_pages():
    """Regression: while a slot is mid chunked-prefill it already has
    shared prefix pages mapped but is not in the decode batch; the Pallas
    kernel writes every lane's K/V unconditionally, so an unmasked lane
    used to clobber position 0 of a live shared page (which write_slot
    then skips, never repairing it).  Inactive lanes must write scratch."""
    entry = registry.get("yi-6b", reduced=True)

    def run(**over):
        ecfg = EngineConfig(max_batch=2, max_seq=48, max_new_tokens=6,
                            **over)
        eng = make_engine(entry, ecfg)
        reqs = make_shared_prefix_trace(
            entry.config.vocab, rate_req_s=1000.0, n_requests=3,
            prefix_len=16, tail_len=0, seed=6)     # identical prompts
        eng.run_trace(reqs)
        return {r.rid: r.tokens_out for r in eng.completed}

    assert run() == run(paged=True, page_size=8, prefix_sharing=True,
                        prefill_chunk=4, use_pallas_decode=True)


def test_engine_defrag_trigger_runs():
    entry = registry.get("yi-6b", reduced=True)
    ecfg = EngineConfig(max_batch=2, max_seq=32, max_new_tokens=3,
                        paged=True, page_size=8, defrag_threshold=0.3)
    eng = make_engine(entry, ecfg)
    m = eng.run_trace(make_trace(entry.config.vocab, rate_req_s=1000.0,
                                 n_requests=6, prompt_len=12, seed=5))
    assert m["requests"] == 6
    assert m["defrag_runs"] >= 1
    assert eng.paged.pages_in_use() == 0


def test_max_seq_roundup_reconciled():
    """A max_seq that isn't a page multiple is rounded up once and adopted
    everywhere; kv_report asserts table capacity and engine agree."""
    entry = registry.get("yi-6b", reduced=True)
    ecfg = EngineConfig(max_batch=2, max_seq=50, max_new_tokens=3,
                        paged=True, page_size=8)
    eng = make_engine(entry, ecfg)
    assert eng.ecfg.max_seq == 56 == eng.paged.max_seq
    assert eng.paged.max_blocks * ecfg.page_size == eng.ecfg.max_seq
    req = RequestState(0, np.arange(9, dtype=np.int32))
    assert eng.submit(req)
    eng.step()
    assert eng.kv_report()["used_tokens"] == 9 + 2


# ---------------------------------------------------------------------------
# Analytical mirror (core.serving_sim)
# ---------------------------------------------------------------------------
def _sim(**kw):
    spec = PAPER_MODELS["LLaMA3-70B"]
    lat = nmp_latency_model(snake_system(), spec, tp=8)
    return simulate_serving(lat, spec, 0.5, system="SNAKE",
                            n_requests=16, **kw)


def test_sim_sharing_reduces_resident_kv():
    base = _sim(cache_mode="paged")
    shared = _sim(cache_mode="paged", prefix_sharing=True,
                  shared_prefix_len=1024)
    assert shared.kv_peak_tokens < base.kv_peak_tokens
    assert shared.dedup_ratio > 1.0
    assert base.dedup_ratio == 1.0
    # sharing is a residency policy, not a latency change
    assert shared.e2e_mean_s == base.e2e_mean_s
    assert shared.tbt_mean_s == base.tbt_mean_s


def test_sim_sharing_edge_cases():
    base = _sim(cache_mode="paged")
    zero = _sim(cache_mode="paged", prefix_sharing=True,
                shared_prefix_len=0)
    assert zero.kv_peak_tokens == base.kv_peak_tokens
    # a sub-page prefix deduplicates nothing (whole pages only)
    subpage = _sim(cache_mode="paged", prefix_sharing=True,
                   shared_prefix_len=7)
    assert subpage.dedup_ratio == 1.0
    with pytest.raises(ValueError):
        _sim(cache_mode="dense", prefix_sharing=True,
             shared_prefix_len=1024)
    with pytest.raises(ValueError):
        _sim(cache_mode="paged", prefix_sharing=True,
             shared_prefix_len=10_000)
