"""Paper-claims validation (EXPERIMENTS.md §Paper).

Reruns the paper's headline comparisons on the reproduced evaluation stack
and asserts the results fall in documented bands.  Bands are wider than the
paper's point values where our tile-level model is known to diverge from
the paper's cycle-accurate Scale-Sim v3 + RTL setup (each divergence is
documented in EXPERIMENTS.md §Paper-fidelity); they are tight where the
quantity is a pure calibration (Fig. 11).
"""
import numpy as np
import pytest

from repro.core.energy import peak_power_breakdown
from repro.core.gpu_model import gpu_decode_step
from repro.core.hw import (area_model, fixed_sa_system, mactree_system,
                           snake_system)
from repro.core.operators import PAPER_MODELS, layer_ops_tp
from repro.core.pipeline import decode_step
from repro.core.schedule import Mode

CTX = 8192 + 512
TP = 8
BATCHES = (8, 16, 32, 64)


def _geomean(xs):
    return float(np.exp(np.mean(np.log(np.asarray(xs)))))


@pytest.fixture(scope="module")
def ratios():
    systems = {"MAC-Tree": mactree_system(),
               "SA-48x48": fixed_sa_system(48, 48),
               "SA-8x288": fixed_sa_system(8, 288)}
    snake = snake_system()
    out = {k: {"speedup": [], "energy": []} for k in
           list(systems) + ["GPU"]}
    for spec in PAPER_MODELS.values():
        for b in BATCHES:
            rs = decode_step(snake, spec, b, CTX, tp=TP)
            for k, sysm in systems.items():
                r = decode_step(sysm, spec, b, CTX, tp=TP)
                out[k]["speedup"].append(r.time_s / rs.time_s)
                out[k]["energy"].append(
                    r.energy.logic_die_j / rs.energy.logic_die_j)
            g = gpu_decode_step(spec, b, CTX, tp=TP)
            out["GPU"]["speedup"].append(g.time_s / rs.time_s)
            out["GPU"]["energy"].append(
                g.energy_j / rs.energy.logic_die_j)
    return {k: {m: _geomean(v) for m, v in d.items()}
            for k, d in out.items()}


# ---------------------------------------------------------------------------
# Fig. 12 — decode speedup / energy efficiency vs baselines
# ---------------------------------------------------------------------------
def test_speedup_vs_mactree(ratios):
    """Paper: 2.90x average speedup over the Stratum-configured MAC tree."""
    assert 1.7 <= ratios["MAC-Tree"]["speedup"] <= 4.0


def test_energy_vs_mactree(ratios):
    """Paper: 2.40x average energy efficiency over the MAC tree."""
    assert 1.7 <= ratios["MAC-Tree"]["energy"] <= 3.4


def test_speedup_vs_sa48(ratios):
    """Paper: 2.33x over the fixed 48x48 SA."""
    assert 1.6 <= ratios["SA-48x48"]["speedup"] <= 3.3


def test_energy_vs_sa48(ratios):
    """Paper: 1.05x over the fixed 48x48 SA (energy)."""
    assert 0.9 <= ratios["SA-48x48"]["energy"] <= 2.2


def test_speedup_vs_sa8x288(ratios):
    """Paper: 3.00x over the fixed 8x288 SA.  Our tile-level model keeps
    the elongated array competitive at small batch (documented divergence:
    no cycle-level stall modelling), so only the direction is asserted."""
    assert ratios["SA-8x288"]["speedup"] >= 1.15


def test_energy_vs_sa8x288(ratios):
    """Paper: 1.31x energy efficiency over the 8x288 SA."""
    assert 0.9 <= ratios["SA-8x288"]["energy"] <= 1.9


def test_speedup_vs_gpu(ratios):
    """Paper: 11.47x over 8x H100 decoding."""
    assert 5.5 <= ratios["GPU"]["speedup"] <= 18.0


def test_energy_vs_gpu(ratios):
    """Paper: 5.74x energy efficiency over the GPU (logic-die vs silicon
    accounting; our GPU energy model is coarser — wide band)."""
    assert 4.0 <= ratios["GPU"]["energy"] <= 14.0


def test_snake_strictly_dominates_every_model(ratios):
    """SNAKE must beat the MAC tree on every (model, batch) cell at b>=16
    (the compute-bound regime the paper targets)."""
    snake = snake_system()
    mac = mactree_system()
    for spec in PAPER_MODELS.values():
        for b in (16, 32, 64):
            rs = decode_step(snake, spec, b, CTX, tp=TP)
            rm = decode_step(mac, spec, b, CTX, tp=TP)
            assert rm.time_s > rs.time_s, (spec.name, b)


# ---------------------------------------------------------------------------
# Fig. 11 — area / power calibration (tight: pure calibration)
# ---------------------------------------------------------------------------
def test_compute_area_efficiency():
    am = area_model()
    assert am["SNAKE"]["compute_area_efficiency"] == pytest.approx(4.00)
    assert am["SA+VectorCore"]["compute_area_efficiency"] == \
        pytest.approx(2.25)


def test_area_breakdown_shares():
    am = area_model()
    assert am["SNAKE"]["breakdown"]["buffers"] == pytest.approx(0.281)
    assert am["SA+VectorCore"]["breakdown"]["buffers"] == pytest.approx(0.536)
    assert am["SNAKE"]["breakdown"]["vector"] == pytest.approx(0.088)


def test_power_breakdown_near_paper():
    """Paper: 61.8 W total = 38.5 matrix + 14.2 vector + 4.4 ctrl + 4.8 NoC
    at the 800 MHz thermal operating point."""
    pw = peak_power_breakdown(snake_system())
    assert pw["matrix_w"] == pytest.approx(38.5, rel=0.05)
    assert pw["vector_w"] == pytest.approx(14.2, rel=0.05)
    assert pw["ctrl_w"] == pytest.approx(4.4, rel=0.01)
    total = sum(v for k, v in pw.items())
    assert total == pytest.approx(61.8, rel=0.06)


# ---------------------------------------------------------------------------
# Fig. 1 — motivation: decode is compute-bound on 3D NMP
# ---------------------------------------------------------------------------
def test_ridge_points():
    """Stratum-class ridge 3.7-6.7 FLOP/B; SNAKE raises it ~3.2x."""
    mac = mactree_system()
    snake = snake_system()
    assert 3.7 <= mac.ridge_point <= 8.0
    assert snake.ridge_point / mac.ridge_point == pytest.approx(3.2, rel=0.1)


def test_decode_flops_mostly_compute_bound_on_stratum():
    """Fig. 1a: at batch>=16 most decode FLOPs sit above Stratum's ridge."""
    spec = PAPER_MODELS["LLaMA3-70B"]
    mac = mactree_system()
    for b in (16, 32, 64):
        lo = layer_ops_tp(spec, b, CTX, TP)
        ops = list(lo.projections) + list(lo.attention) + list(lo.experts)
        cb = sum(g.flops for g in ops
                 if g.arithmetic_intensity > mac.ridge_point)
        assert cb / sum(g.flops for g in ops) > 0.5, b


def test_stratum_compute_lags_memory():
    """Fig. 1b: on the MAC tree, array time exceeds memory-supply time."""
    spec = PAPER_MODELS["LLaMA3-70B"]
    mac = mactree_system()
    for b in (16, 32, 64):
        rep = decode_step(mac, spec, b, CTX, tp=TP)
        comp = sum(e.compute_s for e in rep.op_execs)
        mem = sum(e.memory_s for e in rep.op_execs)
        assert comp > mem, b


# ---------------------------------------------------------------------------
# Fig. 13 — per-operator scheduling beats any fixed mode
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model", ["LLaMA3-70B", "Qwen3-30B-A3B"])
def test_scheduler_beats_fixed_modes(model):
    spec = PAPER_MODELS[model]
    sys = snake_system()
    for b in (8, 32):
        best = decode_step(sys, spec, b, CTX, tp=TP).time_s
        for m in Mode:
            fixed = decode_step(sys, spec, b, CTX, tp=TP,
                                fixed_mode=m).time_s
            assert fixed >= best * 0.999, (model, b, m.value)


# ---------------------------------------------------------------------------
# Serving (Fig. 10) — ordering at saturation
# ---------------------------------------------------------------------------
def test_serving_ordering_at_saturation():
    """At decode saturation, SNAKE <= MAC tree <= ~GPU on TBT (LLaMA3)."""
    from repro.core.serving_sim import (gpu_latency_model,
                                        nmp_latency_model,
                                        simulate_serving)
    spec = PAPER_MODELS["LLaMA3-70B"]
    rate = 2.0
    base = simulate_serving(nmp_latency_model(snake_system(), spec, tp=TP),
                            spec, rate, system="SNAKE", n_requests=32)
    mac = simulate_serving(nmp_latency_model(mactree_system(), spec, tp=TP),
                           spec, rate, system="MAC", n_requests=32)
    gpu = simulate_serving(gpu_latency_model(spec, tp=TP), spec, rate,
                           system="GPU", n_requests=32)
    assert base.tbt_mean_s < mac.tbt_mean_s < gpu.tbt_mean_s
    assert base.e2e_mean_s <= mac.e2e_mean_s <= gpu.e2e_mean_s
