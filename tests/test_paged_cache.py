"""Paged KV-cache tests: block-allocator invariants (deterministic +
hypothesis property tests), block-table gather round-trips, defrag, and
token-exact equivalence of the paged engine against the dense-slot engine
on a recorded request trace."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.serving.engine import (EngineConfig, PagedServingEngine,
                                  RequestState, ServingEngine, make_engine,
                                  make_trace)
from repro.serving.paged_cache import (PageAllocator, PagedCache,
                                       num_blocks, probe_seq_leaves)

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")


# ---------------------------------------------------------------------------
# PageAllocator: deterministic invariants
# ---------------------------------------------------------------------------
def test_allocator_no_double_allocation():
    a = PageAllocator(8)
    p1 = a.alloc(3)
    p2 = a.alloc(5)
    assert p1 is not None and p2 is not None
    assert not (set(p1) & set(p2))
    assert a.free_pages == 0
    assert a.alloc(1) is None          # exhausted: refuse, don't raise


def test_allocator_free_returns_all_pages():
    a = PageAllocator(6)
    p = a.alloc(4)
    a.free(p)
    assert a.free_pages == 6
    assert a.used_pages == 0
    assert a.alloc(6) is not None      # everything reusable


def test_allocator_failed_alloc_leaves_state():
    a = PageAllocator(4)
    a.alloc(3)
    before = (a.free_pages, a.used_pages)
    assert a.alloc(2) is None
    assert (a.free_pages, a.used_pages) == before


def test_allocator_double_free_rejected():
    a = PageAllocator(4)
    p = a.alloc(2)
    a.free(p)
    with pytest.raises(ValueError):
        a.free(p)


@needs_hypothesis
@settings(max_examples=100, deadline=None) if HAS_HYPOTHESIS else (lambda f: f)
@given(st.lists(st.tuples(st.booleans(), st.integers(0, 6)), max_size=40)) \
    if HAS_HYPOTHESIS else (lambda f: f)
def test_allocator_conservation(ops):
    """Any alloc/free interleaving conserves pages and never double-books."""
    a = PageAllocator(16)
    held = []
    for is_alloc, n in ops:
        if is_alloc:
            got = a.alloc(n)
            if got is not None:
                assert len(got) == n
                held.append(got)
        elif held:
            a.free(held.pop())
    flat = [p for grp in held for p in grp]
    assert len(flat) == len(set(flat))                 # no double-allocation
    assert a.used_pages == len(flat)
    assert a.free_pages + a.used_pages == 16           # conservation
    for grp in held:
        a.free(grp)
    assert a.free_pages == 16                          # free returns all


# ---------------------------------------------------------------------------
# PagedCache: probing, gather round-trip, defrag
# ---------------------------------------------------------------------------
def _filled_cache(entry, n_tokens, fill):
    """Batch-1 cache whose sequence leaves are `fill` on the valid prefix."""
    c = entry.cache_zeros(1, n_tokens, 1)
    leaves, treedef = jax.tree.flatten(c)
    seq = probe_seq_leaves(entry, 1)
    out = []
    for leaf, s in zip(leaves, seq):
        if s:
            out.append(jnp.full_like(leaf, fill))
        elif leaf.ndim == 1:
            out.append(jnp.full_like(leaf, n_tokens))  # lengths
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)


@pytest.mark.parametrize("arch,expect_paged", [
    ("yi-6b", True), ("rwkv6-7b", False), ("recurrentgemma-9b", False),
    ("whisper-small", True)])
def test_probe_families(arch, expect_paged):
    entry = registry.get(arch, reduced=True)
    pc = PagedCache(entry, max_batch=2, max_seq=32, page_size=8,
                    num_pages=8)
    assert pc.has_seq == expect_paged


def test_gather_roundtrip_and_isolation():
    """What is written into a slot's pages comes back exactly through the
    block table, and neighbouring slots don't see it."""
    entry = registry.get("yi-6b", reduced=True)
    pc = PagedCache(entry, max_batch=3, max_seq=32, page_size=8,
                    num_pages=12)
    assert pc.alloc_slot(0, 20) and pc.alloc_slot(2, 9)
    pc.write_slot(0, _filled_cache(entry, 20, 3), 20)
    pc.write_slot(2, _filled_cache(entry, 9, 5), 9)
    dense = pc.gather()
    for leaf, s in zip(jax.tree.leaves(dense), pc.is_seq):
        if not s:
            continue
        np.testing.assert_array_equal(np.asarray(leaf[:, 0, :20]), 3)
        np.testing.assert_array_equal(np.asarray(leaf[:, 2, :9]), 5)
        np.testing.assert_array_equal(np.asarray(leaf[:, 1, :]), 0)
    # free slot 0, its pages are reusable, slot 2 untouched
    pc.free_slot(0)
    assert pc.pages_in_use() == num_blocks(9, 8)
    dense = pc.gather()
    for leaf, s in zip(jax.tree.leaves(dense), pc.is_seq):
        if s:
            np.testing.assert_array_equal(np.asarray(leaf[:, 2, :9]), 5)


def test_defrag_preserves_contents():
    entry = registry.get("yi-6b", reduced=True)
    pc = PagedCache(entry, max_batch=3, max_seq=32, page_size=8,
                    num_pages=12)
    for slot, (n, fill) in enumerate([(20, 3), (12, 7), (9, 5)]):
        assert pc.alloc_slot(slot, n)
        pc.write_slot(slot, _filled_cache(entry, n, fill), n)
    pc.free_slot(1)                     # punch a hole in the page space
    before = jax.tree.map(np.asarray, pc.gather())
    mapping = pc.defrag()
    after = jax.tree.map(np.asarray, pc.gather())
    for b, a in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(b, a)
    live = sorted(mapping.values())
    assert live == list(range(len(live)))   # compacted to lowest indices


@needs_hypothesis
@settings(max_examples=20, deadline=None) if HAS_HYPOTHESIS else (lambda f: f)
@given(st.lists(st.integers(1, 30), min_size=1, max_size=3),
       st.integers(0, 10_000)) if HAS_HYPOTHESIS else (lambda f: f)
def test_blocktable_gather_roundtrip_property(lens, seed):
    """Block-table gather round-trips arbitrary per-slot contents."""
    entry = registry.get("yi-6b", reduced=True)
    pc = PagedCache(entry, max_batch=3, max_seq=32, page_size=8,
                    num_pages=12)
    rng = np.random.default_rng(seed)
    fills = rng.integers(1, 100, size=len(lens))
    for slot, (n, fill) in enumerate(zip(lens, fills)):
        assert pc.alloc_slot(slot, n)
        pc.write_slot(slot, _filled_cache(entry, n, int(fill)), n)
    dense = pc.gather()
    for leaf, s in zip(jax.tree.leaves(dense), pc.is_seq):
        if not s:
            continue
        for slot, (n, fill) in enumerate(zip(lens, fills)):
            np.testing.assert_array_equal(np.asarray(leaf[:, slot, :n]),
                                          int(fill))


# ---------------------------------------------------------------------------
# Engine equivalence + proportional residency
# ---------------------------------------------------------------------------
SKEWED_LENS = np.array([9, 17, 5, 30, 12, 24])


def _run(entry, reqs, **over):
    ecfg = EngineConfig(max_batch=3, max_seq=48, max_new_tokens=5, **over)
    eng = make_engine(entry, ecfg)
    m = eng.run_trace(reqs)
    return eng, m


def _trace(entry, seed=3):
    return make_trace(entry.config.vocab, rate_req_s=100.0,
                      n_requests=len(SKEWED_LENS), prompt_len=0, seed=seed,
                      prompt_lens=SKEWED_LENS)


@pytest.mark.slow
def test_paged_engine_matches_dense_tokens():
    """Identical traces through both engines -> identical tokens, while the
    paged engine's resident KV stays proportional to the live contexts."""
    entry = registry.get("yi-6b", reduced=True)
    dense_eng, dense_m = _run(entry, _trace(entry))
    paged_eng, paged_m = _run(entry, _trace(entry), paged=True, page_size=8)
    dense_toks = {r.rid: r.tokens_out for r in dense_eng.completed}
    paged_toks = {r.rid: r.tokens_out for r in paged_eng.completed}
    assert dense_toks == paged_toks
    # proportionality: peak pages never exceed what the 3 longest contexts
    # (max_batch concurrently live requests, +1-token write slack) need,
    # and beat the dense max_batch x max_seq reservation
    per_req = sorted(num_blocks(int(n) + 6, 8) for n in SKEWED_LENS)[-3:]
    assert paged_eng.pages_peak <= sum(per_req)
    assert paged_m["kv_peak_tokens"] < dense_m["kv_reserved_tokens"]


@pytest.mark.slow
def test_paged_pallas_readthrough_matches():
    """The block-table Pallas decode path emits the same tokens as the
    dense engine (no gather is materialized on this path)."""
    entry = registry.get("yi-6b", reduced=True)
    dense_eng, _ = _run(entry, _trace(entry))
    pal_eng, _ = _run(entry, _trace(entry), paged=True, page_size=8,
                      use_pallas_decode=True)
    assert ({r.rid: r.tokens_out for r in dense_eng.completed}
            == {r.rid: r.tokens_out for r in pal_eng.completed})


def test_single_request_pages_proportional():
    """One 9-token prompt on an 8-token page: exactly 2 pages at admission
    (prompt + first-token slack), growing only when decode crosses a page
    boundary."""
    entry = registry.get("yi-6b", reduced=True)
    ecfg = EngineConfig(max_batch=2, max_seq=48, max_new_tokens=8,
                        paged=True, page_size=8)
    eng = make_engine(entry, ecfg)
    req = RequestState(0, np.arange(9, dtype=np.int32) % entry.config.vocab)
    assert eng.submit(req)
    assert eng.paged.pages_in_use() == num_blocks(9 + 1, 8) == 2
    for _ in range(6):                 # decode to 15 tokens: still 2 pages
        eng.step()
    assert eng.paged.pages_in_use() == 2
    eng.step()                         # token 16 crosses into page 3
    assert req.done and eng.paged.pages_in_use() == 0   # freed on finish


@pytest.mark.slow
def test_oversubscribed_pool_preempts_and_completes():
    """A pool below the dense-equivalent capacity forces preemption but the
    trace still completes with every request served."""
    entry = registry.get("yi-6b", reduced=True)
    ecfg = EngineConfig(max_batch=3, max_seq=48, max_new_tokens=6,
                        paged=True, page_size=8, num_pages=8)
    eng = make_engine(entry, ecfg)
    # two 28-token prompts each reserve 4 of the 8 pages (cover 32
    # tokens); decode reaches context 33, so the older request's growth
    # must evict the younger one
    reqs = make_trace(entry.config.vocab, rate_req_s=1000.0, n_requests=5,
                      prompt_len=0, seed=7,
                      prompt_lens=np.array([28, 28, 9, 9, 9]))
    m = eng.run_trace(reqs)
    assert m["requests"] == 5
    assert m["preemptions"] >= 1
    assert m["kv_peak_tokens"] <= 8 * 8


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["rwkv6-7b", "whisper-small"])
def test_paged_engine_other_families(arch):
    """The paged engine serves recurrent and enc-dec families via the same
    batch-axis rule (recurrent states consume zero pages)."""
    entry = registry.get(arch, reduced=True)
    ecfg = EngineConfig(max_batch=2, max_seq=48, max_new_tokens=4,
                        paged=True, page_size=8)
    eng = make_engine(entry, ecfg)
    m = eng.run_trace(make_trace(entry.config.vocab, rate_req_s=100.0,
                                 n_requests=4, prompt_len=12, seed=1))
    assert m["requests"] == 4
    if arch == "rwkv6-7b":
        assert m["kv_peak_tokens"] == 0
