"""Property tests for the scheduling mechanisms added during calibration:
multi-port slice packing (§4.2.1), the joint attention search, TP operator
sharding, and the sharding-rule invariants."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.gemm import Dataflow, Gemm, ceil_div
from repro.core.hw import fixed_sa_system, mactree_system, snake_system
from repro.core.operators import (PAPER_MODELS, layer_ops, layer_ops_tp)
from repro.core.schedule import (_best_unit_exec, schedule_attention,
                                 slice_pack)

SNAKE = snake_system()
FIXED = fixed_sa_system(48, 48)
MAC = mactree_system()


# ---------------------------------------------------------------------------
# Slice packing (§4.2.1)
# ---------------------------------------------------------------------------
@given(m=st.integers(1, 128))
@settings(max_examples=40, deadline=None)
def test_slice_pack_preserves_pe_budget(m):
    slices, shape = slice_pack(SNAKE, m)
    if shape is not None and slices > 1:
        rows, cols = shape
        assert slices * rows * cols == SNAKE.substrate.pes
        assert rows >= m
        assert slices <= 8          # weight-injection port budget


def test_fixed_arrays_cannot_pack():
    assert slice_pack(FIXED, 8) == (1, None)
    assert slice_pack(MAC, 8) == (1, None)


@given(m=st.integers(1, 64), n=st.integers(64, 4096),
       k=st.integers(64, 4096), units=st.integers(1, 2048))
@settings(max_examples=40, deadline=None)
def test_packed_choice_never_worse_than_unpacked(m, n, k, units):
    """The (exec, pack) selection minimizes total waves x wave-time, so it
    can never be slower than the unpacked mapping."""
    g = Gemm("g", m, n, k)
    bw = SNAKE.dram_bw_bytes * SNAKE.dram_bw_efficiency / SNAKE.cores
    f = SNAKE.freq_hz
    from repro.core.schedule import core_exec, exec_units
    nu = exec_units(SNAKE)
    base = core_exec(SNAKE, g, Dataflow.IS)
    t_base = ceil_div(units, nu) * max(base.compute_time(f),
                                       base.memory_time(bw))
    ex, pack = _best_unit_exec(SNAKE, g, Dataflow.IS, units)
    t_best = ceil_div(units, nu * pack) * max(ex.compute_time(f),
                                              ex.memory_time(bw / pack))
    assert t_best <= t_base * (1 + 1e-9)


# ---------------------------------------------------------------------------
# Attention joint search
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sysm", [SNAKE, MAC, FIXED],
                         ids=["snake", "mac", "sa48"])
@pytest.mark.parametrize("count,m,ctx", [(1, 128, 8704), (8, 8, 32768),
                                         (64, 8, 8704), (512, 1, 2048)])
def test_attention_conserves_work(sysm, count, m, ctx):
    """The (head-split, ctx-split, pack) search rescales the unit GEMMs but
    total MACs must be conserved and time positive/finite."""
    dh = 128
    qk = Gemm("qk", m, ctx, dh, count=count,
              weight_reuse_across_count=False)
    av = Gemm("av", m, dh, ctx, count=count,
              weight_reuse_across_count=False)
    macs0 = qk.macs + av.macs
    ex = schedule_attention(sysm, qk, av)
    assert np.isfinite(ex.time_s) and ex.time_s > 0
    # conserved within the padding introduced by ceil-div subdivision
    assert ex.op.macs + 0 >= 0
    assert ex.energy.mac_j == pytest.approx(
        macs0 * sysm.e_mac_pj * 1e-12, rel=0.35)


def test_snake_attention_beats_mactree_large_mla():
    """MLA-style attention (count=1, m=128) must engage SNAKE's whole die
    (head-split + slice packing) and beat the MAC tree."""
    qk = Gemm("qk", 128, 8704, 576, count=1,
              weight_reuse_across_count=False)
    av = Gemm("av", 128, 512, 8704, count=1,
              weight_reuse_across_count=False)
    t_snake = schedule_attention(SNAKE, qk, av).time_s
    t_mac = schedule_attention(MAC, qk, av).time_s
    assert t_snake < t_mac


# ---------------------------------------------------------------------------
# TP operator sharding
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("model", list(PAPER_MODELS))
@pytest.mark.parametrize("tp", [1, 8])
def test_tp_conserves_total_macs(model, tp):
    """Megatron splitting divides work across devices: per-device MACs x tp
    must cover the unsharded MACs (within ceil-div padding)."""
    spec = PAPER_MODELS[model]
    lo1 = layer_ops(spec, 16, 8704)
    lop = layer_ops_tp(spec, 16, 8704, tp)
    for g1, gp in zip(lo1.projections, lop.projections):
        assert gp.macs * tp >= g1.macs * 0.999, g1.name
        assert gp.macs <= g1.macs, g1.name
    for g1, gp in zip(lo1.attention, lop.attention):
        assert gp.macs * tp >= g1.macs * 0.98, g1.name


def test_tp1_is_identity():
    spec = PAPER_MODELS["LLaMA3-70B"]
    assert layer_ops(spec, 8, 1024) == layer_ops_tp(spec, 8, 1024, 1)


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["yi-6b", "dbrx-132b", "whisper-small"])
def test_param_specs_rank_and_divisibility(arch):
    """Every spec entry must name existing mesh axes, fit the leaf rank,
    and only shard divisible dims."""
    from repro.distributed.sharding import fsdp_pspecs, param_pspecs
    from repro.launch.mesh import make_mesh
    from repro.models import registry
    entry = registry.get(arch, reduced=True)
    mesh = make_mesh((1, 1), ("data", "model"))
    params = jax.eval_shape(
        lambda: entry.module.init(jax.random.PRNGKey(0), entry.config, 1))
    for specs in (param_pspecs(params, mesh),
                  fsdp_pspecs(param_pspecs(params, mesh), params, mesh)):
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(
            specs, is_leaf=lambda x: hasattr(x, "_normalized_spec"))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            assert len(spec) <= len(leaf.shape)
            for d, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = int(np.prod([mesh.shape[a] for a in axes]))
                assert leaf.shape[d] % size == 0


def test_moe_chunking_matches_unchunked_semantics():
    """apply_moe with nx=1 (no mesh) must be deterministic and finite, and
    per-chunk capacity must cover uniform routing without drops."""
    import jax.numpy as jnp
    from repro.models import layers as L
    from repro.models import registry
    entry = registry.get("dbrx-132b", reduced=True)
    cfg = entry.config
    key = jax.random.PRNGKey(0)
    p = L.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (64, cfg.d_model),
                          jnp.float32)
    y1 = L.apply_moe(p, x, cfg)
    y2 = L.apply_moe(p, x, cfg)
    assert np.all(np.isfinite(np.asarray(y1)))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
