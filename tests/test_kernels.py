"""Pallas kernel validation: interpret-mode allclose vs ref.py oracles over a
shape x dtype sweep, including ragged/padded edges."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode
from repro.kernels.snake_gemm import choose_mapping, snake_decode_gemm
from repro.kernels.wkv6 import wkv6

GEMM_SHAPES = [
    (1, 128, 128), (8, 512, 256), (8, 2048, 8192), (13, 257, 129),
    (16, 4096, 512), (32, 300, 5000), (64, 1024, 2048), (100, 640, 384),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    # f32 tolerance allows blocked-K reassociation at K up to 16k
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=5e-3, atol=1e-3)


@pytest.mark.parametrize("m,n,k", GEMM_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_snake_gemm_matches_oracle(m, n, k, dtype):
    key = jax.random.PRNGKey(m + n + k)
    a = jax.random.normal(key, (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n),
                          jnp.float32).astype(dtype)
    out = snake_decode_gemm(a, b, interpret=True)
    want = ref.decode_gemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_mapping_follows_paper_rule():
    """IS when N > K and resident A feasible; OS when K >= N (paper §3.1)."""
    assert choose_mapping(8, 28672, 8192, jnp.float32).dataflow == "IS"
    assert choose_mapping(8, 8192, 28672, jnp.float32).dataflow == "OS"
    # M padded to sublane granularity only (SNAKE granularity analogue)
    assert choose_mapping(3, 1024, 1024, jnp.float32).block_m == 8
    assert choose_mapping(3, 1024, 1024, jnp.bfloat16).block_m == 16


def test_mapping_blocks_fit_vmem():
    from repro.kernels.snake_gemm import VMEM_BUDGET
    for (m, n, k) in GEMM_SHAPES:
        for dt in DTYPES:
            mp = choose_mapping(m, n, k, dt)
            es = jnp.dtype(dt).itemsize
            if mp.dataflow == "IS":
                used = (mp.block_m * k + k * mp.block_n
                        + mp.block_m * mp.block_n) * es
            else:
                used = (mp.block_m * mp.block_k
                        + mp.block_k * mp.block_n) * es \
                    + mp.block_m * mp.block_n * 4
            assert used <= VMEM_BUDGET, (m, n, k, dt, mp)


FD_SHAPES = [
    (2, 8, 2, 64, 512), (1, 32, 4, 128, 2048), (3, 12, 12, 64, 600),
    (2, 16, 1, 256, 300), (1, 128, 128, 64, 256),
]


@pytest.mark.parametrize("b,hq,hkv,d,s", FD_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_decode_matches_oracle(b, hq, hkv, d, s, dtype):
    key = jax.random.PRNGKey(b * hq + s)
    q = jax.random.normal(key, (b, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d),
                          jnp.float32).astype(dtype)
    lengths = jnp.asarray([max(1, s - 13 * i) for i in range(b)], jnp.int32)
    out = flash_decode(q, k, v, lengths, block_s=256, interpret=True)
    want = ref.flash_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_decode_ragged_lengths():
    """Every request attends to exactly its own prefix."""
    b, hq, hkv, d, s = 4, 4, 2, 64, 256
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, hq, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    lengths = jnp.asarray([1, 17, 128, 256], jnp.int32)
    out = flash_decode(q, k, v, lengths, block_s=128, interpret=True)
    want = ref.flash_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # corrupting KV beyond the valid prefix must not change the output
    k2 = k.at[:, 200:].set(99.0)
    out2 = flash_decode(q, k2, v, jnp.asarray([1, 17, 128, 200], jnp.int32),
                        block_s=128, interpret=True)
    want2 = ref.flash_decode_ref(q, k2, v,
                                 jnp.asarray([1, 17, 128, 200], jnp.int32))
    np.testing.assert_allclose(np.asarray(out2), np.asarray(want2),
                               rtol=2e-5, atol=2e-5)


WKV_SHAPES = [(1, 16, 2, 32), (2, 33, 4, 64), (1, 8, 1, 128)]


@pytest.mark.parametrize("b,t,h,hs", WKV_SHAPES)
def test_wkv6_matches_oracle(b, t, h, hs):
    key = jax.random.PRNGKey(t * h)
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (b, t, h, hs), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, h, hs), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, h, hs), jnp.float32)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, hs))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (h, hs), jnp.float32) * 0.1
    s0 = jax.random.normal(ks[5], (b, h, hs, hs), jnp.float32) * 0.1
    y, sT = wkv6(r, k, v, w, u, s0, interpret=True)
    yw, sw = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sw),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_state_carry_composes():
    """wkv6(T1+T2) == wkv6(T2) . wkv6(T1) — chunked serving correctness."""
    b, t, h, hs = 1, 24, 2, 32
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 6)
    mk = lambda i: jax.random.normal(ks[i], (b, t, h, hs), jnp.float32)
    r, k, v = mk(0), mk(1), mk(2)
    w = jax.nn.sigmoid(mk(3)) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (h, hs)) * 0.1
    s0 = jnp.zeros((b, h, hs, hs))
    y_full, s_full = wkv6(r, k, v, w, u, s0, interpret=True)
    t1 = 10
    y1, s1 = wkv6(r[:, :t1], k[:, :t1], v[:, :t1], w[:, :t1], u, s0,
                  interpret=True)
    y2, s2 = wkv6(r[:, t1:], k[:, t1:], v[:, t1:], w[:, t1:], u, s1,
                  interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)
