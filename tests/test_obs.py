"""Observability subsystem tests (PR 9).

The contract under test: tracing must be *free* when off and *lossless*
when on.  Tokens and finish reasons are bit-identical with the tracer
attached or not (engine and analytic sims); the Perfetto export
round-trips through ``json`` with monotone per-track timestamps; a
replayed JSONL log reproduces ``trace_report`` exactly; and the metrics
registry reports numbers identical to the legacy ad-hoc numpy math it
replaced (it retains exact samples alongside the bucket counts).
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core.hw import snake_system
from repro.core.operators import PAPER_MODELS
from repro.core.serving_sim import (nmp_latency_model, simulate_cluster,
                                    simulate_serving)
from repro.models import registry
from repro.obs import (EVENT_KINDS, NULL_TRACER, Histogram, MetricsRegistry,
                       TraceEvent, Tracer, export_perfetto, load_jsonl,
                       pctl, save_jsonl, serving_registry, trace_report)


# ---------------------------------------------------------------------------
# metrics registry: the shared percentile helper + exact-sample histograms
# ---------------------------------------------------------------------------
def test_pctl_matches_numpy_and_handles_empty():
    xs = [0.3, 0.1, 4.0, 2.2, 0.9]
    for q in (50, 90, 99):
        assert pctl(xs, q) == float(np.percentile(xs, q))
    assert pctl([], 99) == 0.0


def test_histogram_buckets_and_exact_stats():
    h = Histogram("lat", buckets=[0.01, 0.1, 1.0])
    samples = [0.005, 0.01, 0.05, 0.5, 2.0, 7.0]
    for v in samples:
        h.observe(v)
    s = h.summary()
    # le semantics: 0.01 lands in the first bucket, overflow catches >1.0
    assert s["buckets"] == {"le_0.01": 2, "le_0.1": 1, "le_1": 1, "inf": 2}
    assert s["count"] == len(samples)
    # stats come from the retained exact samples, not the buckets
    assert h.mean == float(np.mean(samples))
    assert h.quantile(99) == float(np.percentile(samples, 99))
    with pytest.raises(ValueError):
        Histogram("empty", buckets=[])


def test_registry_get_or_create_and_summaries():
    reg = MetricsRegistry()
    assert reg.counter("reqs") is reg.counter("reqs")
    reg.counter("reqs").inc(3)
    reg.gauge("free_pages").set(7.0)
    h = reg.observe_all("ttft_s", [0.1, 0.2])   # default buckets by name
    assert h is reg.histogram("ttft_s") and h.count == 2
    with pytest.raises(ValueError):
        reg.histogram("no_default_buckets_for_this")
    s = reg.summaries()
    assert s["counters"] == {"reqs": 3}
    assert s["gauges"] == {"free_pages": 7.0}
    assert set(s["histograms"]) == {"ttft_s"}
    # the serving registry pre-declares every serving instrument
    assert set(serving_registry().histograms) == {
        "ttft_s", "tpot_s", "gather_cost_s", "fused_horizon", "e2e_s"}


# ---------------------------------------------------------------------------
# tracer core: no-op default, typed kinds, lazy wall-clock origin
# ---------------------------------------------------------------------------
def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.emit("finish", rid=1, reason="eos") is None
    assert NULL_TRACER.events == []
    assert NULL_TRACER.for_replica(3) is NULL_TRACER


def test_tracer_rejects_unknown_kind_and_anchors_origin():
    tr = Tracer()
    with pytest.raises(ValueError):
        tr.emit("made_up_kind")
    tr.emit("arrival", rid=0, arrival_s=0.5)      # wall ts, lazy t0
    tr.emit("finish", rid=0, reason="budget")
    assert [e.kind for e in tr.events] == ["arrival", "finish"]
    assert tr.events[0].ts == 0.0                 # origin = first event
    assert tr.events[1].ts >= 0.0
    # modeled-clock tracers pass ts explicitly against t0=0
    tm = Tracer(t0=0.0)
    tm.emit("decode_step", ts=1.25, dur=0.5, batch=4)
    assert tm.events[0].ts == 1.25 and tm.events[0].dur == 0.5


def test_bound_tracer_stamps_replica():
    tr = Tracer(t0=0.0)
    tr.for_replica(2).emit("dispatch", ts=0.0, rid=7, policy="round_robin")
    tr.emit("dispatch", ts=0.1, rid=8, policy="round_robin")
    assert [e.replica for e in tr.events] == [2, 0]
    assert all(e.kind in EVENT_KINDS for e in tr.events)


# ---------------------------------------------------------------------------
# sims: tracing must not perturb the report; spans partition the makespan
# ---------------------------------------------------------------------------
def _sim(tracer=None):
    lat = nmp_latency_model(snake_system(), PAPER_MODELS["LLaMA3-70B"],
                            tp=8)
    return simulate_serving(lat, PAPER_MODELS["LLaMA3-70B"], 0.5,
                            system="SNAKE", n_requests=8, input_len=256,
                            output_len=48, max_batch=4,
                            cache_mode="paged", page_size=16,
                            prefill_on_device=True, prefill_chunk=64,
                            fuse_steps=8, tracer=tracer)


def test_sim_report_identical_with_and_without_tracer():
    r0 = _sim(tracer=None)
    tr = Tracer(t0=0.0)
    r1 = _sim(tracer=tr)
    assert dataclasses.asdict(r0) == dataclasses.asdict(r1)
    kinds = {e.kind for e in tr.events}
    assert {"arrival", "admit", "prefill_chunk", "fused_tick",
            "finish"} <= kinds


def test_sim_phases_sum_to_makespan():
    tr = Tracer(t0=0.0)
    _sim(tracer=tr)
    rep = trace_report(tr.events)
    assert rep["finished"] == 8
    total = sum(rep["phases"].values())
    assert abs(total - rep["makespan_s"]) <= 1e-9 * max(1.0, total)


def test_cluster_sim_traced_and_unperturbed():
    lat = nmp_latency_model(snake_system(), PAPER_MODELS["LLaMA3-70B"],
                            tp=8)
    kw = dict(policy="round_robin", n_replicas=2, n_requests=8,
              input_len=256, output_len=32, max_batch=4,
              prefix_sharing=True, shared_prefix_len=128, n_groups=2)
    r0 = simulate_cluster(lat, PAPER_MODELS["LLaMA3-70B"], 0.5, **kw)
    tr = Tracer(t0=0.0)
    r1 = simulate_cluster(lat, PAPER_MODELS["LLaMA3-70B"], 0.5,
                          tracer=tr, **kw)
    assert dataclasses.asdict(r0) == dataclasses.asdict(r1)
    dispatches = [e for e in tr.events if e.kind == "dispatch"]
    assert len(dispatches) == 8
    assert {e.replica for e in tr.events} == {0, 1}


# ---------------------------------------------------------------------------
# exporters: Perfetto JSON round-trip, lossless JSONL replay
# ---------------------------------------------------------------------------
def test_perfetto_roundtrip_monotone_tracks(tmp_path):
    tr = Tracer(t0=0.0)
    _sim(tracer=tr)
    path = tmp_path / "trace.json"
    obj = export_perfetto(tr.events, str(path))
    # the written file and the returned object are the same JSON document
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(obj))
    evs = loaded["traceEvents"]
    assert any(e["ph"] == "X" for e in evs)
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)
    last = {}
    for e in evs:
        if e["ph"] not in ("X", "C"):
            continue
        key = (e["pid"], e["tid"])
        assert e["ts"] >= last.get(key, float("-inf")), \
            f"track {key} timestamps regressed"
        last[key] = e["ts"]


def test_jsonl_replay_reproduces_trace_report(tmp_path):
    tr = Tracer(t0=0.0)
    _sim(tracer=tr)
    path = tmp_path / "trace.jsonl"
    save_jsonl(tr.events, str(path))
    replayed = load_jsonl(str(path))
    assert replayed == tr.events                  # lossless, field-exact
    assert trace_report(replayed) == trace_report(tr.events)
    assert all(isinstance(e, TraceEvent) for e in replayed)


# ---------------------------------------------------------------------------
# live engine: tokens + finish reasons bit-identical, tracer on or off
# ---------------------------------------------------------------------------
def _engine_run(tracer=None):
    from repro.serving.engine import (EngineConfig, make_engine,
                                      make_shared_prefix_trace)
    entry = registry.get("yi-6b", reduced=True)
    ecfg = EngineConfig(max_batch=3, max_seq=64, max_new_tokens=4,
                        paged=True, page_size=8, prefix_sharing=True,
                        prefill_chunk=4, fuse_steps=4)
    eng = make_engine(entry, ecfg)
    if tracer is not None:
        eng.set_tracer(tracer, replica=0)
    reqs = make_shared_prefix_trace(entry.config.vocab, rate_req_s=500.0,
                                    n_requests=4, prefix_len=16,
                                    tail_len=5, seed=2)
    m = eng.run_trace(reqs)
    toks = {r.rid: list(r.tokens_out) for r in eng.completed}
    reasons = {r.rid: r.finish_reason for r in eng.completed}
    return m, toks, reasons


def test_engine_tokens_bit_identical_tracer_on_off():
    _, base_t, base_r = _engine_run(tracer=None)
    tr = Tracer()
    m, toks, reasons = _engine_run(tracer=tr)
    assert toks == base_t and reasons == base_r
    kinds = {e.kind for e in tr.events}
    assert {"arrival", "admit", "prefill_chunk", "fused_tick",
            "finish", "gauge"} <= kinds
    # the registry's bucketed summaries ride along in the metrics dict
    assert m["hists"]["fused_horizon"]["count"] == m["fused_ticks"]
    rep = trace_report(tr.events)
    assert rep["finished"] == len(base_t)
    total = sum(rep["phases"].values())
    assert abs(total - rep["makespan_s"]) <= 1e-9 * max(1.0, total)
