"""Unit tests for the single-core systolic / MAC-tree execution models."""
import pytest

from repro.core import (BufferConfig, Dataflow, Gemm, best_logical_shape,
                        fixed_sa_system, mactree_gemm, mactree_system,
                        sa_gemm, sa_gemm_auto, snake_system)
from repro.core.hw import FP16_BYTES

SNAKE_SA = snake_system().substrate
BIG = BufferConfig(weight=1 << 30, act=1 << 30, out=1 << 30)
TINY = BufferConfig(weight=4096, act=4096, out=4096)


# ---------------------------------------------------------------------------
# Cycle counts pinned to hand calculations
# ---------------------------------------------------------------------------
def test_os_cycles_exact_single_tile():
    # 8x512 logical array, M=8, N=512 -> one tile, K temporal.
    e = sa_gemm(Gemm("g", 8, 512, 1000), 8, 512, Dataflow.OS, BIG)
    assert e.spatial_tiles == 1
    assert e.array_cycles == 1000 + 8 + 512 - 2
    assert e.fill_drain_cycles == 518


def test_os_cycles_tiled():
    e = sa_gemm(Gemm("g", 16, 1024, 100), 8, 512, Dataflow.OS, BIG)
    # Tm=2, Tn=2 -> 4 tiles of (K + fill)
    assert e.spatial_tiles == 4
    assert e.array_cycles == 4 * (100 + 518)


def test_is_cycles_exact():
    # IS: M->rows, K->cols, N temporal.
    e = sa_gemm(Gemm("g", 8, 1000, 512), 8, 512, Dataflow.IS, BIG)
    assert e.spatial_tiles == 1
    assert e.array_cycles == 1000 + 518


def test_is_vs_os_tile_fold_rule():
    """Paper §3.1: IS preferred when N > K, OS when K >= N (fewer folds)."""
    sa = SNAKE_SA
    g_ngk = Gemm("up", 8, 28672, 8192)    # N > K -> IS
    g_kgn = Gemm("down", 8, 8192, 28672)  # K > N -> OS
    assert sa_gemm_auto(g_ngk, sa).dataflow == Dataflow.IS
    assert sa_gemm_auto(g_kgn, sa).dataflow == Dataflow.OS


def test_compulsory_traffic_lower_bound():
    g = Gemm("g", 8, 4096, 4096)
    for df in Dataflow:
        e = sa_gemm(g, 8, 512, df, BIG)
        assert e.dram_bytes >= g.min_dram_bytes


def test_big_buffers_reach_compulsory_traffic():
    g = Gemm("g", 8, 4096, 4096)
    e = sa_gemm(g, 8, 512, Dataflow.OS, BIG)
    assert e.dram_bytes == g.min_dram_bytes


def test_small_buffers_cause_rereads():
    g = Gemm("g", 64, 8192, 8192)
    big = sa_gemm(g, 8, 512, Dataflow.OS, BIG)
    small = sa_gemm(g, 8, 512, Dataflow.OS, TINY)
    assert small.dram_bytes > big.dram_bytes


def test_mfold_weight_restream():
    """Elongated fixed arrays re-stream weights once per M-fold (the
    mechanism that sinks the 8x288 baseline at large batch)."""
    g = Gemm("g", 64, 4096, 8192)
    e = sa_gemm(g, 8, 288, Dataflow.OS, TINY)
    tm = -(-64 // 8)
    assert e.dram_bytes >= tm * g.b_bytes_once


# ---------------------------------------------------------------------------
# SNAKE serpentine logical remapping (paper §4.2.2)
# ---------------------------------------------------------------------------
def test_logical_shapes_preserve_pe_count():
    for r, c in SNAKE_SA.logical_shapes():
        assert r * c == 64 * 64


@pytest.mark.parametrize("m,expect", [(1, (8, 512)), (8, (8, 512)),
                                      (9, (16, 256)), (16, (16, 256)),
                                      (17, (32, 128)), (32, (32, 128)),
                                      (33, (64, 64)), (64, (64, 64)),
                                      (100, (64, 64))])
def test_shape_selection(m, expect):
    assert best_logical_shape(SNAKE_SA, m) == expect


def test_reconfig_beats_fixed_square_on_small_m():
    """M=8 on the reshaped 8x512 must beat the same PEs as fixed 64x64."""
    g = Gemm("g", 8, 8192, 4096)
    elong = sa_gemm(g, 8, 512, Dataflow.IS, BIG)
    square = sa_gemm(g, 64, 64, Dataflow.IS, BIG)
    assert elong.array_cycles < square.array_cycles
    assert elong.util > square.util


def test_util_bounds():
    for m in (1, 8, 13, 64, 200):
        g = Gemm("g", m, 2048, 2048)
        for df in Dataflow:
            e = sa_gemm(g, *best_logical_shape(SNAKE_SA, m), df, BIG)
            assert 0.0 < e.util <= 1.0


# ---------------------------------------------------------------------------
# MAC tree
# ---------------------------------------------------------------------------
def test_mactree_cycles_exact():
    mt = mactree_system().substrate
    e = mactree_gemm(Gemm("g", 16, 160, 160), mt)
    assert e.array_cycles == 1 * 10 * 10


def test_mactree_m_padding_waste():
    mt = mactree_system().substrate
    full = mactree_gemm(Gemm("g", 16, 1600, 1600), mt)
    half = mactree_gemm(Gemm("g", 8, 1600, 1600), mt)
    assert half.array_cycles == full.array_cycles  # same cycles, half work
    assert abs(half.util - full.util / 2) < 1e-9


def test_mactree_higher_operand_traffic_per_mac():
    """Broadcast delivery: tree fetches more SRAM bytes per MAC than SA."""
    g = Gemm("g", 16, 4096, 4096)
    mt = mactree_system().substrate
    et = mactree_gemm(g, mt)
    es = sa_gemm(g, 16, 256, Dataflow.OS, BIG)
    assert et.sram_bytes / g.macs > es.sram_bytes / g.macs


# ---------------------------------------------------------------------------
# System-level hardware invariants (paper §1 / Fig. 1a)
# ---------------------------------------------------------------------------
def test_ridge_points_match_paper_band():
    assert 3.7 <= mactree_system().ridge_point <= 6.7  # Stratum band
    snake = snake_system()
    assert snake.peak_flops > mactree_system().peak_flops * 3.1
    # SNAKE's ridge sits well above batch-8 decode AI (=8 FLOP/B): batch-8
    # decode is memory-bound on SNAKE, compute-bound on the MAC tree.
    assert snake.ridge_point > 8 > mactree_system().ridge_point


def test_area_efficiency_ratios():
    from repro.core import area_model
    am = area_model()
    assert am["SNAKE"]["compute_area_efficiency"] == pytest.approx(4.00)
    assert am["SA+VectorCore"]["compute_area_efficiency"] == pytest.approx(2.25)


def test_power_budget_matches_paper():
    from repro.core import peak_power_breakdown, snake_system
    pb = peak_power_breakdown(snake_system())
    total = sum(pb.values())
    assert 55.0 < total < 70.0           # paper: 61.8 W
    assert pb["matrix_w"] == pytest.approx(38.5, rel=0.05)
    assert pb["vector_w"] == pytest.approx(14.2, rel=0.05)
