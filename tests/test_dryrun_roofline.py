"""Dry-run + roofline integration: one cell per mesh kind compiles on a
small placeholder-device mesh (subprocess — XLA device count is locked at
first jax init), and the HLO collective parser is pinned on synthetic IR.
"""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(tmp_path, *args):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--no-calibrate",
         "--out", str(tmp_path), *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_cell_compiles(tmp_path, mesh):
    r = _run_dryrun(tmp_path, "--arch", "stablelm-3b",
                    "--shape", "decode_32k", "--mesh", mesh)
    assert "OK" in r.stdout, r.stdout + r.stderr
    recs = []
    for f in os.listdir(tmp_path):
        recs += json.load(open(os.path.join(tmp_path, f)))
    ok = [x for x in recs if x.get("status") == "OK"]
    assert ok and ok[0]["collective_count"] > 0
    assert ok[0]["t_memory_s"] > 0


def test_skip_policy_applied(tmp_path):
    r = _run_dryrun(tmp_path, "--arch", "yi-6b", "--shape", "long_500k")
    assert "SKIP(full-attention" in r.stdout


def test_parse_collectives_ring_math():
    from repro.analysis.roofline import parse_collectives
    hlo = "\n".join([
        "%ar = f32[128,256]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256]",
        "%ag = bf16[64,64]{1,0} all-gather(%y), replica_groups=[2,8]<=[16]",
        "%rs = f32[32]{0} reduce-scatter(%z), replica_groups=[4,4]<=[16]",
        "%cp = bf16[8,8]{1,0} collective-permute(%w)",
    ])
    prof = parse_collectives(hlo, 256)
    ar_bytes = 128 * 256 * 4
    ag_bytes = 64 * 64 * 2
    rs_bytes = 32 * 4
    want = (int(2 * 15 / 16 * ar_bytes) + int(7 / 8 * ag_bytes)
            + int(3 * rs_bytes) + 8 * 8 * 2)
    assert prof.count == 4
    assert prof.wire_bytes == want


def test_analytic_corrections_families():
    from repro.analysis.roofline import analytic_corrections
    from repro.models import registry
    from repro.models.config import SHAPES
    # dense train: attention + CE corrections are positive
    cfg = registry.get_config("yi-6b")
    c = analytic_corrections(cfg, SHAPES["train_4k"], 16, 256)
    assert c["flops"] > 0 and c["bytes"] > 0
    # decode: no correction (einsum attention is fully counted)
    c = analytic_corrections(cfg, SHAPES["decode_32k"], 16, 256)
    assert c["flops"] == 0 and c["bytes"] == 0
    # ssm: no attention loops -> prefill correction is zero flops
    cfg = registry.get_config("rwkv6-7b")
    c = analytic_corrections(cfg, SHAPES["prefill_32k"], 16, 256)
    assert c["flops"] == 0
