"""Prefill/decode disaggregation tests (PR 10): page-shipment pricing,
cache-level export/import integrity, the Replica protocol, tiered-router
dispatch determinism, sim-mirror shipment-latency accounting, and
engine-level token exactness across the tier handoff (including the
mid-chunked-prefill deferral)."""
import numpy as np
import pytest

from repro.core.hw import snake_system
from repro.core.noc import page_gather, page_ship
from repro.core.serving_sim import nmp_latency_model, simulate_cluster
from repro.models import registry
from repro.obs.export import trace_report
from repro.obs.tracer import Tracer
from repro.serving.engine import EngineConfig, make_engine
from repro.serving.replica_api import (LoadReport, PlacementReport,
                                       Replica)
from repro.serving.router import Router, make_cluster
from repro.serving.scheduler import (RequestState,
                                     make_grouped_prefix_trace)

from tests.test_serving_router import _StubReplica


# ---------------------------------------------------------------------------
# Pricing: the cross-stack link term on top of the intra-stack gather
# ---------------------------------------------------------------------------
def test_page_ship_hops0_is_page_gather():
    sys = snake_system()
    payload, segments = 1 << 20, 16
    ship = page_ship(sys, payload, segments, hops=0)
    gather = page_gather(sys, 0, payload, segments)
    assert ship == gather


def test_page_ship_link_terms_monotonic():
    sys = snake_system()
    payload, segments = 1 << 20, 16
    costs = [page_ship(sys, payload, segments, hops=h) for h in range(3)]
    assert costs[0].time_s < costs[1].time_s < costs[2].time_s
    # each extra hop adds exactly one link-latency crossing
    d1 = costs[1].time_s - costs[0].time_s
    d2 = costs[2].time_s - costs[1].time_s
    assert d2 == pytest.approx(sys.xlink_latency_s)
    assert d1 == pytest.approx(d2 + payload / sys.xlink_bw_bytes
                               + payload / sys.dram_bw_per_pu)


def test_page_ship_negative_hops_rejected():
    with pytest.raises(ValueError):
        page_ship(snake_system(), 1024, 1, hops=-1)


# ---------------------------------------------------------------------------
# Cache-level shipment integrity (the checker's ship op, run clean)
# ---------------------------------------------------------------------------
def test_ship_integrity_checker_clean_on_real_cache():
    from repro.analysis.checks import allocator_model
    assert allocator_model.check_ship_integrity() == []


def test_trie_dropping_import_is_flagged():
    from repro.analysis.checks import allocator_model
    from repro.analysis.checks.fixtures import pr10_ship_trie_drop as fx
    findings = allocator_model.check_ship_integrity(
        cache_cls=fx.TrieDroppingCache)
    assert findings and findings[0].invariant == "ship-integrity"


# ---------------------------------------------------------------------------
# Replica protocol: every implementation satisfies the runtime contract
# ---------------------------------------------------------------------------
def test_replica_protocol_typed_reports():
    rep = LoadReport(active=1, prefilling=0, queue_depth=2, free_slots=3,
                     free_pages=10, min_region_free=4,
                     region_free=(4, 6))
    d = rep.to_dict()
    assert d["free_pages"] == 10 and d["region_free"] == [4, 6]
    bare = LoadReport(active=0, prefilling=0, queue_depth=0,
                      free_slots=1, free_pages=1, min_region_free=1)
    assert "region_free" not in bare.to_dict()
    assert PlacementReport().empty
    assert PlacementReport().to_dict() == {}


def test_stub_and_sim_replicas_satisfy_protocol():
    assert isinstance(_StubReplica(), Replica)
    from repro.core.operators import PAPER_MODELS
    spec = PAPER_MODELS["LLaMA3-70B"]
    lat = nmp_latency_model(snake_system(), spec, tp=8)
    rep = simulate_cluster(lat, spec, 50.0, n_replicas=2, n_requests=4,
                           input_len=256, output_len=16, max_batch=4,
                           page_size=64, tiers=(1, 1))
    assert rep.tiers == "1:1" and rep.shipments == 4


def test_sim_replica_isinstance():
    from repro.core.operators import PAPER_MODELS
    from repro.core.serving_sim import _Replica
    spec = PAPER_MODELS["LLaMA3-70B"]
    lat = nmp_latency_model(snake_system(), spec, tp=8)
    r = _Replica(lat, spec, max_batch=4, pages_cap=32, page_size=64,
                 shared_full=0)
    assert isinstance(r, Replica)


# ---------------------------------------------------------------------------
# Tiered router: validation + dispatch determinism on stub replicas
# ---------------------------------------------------------------------------
def _req(rid):
    return RequestState(rid, np.arange(rid, rid + 8, dtype=np.int32))


def test_tier_validation():
    with pytest.raises(ValueError):
        Router([_StubReplica() for _ in range(3)], tiers=(0, 3))
    with pytest.raises(ValueError):
        Router([_StubReplica() for _ in range(3)], tiers=(2, 2))
    with pytest.raises(ValueError):
        make_cluster(registry.get("yi-6b", reduced=True),
                     EngineConfig(max_batch=2, max_seq=32, paged=False),
                     2, tiers=(1, 1))


def test_tiered_dispatch_targets_prefill_tier_only():
    stubs = [_StubReplica(queue_depth=q) for q in (2, 0, 1, 0)]
    router = Router(stubs, policy="round_robin", tiers=(2, 2))
    assert [e.role for e in stubs] == ["prefill", "prefill",
                                       "decode", "decode"]
    # arrivals go to the least-loaded PREFILL replica, never to decode
    picks = [router.dispatch(_req(i)) for i in range(4)]
    assert set(picks) <= {0, 1}
    # identical stub state must reproduce the identical pick sequence
    stubs2 = [_StubReplica(queue_depth=q) for q in (2, 0, 1, 0)]
    router2 = Router(stubs2, policy="round_robin", tiers=(2, 2))
    assert [router2.dispatch(_req(i)) for i in range(4)] == picks


# ---------------------------------------------------------------------------
# Sim mirror: shipment latency on the modeled clock + trace accounting
# ---------------------------------------------------------------------------
def _sim(tiers=None, tracer=None, n_requests=12, **kw):
    from repro.core.operators import PAPER_MODELS
    spec = PAPER_MODELS["LLaMA3-70B"]
    sys = snake_system()
    lat = nmp_latency_model(sys, spec, tp=8)
    return simulate_cluster(lat, spec, 50.0, n_replicas=4,
                            n_requests=n_requests, input_len=512,
                            output_len=32, max_batch=4, page_size=64,
                            seed=0, tiers=tiers, tracer=tracer,
                            sys=sys, **kw)


def test_sim_ships_every_request_and_prices_the_link():
    rep = _sim(tiers=(1, 3))
    assert rep.tiers == "1:3"
    assert rep.shipments == rep.completed == 12
    assert rep.shipped_pages == 12 * (512 // 64)
    assert rep.ship_cost_s > 0.0
    colo = _sim()
    assert colo.tiers == "" and colo.shipments == 0
    # the link time is visible end-to-end: shipped requests cannot
    # finish before their colocated counterparts on an idle cluster
    assert rep.e2e_p50_s >= colo.e2e_p50_s


def test_sim_ship_spans_match_report_accounting():
    tr = Tracer(t0=0.0)
    rep = _sim(tiers=(2, 2), tracer=tr)
    ships = [ev for ev in tr.events if ev.kind == "ship"]
    assert len(ships) == rep.shipments
    assert sum(ev.dur for ev in ships) == pytest.approx(rep.ship_cost_s)
    report = trace_report(tr.events)
    assert report["phases"]["ship_s"] == pytest.approx(rep.ship_cost_s)
    for ev in ships:
        assert ev.args["src"] in (0, 1) and ev.args["dst"] in (2, 3)


def test_sim_tier_ratio_ordering_decode_heavy_wins():
    reps = {t: _sim(tiers=t, n_requests=16) for t in ((1, 3), (3, 1))}
    assert reps[(1, 3)].tbt_mean_s < reps[(3, 1)].tbt_mean_s


# ---------------------------------------------------------------------------
# Engine handoff: bit-identical tokens, deferral mid chunked prefill
# ---------------------------------------------------------------------------
ENG_KW = dict(max_batch=3, max_seq=64, max_new_tokens=6, paged=True,
              page_size=8, num_pages=24, prefix_sharing=True,
              prefill_chunk=8)


def _grouped_trace(entry, n=8, seed=0):
    return make_grouped_prefix_trace(entry.config.vocab, rate_req_s=200.0,
                                     n_requests=n, n_groups=2,
                                     prefix_len=16, tail_len=6, skew=0.8,
                                     seed=seed)


@pytest.mark.slow
def test_disagg_cluster_token_exact_vs_colocated():
    """A 1P:1D tiered cluster must decode the exact tokens of the bare
    engine on a shared-prefix trace — the handoff ships KV pages, the
    trie dedup on the decode tier, and greedy decode is
    schedule-independent."""
    entry = registry.get("yi-6b", reduced=True)
    eng = make_engine(entry, EngineConfig(**ENG_KW))
    eng.run_trace(_grouped_trace(entry))
    base = {r.rid: r.tokens_out for r in eng.completed}
    router = make_cluster(entry, EngineConfig(**ENG_KW), 2, tiers=(1, 1))
    m = router.run_trace(_grouped_trace(entry))
    got = {r.rid: r.tokens_out
           for e in router.engines for r in e.completed}
    assert got == base
    assert m["tiers"] == "1:1"
    assert m["shipments"] == len(base)
    assert m["shipped_pages"] > 0 and m["ship_cost_s"] > 0.0
    # handoffs are logged (rid, src, dst) with src/dst in tier order
    assert len(router.ship_log) == len(base)
    assert all(src == 0 and dst == 1
               for _, src, dst in router.ship_log)
    # prefill-tier engine completed nothing; decode tier everything
    assert not router.engines[0].completed
    assert len(router.engines[1].completed) == len(base)


@pytest.mark.slow
def test_export_deferred_mid_chunked_prefill():
    """A request still mid chunked-prefill exports as None (deferred);
    once the chunk scheduler finishes, the shipment carries the whole
    prompt and the first decoded token, and the destination engine
    continues to the exact colocated completion."""
    entry = registry.get("yi-6b", reduced=True)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, entry.config.vocab, 24).astype(np.int32)

    ref = make_engine(entry, EngineConfig(**ENG_KW))
    assert ref.admit(RequestState(0, prompt.copy()))
    while ref.busy():
        ref.tick()
    want = ref.completed[0].tokens_out

    src = make_engine(entry, EngineConfig(**ENG_KW))
    dst = make_engine(entry, EngineConfig(**ENG_KW))
    src.role, dst.role = "prefill", "decode"
    req = RequestState(0, prompt.copy())
    assert src.admit(req)
    assert src._prefilling is not None, "24 tokens must chunk at 8"
    assert src.export_slot_pages(0) is None   # deferred: mid prefill
    while src._prefilling is not None:
        src.tick()
    ship = src.export_slot_pages(0)
    assert ship is not None and ship.n_tokens == len(prompt)
    assert ship.cost_s > 0.0 and ship.next_tok >= 0
    assert not src.active and not src.busy()
    assert dst.import_slot_pages(ship)
    # source pool fully released; destination holds the prompt pages
    assert src.paged.alloc.used_pages == 0
    assert src.paged.shipped_pages == ship.n_pages
    assert dst.paged.alloc.used_pages >= ship.n_pages
    assert dst.paged.mirror_consistent()
    while dst.busy():
        dst.tick()
    assert dst.completed[0].tokens_out == want
    assert dst.paged.alloc.used_pages == 0
