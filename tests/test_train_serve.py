"""Integration tests: training loop (checkpoint/resume/determinism),
serving engine (continuous batching), gradient compression, microbatching.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.train import train
from repro.models import registry
from repro.optim import adamw as axw
from repro.serving.engine import EngineConfig, ServingEngine


# ---------------------------------------------------------------------------
# Training driver
# ---------------------------------------------------------------------------
def test_train_loss_decreases(tmp_path):
    out = train("yi-6b", steps=14, global_batch=4, seq=64,
                ckpt_dir=str(tmp_path), save_every=6, log_every=100)
    assert out["steps"] == 14
    assert np.isfinite(out["final_loss"])
    assert out["final_loss"] < out["first_loss"]


def test_train_resume_is_exact(tmp_path):
    """Crash/restart must replay to the same loss as an uninterrupted run
    (atomic checkpoints + shard-deterministic data)."""
    a = train("stablelm-3b", steps=10, global_batch=2, seq=32,
              ckpt_dir=None, log_every=100, seed=3)
    train("stablelm-3b", steps=10, stop_step=6, global_batch=2, seq=32,
          ckpt_dir=str(tmp_path), save_every=6, log_every=100, seed=3)
    b = train("stablelm-3b", steps=10, global_batch=2, seq=32,
              ckpt_dir=str(tmp_path), save_every=100, log_every=100, seed=3)
    assert b["final_loss"] == pytest.approx(a["final_loss"], rel=1e-4)


def test_grad_compression_trains(tmp_path):
    out = train("yi-6b", steps=8, global_batch=2, seq=32,
                ckpt_dir=None, compress_grads=True, log_every=100)
    assert np.isfinite(out["final_loss"])
    assert out["final_loss"] < out["first_loss"] + 0.5


def test_microbatched_step_matches_full_batch():
    """Gradient accumulation must give (numerically) the same update."""
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import make_train_step
    entry = registry.get("yi-6b", reduced=True)
    cfg = entry.config
    mesh = make_mesh((1, 1), ("data", "model"))
    ocfg = axw.AdamWConfig()
    params = entry.module.init(jax.random.PRNGKey(0), cfg, 1)
    opt = axw.init(params, ocfg)
    data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                    global_batch=4))
    batch = {k: v for k, v in data.batch_at(0).items() if k != "mask"}
    s1 = jax.jit(make_train_step(entry, ocfg, 1, mesh))
    s2 = jax.jit(make_train_step(entry, ocfg, 1, mesh, microbatch=2))
    _, _, m1 = s1(params, opt, batch)
    _, _, m2 = s2(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    assert float(m1["grad_norm"]) == pytest.approx(float(m2["grad_norm"]),
                                                   rel=1e-3)


def test_data_pipeline_shard_determinism():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    full = TokenPipeline(cfg).batch_at(5)
    shards = [TokenPipeline(cfg, shard=i, num_shards=4).batch_at(5)
              for i in range(4)]
    # shard batches are deterministic and distinct
    assert not np.array_equal(shards[0]["tokens"], shards[1]["tokens"])
    again = TokenPipeline(cfg, shard=2, num_shards=4).batch_at(5)
    np.testing.assert_array_equal(shards[2]["tokens"], again["tokens"])
    del full


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["yi-6b", "rwkv6-7b"])
def test_engine_completes_workload(arch):
    entry = registry.get(arch, reduced=True)
    ecfg = EngineConfig(max_batch=3, max_seq=48, max_new_tokens=6)
    eng = ServingEngine(entry, ecfg)
    m = eng.run_workload(rate_req_s=50.0, n_requests=7, prompt_len=16)
    assert m["requests"] == 7
    assert m["decoded_tokens"] == 7 * 6
    assert m["tokens_per_s"] > 0


def test_engine_continuous_batching_reuses_slots():
    entry = registry.get("yi-6b", reduced=True)
    ecfg = EngineConfig(max_batch=2, max_seq=48, max_new_tokens=4)
    eng = ServingEngine(entry, ecfg)
    m = eng.run_workload(rate_req_s=100.0, n_requests=5, prompt_len=8)
    assert m["requests"] == 5           # 5 requests through 2 slots


def test_engine_matches_offline_decode():
    """Engine tokens == straight prefill+decode_step loop tokens."""
    entry = registry.get("yi-6b", reduced=True)
    cfg = entry.config
    ecfg = EngineConfig(max_batch=2, max_seq=40, max_new_tokens=4)
    eng = ServingEngine(entry, ecfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=(16,)).astype(np.int32)
    from repro.serving.engine import RequestState
    req = RequestState(0, prompt)
    assert eng.submit(req)
    while not req.done:
        eng.step()
    # offline reference
    logits, cache = entry.module.prefill(
        eng.params, cfg, jnp.asarray(prompt[None, :]), tp=1, max_seq=40)
    toks = [int(jnp.argmax(logits[0, : cfg.vocab]))]
    for _ in range(3):
        logits, cache = entry.module.decode_step(
            eng.params, cfg, jnp.asarray([toks[-1]], jnp.int32), cache,
            tp=1)
        toks.append(int(jnp.argmax(logits[0, : cfg.vocab])))
    assert req.tokens_out == toks


def test_chunked_prefill_matches_full():
    """Sarathi-style chunked prefill must reproduce full-prefill logits
    and cache exactly (fp32 reduced config)."""
    entry = registry.get("yi-6b", reduced=True)
    cfg = entry.config
    params = entry.module.init(jax.random.PRNGKey(0), cfg, 1)
    toks = np.random.default_rng(1).integers(
        0, cfg.vocab, (2, 64)).astype(np.int32)
    lf, cf = entry.module.prefill(params, cfg, jnp.asarray(toks), tp=1,
                                  max_seq=96)
    lc, cc = entry.module.prefill(params, cfg, jnp.asarray(toks), tp=1,
                                  max_seq=96, chunk=16)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(lf),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cc.k[:, :, :64]),
                               np.asarray(cf.k[:, :, :64]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(np.asarray(cc.lengths),
                                  np.asarray(cf.lengths))
    # and decode continues identically from either cache
    nxt = jnp.argmax(lf[:, : cfg.vocab], -1).astype(jnp.int32)
    df, _ = entry.module.decode_step(params, cfg, nxt, cf, tp=1)
    dc, _ = entry.module.decode_step(params, cfg, nxt, cc, tp=1)
    np.testing.assert_allclose(np.asarray(dc), np.asarray(df),
                               rtol=2e-4, atol=2e-4)


def test_engine_chunked_prefill_same_tokens():
    """The engine with Sarathi chunked prefill decodes identical tokens."""
    entry = registry.get("yi-6b", reduced=True)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, entry.config.vocab, size=(32,)).astype(np.int32)
    outs = []
    for chunk in (None, 8):
        ecfg = EngineConfig(max_batch=2, max_seq=48, max_new_tokens=4,
                            prefill_chunk=chunk)
        eng = ServingEngine(entry, ecfg)
        from repro.serving.engine import RequestState
        req = RequestState(0, prompt)
        assert eng.submit(req)
        while not req.done:
            eng.step()
        outs.append(req.tokens_out)
    assert outs[0] == outs[1]


def test_train_retries_transient_failures(monkeypatch, tmp_path):
    """Bounded retry: a step that fails transiently must be retried and the
    run must complete (fault-tolerance path)."""
    import repro.launch.train as T
    real_jit = jax.jit
    state = {"fails_left": 2}

    def flaky_jit(fn, **kw):
        compiled = real_jit(fn, **kw)

        def wrapper(*a, **k):
            if state["fails_left"] > 0:
                state["fails_left"] -= 1
                raise RuntimeError("injected transient failure")
            return compiled(*a, **k)

        return wrapper

    monkeypatch.setattr(T.jax, "jit", flaky_jit)
    out = T.train("yi-6b", steps=4, global_batch=2, seq=32,
                  ckpt_dir=None, log_every=100, max_retries=3)
    assert out["steps"] == 4
    assert state["fails_left"] == 0
    assert np.isfinite(out["final_loss"])
