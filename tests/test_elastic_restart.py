"""Elastic restart: a checkpoint written on one mesh restores onto a
DIFFERENT mesh shape (device count fixed by the platform, so both legs run
in subprocesses with 8 placeholder devices and different (data, model)
factorizations).  The on-disk manifest is mesh-independent — this is the
mechanism that lets a 1000-node job resume after losing a rack.
"""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    from repro.launch.train import train
    data, model, steps, stop, ckpt = sys.argv[1:6]
    out = train("yi-6b", steps=int(steps), stop_step=int(stop) or None,
                global_batch=8, seq=32, ckpt_dir=ckpt, save_every=100,
                mesh_shape=(int(data), int(model)), log_every=100, seed=1)
    print("RESULT", json.dumps({"final_loss": out["final_loss"],
                                "steps": out["steps"]}))
""")


def _leg(tmp_path, data, model, steps, stop):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(data), str(model), str(steps),
         str(stop), str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)
    assert "RESULT" in r.stdout, r.stdout + r.stderr
    import json
    return json.loads(r.stdout.split("RESULT", 1)[1].strip())


def test_restart_on_resharded_mesh(tmp_path):
    # leg 1: (data=4, model=2), stop after 4 of 8 scheduled steps
    a = _leg(tmp_path, 4, 2, 8, 4)
    assert a["steps"] == 4
    # leg 2: resume the SAME schedule on a (data=2, model=4) mesh
    b = _leg(tmp_path, 2, 4, 8, 0)
    assert b["steps"] == 4               # resumed at step 4, ran 4 more
    import numpy as np
    assert np.isfinite(b["final_loss"])
