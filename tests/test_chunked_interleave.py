"""Chunked-prefill interleaving tests: the incremental ``extend_step``
matches full prefill, the engine's Sarathi chunk scheduler emits identical
tokens with chunking on/off (dense and paged), and the analytical serving
simulator's co-scheduled chunks keep the decode stall bounded."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hw import snake_system
from repro.core.operators import PAPER_MODELS
from repro.core.serving_sim import nmp_latency_model, simulate_serving
from repro.models import registry
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, make_engine, make_trace

SKEWED_LENS = np.array([9, 17, 5, 30, 12, 24])


def _trace(entry, seed=3):
    return make_trace(entry.config.vocab, rate_req_s=100.0,
                      n_requests=len(SKEWED_LENS), prompt_len=0, seed=seed,
                      prompt_lens=SKEWED_LENS)


# ---------------------------------------------------------------------------
# extend_step unit equivalence
# ---------------------------------------------------------------------------
def test_extend_step_matches_full_prefill():
    """Chunk-by-chunk extension reproduces full-prefill logits and cache,
    including a ragged final chunk."""
    entry = registry.get("yi-6b", reduced=True)
    cfg = entry.config
    params = T.init(jax.random.PRNGKey(0), cfg, 1)
    toks = np.random.default_rng(1).integers(
        0, cfg.vocab, (1, 29)).astype(np.int32)
    lf, cf = T.prefill(params, cfg, jnp.asarray(toks), tp=1, max_seq=48)
    cache = T.KVCache.zeros(cfg, 1, 48, 1)
    pos = 0
    for chunk in (8, 8, 8, 5):         # ragged tail
        lg, cache = T.extend_step(
            params, cfg, jnp.asarray(toks[:, pos: pos + chunk]), cache,
            tp=1)
        pos += chunk
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lf),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(cache.k[:, :, :29]),
                               np.asarray(cf.k[:, :, :29]),
                               rtol=2e-4, atol=2e-4)
    assert int(cache.lengths[0]) == 29
    # decode continues identically from either cache
    nxt = jnp.argmax(lf[:, : cfg.vocab], -1).astype(jnp.int32)
    df, _ = T.decode_step(params, cfg, nxt, cf, tp=1)
    dc, _ = T.decode_step(params, cfg, nxt, cache, tp=1)
    np.testing.assert_allclose(np.asarray(dc), np.asarray(df),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Engine: chunk scheduler token equivalence
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("paged", [False, True])
def test_interleaved_chunk_scheduler_same_tokens(paged):
    """``prefill_chunk`` set vs. unset yields identical tokens through the
    arrival-driven scheduler, dense and paged."""
    entry = registry.get("yi-6b", reduced=True)
    outs = []
    for chunk in (None, 8):
        ecfg = EngineConfig(max_batch=3, max_seq=48, max_new_tokens=5,
                            prefill_chunk=chunk, paged=paged, page_size=8)
        eng = make_engine(entry, ecfg)
        eng.run_trace(_trace(entry))
        outs.append({r.rid: r.tokens_out for r in eng.completed})
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# Simulator: co-scheduled chunks bound the decode stall
# ---------------------------------------------------------------------------
def _sim(**kw):
    spec = PAPER_MODELS["LLaMA3-70B"]
    lat = nmp_latency_model(snake_system(), spec, tp=8)
    return simulate_serving(lat, spec, 0.5, system="SNAKE", n_requests=16,
                            input_len=2048, output_len=128, max_batch=8,
                            **kw)


def test_sim_chunked_prefill_bounds_decode_stall():
    """With on-device prefill, chunking caps the latency a decode iteration
    spends on admitted prefill work at one chunk's worth."""
    full = _sim(prefill_on_device=True)
    chunked = _sim(prefill_on_device=True, prefill_chunk=256)
    assert full.completed == chunked.completed == 16
    assert chunked.max_decode_stall_s < full.max_decode_stall_s
    # stall is bounded by chunk/prompt of the unchunked stall
    assert chunked.max_decode_stall_s \
        <= full.max_decode_stall_s * (256 / 2048) * 1.01
    # and decode between admitted chunks never waits longer than
    # (decode iteration + one chunk)
    assert chunked.tbt_mean_s <= full.tbt_mean_s


def test_sim_paged_occupancy_beats_dense():
    dense = _sim()
    paged = _sim(cache_mode="paged", page_size=64)
    # same latency policy -> identical latency results with a full pool
    assert paged.e2e_mean_s == pytest.approx(dense.e2e_mean_s)
    assert paged.tbt_mean_s == pytest.approx(dense.tbt_mean_s)
    # but resident KV tracks live contexts instead of the reservation
    assert paged.kv_util_mean > 2 * dense.kv_util_mean
    assert paged.kv_peak_tokens < dense.kv_peak_tokens


def test_sim_default_mode_regression():
    """The extended simulator's defaults reproduce the seed policy."""
    rep = _sim()
    assert rep.completed == 16
    assert rep.preemptions == 0
    assert rep.max_decode_stall_s == 0.0
    assert rep.e2e_mean_s > 0 and rep.tbt_mean_s > 0
