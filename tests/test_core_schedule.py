"""Tests for the multi-PU scheduling framework (paper §5) + pipeline."""
import pytest

from repro.core import (DEEPSEEK_236B, LLAMA3_70B, MIXTRAL_8X22B, OPT_66B,
                        QWEN3_30B_A3B, Gemm, Mode, decode_step, decode_ops,
                        gpu_decode_step, layer_ops, mactree_system,
                        mode_candidates, schedule_attention, schedule_chain,
                        schedule_experts, schedule_projection, snake_system)

SNAKE = snake_system()
MACT = mactree_system()


# ---------------------------------------------------------------------------
# Mode search
# ---------------------------------------------------------------------------
def test_search_at_least_as_good_as_every_fixed_mode():
    for g in (Gemm("up", 8, 57344, 8192), Gemm("down", 8, 8192, 28672),
              Gemm("qkv", 32, 10240, 8192), Gemm("head", 64, 128256, 8192)):
        best = schedule_projection(SNAKE, g)
        for cand in mode_candidates(SNAKE, g):
            assert best.time_s <= cand.time_s + 1e-12


def test_four_modes_enumerated():
    cands = mode_candidates(SNAKE, Gemm("g", 8, 8192, 8192))
    assert sorted(c.mode for c in cands) == ["IS-S", "IS-ST", "OS-S", "OS-ST"]


def test_st_overlaps_collective():
    """ST must never expose more comm than its S counterpart."""
    g = Gemm("up", 64, 57344, 8192)
    by_mode = {c.mode: c for c in mode_candidates(SNAKE, g)}
    assert by_mode["IS-ST"].comm_s <= by_mode["IS-S"].comm_s + 1e-12
    assert by_mode["OS-ST"].comm_s <= by_mode["OS-S"].comm_s + 1e-12


def test_chaining_skips_gather():
    """OS-S -> IS-S chain: producer may keep its N shard when the consumer
    splits exactly that dimension as K."""
    up = Gemm("up", 8, 28672, 8192)
    down = Gemm("down", 8, 8192, 28672)
    chained = schedule_chain(SNAKE, [up, down])
    unchained = [schedule_projection(SNAKE, up), schedule_projection(SNAKE, down)]
    assert sum(e.time_s for e in chained) <= sum(e.time_s for e in unchained) + 1e-12


def test_m_never_split_across_pus():
    """Per-PU sub-GEMMs preserve the full M (paper §3.1 / §5a)."""
    g = Gemm("g", 48, 8192, 8192)
    for cand in mode_candidates(SNAKE, g):
        assert cand.core is not None
        # core-level M equals op M (only N/K were partitioned)
        r, _ = cand.core.logical_shape
        assert r >= min(48, 64)


# ---------------------------------------------------------------------------
# Attention + experts
# ---------------------------------------------------------------------------
def test_attention_head_parallel_waves():
    lo = layer_ops(LLAMA3_70B, batch=8, ctx=4096)
    qk, av = lo.attention
    ex = schedule_attention(SNAKE, qk, av)
    assert ex.mode == "HEAD-P"
    assert ex.time_s > 0
    # 8 requests x 8 kv heads = 64 units on 64 cores -> single wave
    assert qk.count == 64


def test_experts_split_when_fewer_than_pus():
    """E=8 experts on 16 PUs must not leave half the die idle."""
    lo = layer_ops(MIXTRAL_8X22B, batch=32, ctx=2048)
    ex = schedule_experts(SNAKE, list(lo.experts), lo.moe_dispatch_bytes)
    lo2 = layer_ops(QWEN3_30B_A3B, batch=32, ctx=2048)
    ex2 = schedule_experts(SNAKE, list(lo2.experts), lo2.moe_dispatch_bytes)
    assert ex.time_s > 0 and ex2.time_s > 0


# ---------------------------------------------------------------------------
# Operator extraction
# ---------------------------------------------------------------------------
def test_llama3_decode_op_shapes():
    lo = layer_ops(LLAMA3_70B, batch=16, ctx=4096)
    by_name = {g.name: g for g in lo.projections}
    qkv = by_name["proj.qkv"]
    assert (qkv.m, qkv.k) == (16, 8192)
    assert qkv.n == (64 + 2 * 8) * 128
    up = by_name["ffn.up_gate"]
    assert (up.m, up.n, up.k) == (16, 2 * 28672, 8192)
    qk, av = lo.attention
    assert (qk.m, qk.n, qk.k) == (8, 4096, 128)      # GQA group of 8
    assert (av.m, av.n, av.k) == (8, 128, 4096)
    assert qk.count == 16 * 8


def test_moe_uniform_routing_shapes():
    lo = layer_ops(QWEN3_30B_A3B, batch=32, ctx=2048)
    up = [g for g in lo.experts if "up" in g.name][0]
    # 32*8 = 256 tokens over 128 experts -> M_e = 2, all experts active
    assert up.m == 2 and up.count == 128
    assert up.k == 2048 and up.n == 2 * 768


def test_mla_absorbed_attention():
    lo = layer_ops(DEEPSEEK_236B, batch=8, ctx=4096)
    qk, av = lo.attention
    assert qk.m == 128 and qk.k == 512 + 64 and qk.n == 4096
    assert av.k == 4096 and av.n == 512
    assert qk.count == 8


def test_param_counts_sane():
    assert 60e9 < LLAMA3_70B.params() < 75e9
    assert 120e9 < MIXTRAL_8X22B.params() < 150e9
    assert 200e9 < DEEPSEEK_236B.params() < 260e9
    assert 25e9 < QWEN3_30B_A3B.params() < 35e9
    assert QWEN3_30B_A3B.active_params() < 5e9


# ---------------------------------------------------------------------------
# End-to-end decode (paper Fig. 12 directional claims)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", [LLAMA3_70B, MIXTRAL_8X22B])
def test_snake_beats_mactree(spec):
    for batch in (8, 64):
        rs = decode_step(SNAKE, spec, batch, 2048)
        rm = decode_step(MACT, spec, batch, 2048)
        assert rm.time_s > rs.time_s * 1.2


def test_batch8_memory_bound_on_snake_compute_bound_on_mactree():
    """Paper Fig. 1: bandwidth advantage flips the bottleneck."""
    rs = decode_step(SNAKE, LLAMA3_70B, 8, 2048)
    rm = decode_step(MACT, LLAMA3_70B, 8, 2048)
    proj_s = [e for e in rs.op_execs if e.op.name.startswith(("proj", "ffn"))]
    proj_m = [e for e in rm.op_execs if e.op.name.startswith(("proj", "ffn"))]
    assert sum(e.stalled for e in proj_s) > len(proj_s) // 2
    assert sum(not e.stalled for e in proj_m) > len(proj_m) // 2


def test_per_op_scheduler_beats_fixed_modes():
    """Paper Fig. 13b: any fixed mode is a slowdown vs the per-op search."""
    flex = decode_step(SNAKE, QWEN3_30B_A3B, 16, 2048)
    for mode in Mode:
        fixed = decode_step(SNAKE, QWEN3_30B_A3B, 16, 2048, fixed_mode=mode)
        assert fixed.time_s >= flex.time_s * 0.999


def test_gpu_slower_than_snake():
    for spec in (OPT_66B, LLAMA3_70B):
        rs = decode_step(SNAKE, spec, 8, 4096)
        rg = gpu_decode_step(spec, 8, 4096, tp=1)
        assert rg.time_s > 3 * rs.time_s


def test_decode_energy_positive_and_decomposed():
    r = decode_step(SNAKE, LLAMA3_70B, 16, 2048)
    e = r.energy
    for f in ("mac_j", "sram_j", "dram_j", "vector_j", "ctrl_j"):
        assert getattr(e, f) > 0
    assert e.logic_die_j < e.total_j
