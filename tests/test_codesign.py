"""Live array-shape/dataflow co-design (PR 6).

Covers the TickLatencyModel against the offline §5 scheduler, its
memoization and reconfiguration accounting, the codesign-enabled engine
(token exactness — the modeled clock is an accounting channel), and the
shared-prefix chunked-prefill compute skip.
"""
import numpy as np
import pytest

from repro.core.hw import fixed_sa_system, snake_system
from repro.core.pipeline import decode_step
from repro.core.schedule import exec_config, shape_profile
from repro.core.serving_sim import nmp_tick_model, simulate_serving
from repro.models import registry
from repro.serving.engine import (EngineConfig, make_engine,
                                  make_shared_prefix_trace)
from repro.serving.scheduler import make_trace

SNAKE = snake_system()
SPEC = registry.get_config("llama3-70b").nmp_spec()
MOE_SPEC = registry.get_config("qwen3-30b-a3b").nmp_spec()


# --- TickLatencyModel vs the offline scheduler -------------------------
def test_tick_decision_matches_offline_schedule():
    """A decode-only tick picks exactly the per-op (mode, shape)
    configuration the offline scheduling search picks for the same
    bucket-aligned composition."""
    tm = nmp_tick_model(SNAKE, SPEC, tp=8)
    d = tm.step(8, [4096] * 8)
    rep = decode_step(SNAKE, SPEC, 8, 4096, tp=8)
    assert d.config == exec_config(rep.op_execs)
    assert d.shapes == shape_profile(rep.op_execs)
    assert d.decode_s == pytest.approx(rep.time_s)
    assert d.prefill_s == 0.0


def test_tick_prefill_chunk_priced_without_head():
    tm = nmp_tick_model(SNAKE, SPEC, tp=8)
    d = tm.step(0, [], prefill_tokens=256, prefill_ctx=2048)
    rep = decode_step(SNAKE, SPEC, 256, 2048, include_head=False, tp=8)
    assert d.prefill_s == pytest.approx(rep.time_s)
    assert d.decode_s == 0.0


def test_tick_latency_monotone_in_ctx_and_batch():
    """Modeled decode latency never decreases with context, and on a
    FIXED substrate never decreases with batch.  (On the reconfigurable
    substrate a larger batch may unlock a better array shape and tick
    *faster* — that dip is the co-design effect, so the batch claim
    there is the weaker per-token one: time per decoded token falls.)"""
    tm = nmp_tick_model(SNAKE, SPEC, tp=8)
    times = [tm.step(8, [c] * 8).decode_s for c in (2048, 4096, 8192)]
    assert all(t1 >= t0 * 0.999 for t0, t1 in zip(times, times[1:]))
    fixed = nmp_tick_model(fixed_sa_system(16, 256), SPEC, tp=8)
    ftimes = [fixed.step(b, [4096] * b).decode_s for b in (4, 8, 16, 32)]
    assert all(t1 >= t0 * 0.999 for t0, t1 in zip(ftimes, ftimes[1:]))
    per_tok = [tm.step(b, [4096] * b).decode_s / b for b in (4, 8, 16, 32)]
    assert all(t1 <= t0 * 1.001 for t0, t1 in zip(per_tok, per_tok[1:]))


def test_tick_model_memoizes_on_shape_signature():
    tm = nmp_tick_model(SNAKE, SPEC, tp=8)
    d1 = tm.step(4, [1000, 1100, 900, 1024])
    n_cached = len(tm._cache)
    # same reduced signature (same batch, same mean-ctx bucket)
    d2 = tm.step(4, [1010, 1090, 910, 1014])
    assert len(tm._cache) == n_cached
    assert d2 is d1


def test_reconfiguration_accounting_per_stream():
    """Shape-profile changes count per stream; a fixed-shape substrate
    never reconfigures (single legal shape)."""
    tm = nmp_tick_model(SNAKE, MOE_SPEC, tp=8)
    profiles = set()
    for batch, pf in ((1, 0), (32, 0), (1, 256), (64, 0)):
        d = tm.step(batch, [2048] * batch, prefill_tokens=pf,
                    prefill_ctx=2048, stream="a")
        profiles.add(d.shapes)
    assert len(profiles) > 1        # composition diversity forces changes
    assert tm.reconfigurations > 0
    # an independent stream replays the same decisions from cache and
    # pays its own reconfigurations
    before = tm.reconfigurations
    tm.step(1, [2048], stream="b")
    assert tm.reconfigurations == before
    fixed = nmp_tick_model(fixed_sa_system(16, 256), MOE_SPEC, tp=8)
    for batch, pf in ((1, 0), (32, 0), (1, 256), (64, 0)):
        fixed.step(batch, [2048] * batch, prefill_tokens=pf,
                   prefill_ctx=2048, stream="a")
    assert fixed.reconfigurations == 0
    assert len({s for s in fixed._last_shapes.values()}) == 1


def test_tick_model_is_decode_latency_model_compatible():
    tm = nmp_tick_model(SNAKE, SPEC, tp=8)
    assert tm(8, 4096) == pytest.approx(tm.step(8, [4096] * 8).time_s)


# --- simulate_serving mirror ------------------------------------------
def test_simulate_serving_tick_model_drives_clock():
    """The per-tick model is the serving clock in the mirror: decoded
    tokens match the scalar-model run, throughput fields populate, and
    only the reconfigurable substrate reports reconfigurations."""
    kw = dict(rate_req_s=100.0, system="SNAKE", n_requests=4,
              input_len=512, output_len=32, max_batch=4,
              prefill_on_device=True, prefill_chunk=256)
    tick = nmp_tick_model(SNAKE, SPEC, tp=8)
    rep = simulate_serving(tick, SPEC, **kw)
    assert rep.completed == 4
    assert rep.decoded_tokens == 4 * 32
    assert rep.makespan_s > 0 and rep.tokens_per_s > 0
    assert rep.substrate_configs >= 1
    assert 0.0 < rep.array_util_mean <= 1.0
    fixed = nmp_tick_model(fixed_sa_system(16, 256), SPEC, tp=8)
    rf = simulate_serving(fixed, SPEC, **kw)
    assert rf.decoded_tokens == rep.decoded_tokens
    assert rf.reconfigurations == 0


# --- codesign engine (accounting channel) -----------------------------
def _run_engine(entry, reqs, **over):
    ecfg = EngineConfig(max_batch=2, max_seq=64, max_new_tokens=4,
                        paged=True, page_size=8, prefill_chunk=16, **over)
    eng = make_engine(entry, ecfg)
    eng.run_trace(reqs)
    return eng


def test_codesign_engine_token_exact_and_reports():
    """Turning co-design pricing on (reconfigurable or fixed substrate)
    never changes decoded tokens, and the report chain threads through
    Scheduler.metrics."""
    entry = registry.get("yi-6b", reduced=True)
    reqs = make_trace(entry.config.vocab, rate_req_s=500.0, n_requests=4,
                      prompt_len=40, seed=3)

    def toks(e):
        return {r.rid: list(map(int, r.tokens_out)) for r in e.completed}

    base = _run_engine(entry, reqs)
    snake_eng = _run_engine(entry, reqs, codesign=True)
    fixed_eng = _run_engine(entry, reqs, codesign=True, codesign_rows=16)
    assert toks(base) == toks(snake_eng) == toks(fixed_eng)
    assert base.codesign_report() == {}
    cd = snake_eng.codesign_report()
    assert cd["substrate"] == "SNAKE"
    assert cd["modeled_time_s"] > 0
    assert cd["substrate_configs"] >= 1
    assert fixed_eng.codesign_report()["reconfigurations"] == 0

    from repro.serving.scheduler import Scheduler
    sch = Scheduler(snake_eng)
    m = sch.metrics(1.0, 0.0)
    assert m["codesign_substrate"] == "SNAKE"
    assert m["modeled_time_s"] == pytest.approx(cd["modeled_time_s"])
    assert m["modeled_tokens_per_s"] > 0


def test_codesign_spec_and_tp_override():
    """codesign_spec/codesign_tp price a full-size deployment while the
    reduced engine runs tiny weights."""
    entry = registry.get("yi-6b", reduced=True)
    reqs = make_trace(entry.config.vocab, rate_req_s=500.0, n_requests=2,
                      prompt_len=24, seed=0)
    eng = _run_engine(entry, reqs, codesign=True, codesign_spec=SPEC,
                      codesign_tp=8)
    assert eng._tick_model.spec is SPEC
    assert eng._tick_model.tp == 8
    assert eng.codesign_report()["modeled_time_s"] > 0


# --- shared-prefix chunked-prefill compute skip ------------------------
def test_chunked_prefill_skips_resident_prefix_token_exact():
    """With sharing + chunked prefill, later requests skip recomputing
    resident full prefix pages — and still decode the exact tokens the
    dense engine decodes."""
    entry = registry.get("yi-6b", reduced=True)

    def run(**over):
        ecfg = EngineConfig(max_batch=3, max_seq=64, max_new_tokens=5,
                            **over)
        eng = make_engine(entry, ecfg)
        reqs = make_shared_prefix_trace(
            entry.config.vocab, rate_req_s=500.0, n_requests=5,
            prefix_len=24, tail_len=6, seed=4)
        eng.run_trace(reqs)
        return eng

    dense = run()
    shared = run(paged=True, page_size=8, prefix_sharing=True,
                 prefill_chunk=8)

    def toks(e):
        return {r.rid: list(map(int, r.tokens_out)) for r in e.completed}

    assert toks(dense) == toks(shared)
    assert shared.prefill_tokens_skipped > 0
    assert shared.kv_report()["prefill_skipped_tokens"] \
        == shared.prefill_tokens_skipped


# --- reconfiguration pricing (fill/drain penalty) ----------------------
def test_reconfig_cost_derived_from_array_geometry():
    """Default penalty is the pipeline fill/drain of the new
    configuration: (rows + cols - 2 + reconfig_cycles) cycles.  A MAC
    tree has no systolic pipeline, so its derived cost is zero."""
    sa = SNAKE.substrate
    cyc = sa.phys_rows + sa.phys_cols - 2 + sa.reconfig_cycles
    tm = nmp_tick_model(SNAKE, SPEC, tp=8)
    assert tm.reconfig_cost_s == pytest.approx(cyc / SNAKE.freq_hz)
    assert tm.reconfig_cost_s > 0
    from repro.core.hw import mactree_system
    assert nmp_tick_model(mactree_system(), SPEC).reconfig_cost_s == 0.0
    assert nmp_tick_model(SNAKE, SPEC, reconfig_cost_s=0.25
                          ).reconfig_cost_s == 0.25


def test_reconfiguration_pricing_dips_modeled_throughput():
    """Each shape-profile change is charged, not just counted: the same
    tick sequence priced with a reconfig cost is slower by exactly
    cost x count, memoization identity is preserved, and a fixed-shape
    substrate never pays (it never reconfigures)."""
    seq = ((1, 0), (32, 0), (1, 256), (64, 0), (32, 0))

    def run(tm):
        total = 0.0
        for batch, pf in seq:
            d = tm.step(batch, [2048] * batch, prefill_tokens=pf,
                        prefill_ctx=2048, stream="a")
            total += d.time_s + d.reconfig_s
        return total

    free = nmp_tick_model(SNAKE, MOE_SPEC, tp=8, reconfig_cost_s=0.0)
    t_free = run(free)
    assert free.reconfigurations > 0
    cost = 1e-3
    priced = nmp_tick_model(SNAKE, MOE_SPEC, tp=8, reconfig_cost_s=cost)
    t_priced = run(priced)
    assert priced.reconfigurations == free.reconfigurations
    assert t_priced == pytest.approx(
        t_free + cost * priced.reconfigurations)
    # the cached entry stays penalty-free: a repeat of the same
    # signature with no profile change is the identical object again
    d1 = priced.step(32, [2048] * 32, stream="a")
    d2 = priced.step(32, [2048] * 32, stream="a")
    assert d2 is d1 and d2.reconfig_s == 0.0
    fixed = nmp_tick_model(fixed_sa_system(16, 256), MOE_SPEC, tp=8,
                           reconfig_cost_s=cost)
    for batch, pf in seq:
        d = fixed.step(batch, [2048] * batch, prefill_tokens=pf,
                       prefill_ctx=2048, stream="a")
        assert d.reconfig_s == 0.0
    assert fixed.reconfigurations == 0


def test_simulate_serving_charges_reconfigurations():
    """The analytic mirror's clock pays the penalty: same workload, same
    decoded tokens, strictly lower modeled throughput when
    reconfigurations are priced high."""
    kw = dict(rate_req_s=100.0, system="SNAKE", n_requests=4,
              input_len=512, output_len=32, max_batch=4,
              prefill_on_device=True, prefill_chunk=256)
    free = simulate_serving(
        nmp_tick_model(SNAKE, MOE_SPEC, tp=8, reconfig_cost_s=0.0),
        MOE_SPEC, **kw)
    priced = simulate_serving(
        nmp_tick_model(SNAKE, MOE_SPEC, tp=8, reconfig_cost_s=5e-3),
        MOE_SPEC, **kw)
    assert priced.decoded_tokens == free.decoded_tokens
    assert priced.reconfigurations > 0
    assert priced.makespan_s > free.makespan_s
    assert priced.tokens_per_s < free.tokens_per_s


def test_engine_reconfig_cost_knob_charges_modeled_clock():
    """EngineConfig.codesign_reconfig_cost_s threads to the tick model
    and the engine's modeled clock pays time_s + reconfig_s per tick —
    the total penalty is exactly cost x reconfigurations.  (Tick
    compositions drift run-to-run under wall-clock scheduling, so the
    identity is checked within one run, not across two.)"""
    entry = registry.get("yi-6b", reduced=True)
    reqs = make_trace(entry.config.vocab, rate_req_s=500.0, n_requests=4,
                      prompt_len=40, seed=3)
    cost = 2e-3
    ecfg = EngineConfig(max_batch=2, max_seq=64, max_new_tokens=4,
                        paged=True, page_size=8, prefill_chunk=16,
                        codesign=True, codesign_reconfig_cost_s=cost)
    eng = make_engine(entry, ecfg)
    tm = eng._tick_model
    assert tm.reconfig_cost_s == cost
    seen = []
    orig = tm.step

    def recording_step(*a, **kw):
        d = orig(*a, **kw)
        seen.append(d)
        return d

    tm.step = recording_step
    eng.run_trace(reqs)
    assert seen
    assert eng.modeled_time_s == pytest.approx(
        sum(d.time_s + d.reconfig_s for d in seen))
    assert sum(d.reconfig_s for d in seen) == pytest.approx(
        cost * tm.reconfigurations)
    # default (no knob) derives the fill/drain cost from the substrate
    eng2 = make_engine(entry, EngineConfig(
        max_batch=2, max_seq=64, max_new_tokens=4, paged=True,
        page_size=8, codesign=True))
    assert eng2._tick_model.reconfig_cost_s > 0
