"""Stack-aware page placement policies on a skewed shared-prefix trace.

The workload placement exists for: several tenant groups with Zipf-skewed
popularity share system prompts while unique tails and decode growth
churn the page pool.  ``free-first`` leaves each slot's block table
wherever the free list pointed — straddling channel regions once the
pool has holes; ``interleave`` stripes it on purpose; ``affinity``
co-locates a slot's private pages in one home region and parks the
shareable prompt pages in the communal region.  The score is
``core.placement.gather_cost``: pages outside the majority channel
funnel through the issuing PU's single NoC injection port.

Placement never changes admission (spill keeps success a function of the
global free count), so every policy decodes the IDENTICAL tokens — this
is asserted against the dense engine, making the gather-cost comparison
apples-to-apples.

Two sections, both written to ``benchmarks/out/serving_placement.json``:

* real-JAX engine (reduced config, CPU-runnable): dense baseline + the
  three placement policies on one trace; asserts token-exactness across
  all of them and that ``affinity`` beats ``free-first`` on mean gather
  cost;
* analytical mirror (``core/serving_sim.py``): the paper-scale workload
  (8K-in/1K-out, 1K shared prefix on the SNAKE substrate) under the same
  three policies, same assertion.

Run directly or via ``benchmarks.run``:

  PYTHONPATH=src:. python benchmarks/serving_placement.py [--smoke]
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

from benchmarks.common import Row, emit
from repro.models import registry
from repro.serving.engine import EngineConfig, load_trace, make_engine, \
    make_grouped_prefix_trace

ARCH = "yi-6b"
N_REQ = 14
RATE = 40.0           # staggered enough that frees interleave with allocs
MAX_BATCH = 4
MAX_SEQ = 64
MAX_NEW = 12
PAGE = 4
NUM_PAGES = 40
N_REGIONS = 8
N_GROUPS = 3
PREFIX = 16           # 4 full pages of shared system prompt per group
TAIL = 6
SKEW = 0.8
SEED = 0
POLICIES = ("free-first", "interleave", "affinity")


def _ecfg(placement: Optional[str], max_new: int) -> EngineConfig:
    return EngineConfig(max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                        max_new_tokens=max_new, paged=True,
                        page_size=PAGE, num_pages=NUM_PAGES,
                        prefix_sharing=True, prefill_chunk=8,
                        placement=placement,
                        placement_regions=N_REGIONS)


def engine_rows(n_req: int, max_new: int,
                trace_file: Optional[str] = None) -> List[Row]:
    entry = registry.get(ARCH, reduced=True)

    def trace():
        if trace_file:
            return load_trace(trace_file, vocab=entry.config.vocab)
        return make_grouped_prefix_trace(
            entry.config.vocab, rate_req_s=RATE, n_requests=n_req,
            n_groups=N_GROUPS, prefix_len=PREFIX, tail_len=TAIL,
            skew=SKEW, seed=SEED)

    rows: List[Row] = []
    # -- dense baseline: the token-exactness reference -------------------
    dense = make_engine(entry, EngineConfig(
        max_batch=MAX_BATCH, max_seq=MAX_SEQ, max_new_tokens=max_new))
    dense.run_trace(trace())
    base_tokens = {r.rid: r.tokens_out for r in dense.completed}

    metrics = {}
    for policy in POLICIES:
        eng = make_engine(entry, _ecfg(policy, max_new))
        m = eng.run_trace(trace())
        toks = {r.rid: r.tokens_out for r in eng.completed}
        assert toks == base_tokens, \
            f"placement {policy} changed decoded tokens vs dense"
        metrics[policy] = m
        p = f"serving_placement/{policy}"
        rows.append(Row(f"{p}/gather_cost_mean_us",
                        m["kv_gather_cost_mean_s"] * 1e6,
                        note="mean per-slot block-table DMA cost (SNAKE)"))
        rows.append(Row(f"{p}/gather_concentration",
                        m["kv_gather_concentration"],
                        note="majority-channel share of mapped pages"))
        rows.append(Row(f"{p}/tokens_per_s", m["tokens_per_s"]))
        rows.append(Row(f"{p}/preemptions", m["preemptions"]))
    rows.append(Row("serving_placement/token_exact_vs_dense", 1.0,
                    note="all placement policies == dense engine tokens"))

    aff, ff = metrics["affinity"], metrics["free-first"]
    assert aff["kv_gather_cost_mean_s"] < ff["kv_gather_cost_mean_s"], \
        "affinity placement did not lower the mean gather cost"
    rows.append(Row(
        "serving_placement/cost_affinity_over_free_first",
        aff["kv_gather_cost_mean_s"] / max(1e-30,
                                           ff["kv_gather_cost_mean_s"]),
        note="< 1: co-location beats the free-list layout"))
    rows.append(Row(
        "serving_placement/conc_affinity_minus_free_first",
        aff["kv_gather_concentration"] - ff["kv_gather_concentration"]))
    return rows


def sim_rows(n_requests: int = 32) -> List[Row]:
    from repro.core.hw import snake_system
    from repro.core.operators import PAPER_MODELS
    from repro.core.serving_sim import nmp_latency_model, simulate_serving
    spec = PAPER_MODELS["LLaMA3-70B"]
    sys = snake_system()
    lat = nmp_latency_model(sys, spec, tp=8)
    rows: List[Row] = []
    reports = {}
    for policy in POLICIES:
        rep = simulate_serving(
            lat, spec, 0.5, system="SNAKE", n_requests=n_requests,
            cache_mode="paged", prefix_sharing=True,
            shared_prefix_len=1024, page_size=64, num_pages=1600,
            placement=policy, n_regions=8, hw=sys)
        reports[policy] = rep
        p = f"serving_placement/sim/{policy}"
        rows.append(Row(f"{p}/gather_cost_mean_ms",
                        rep.gather_cost_mean_s * 1e3))
        rows.append(Row(f"{p}/gather_concentration",
                        rep.gather_concentration))
        rows.append(Row(f"{p}/region_peak_max",
                        max(rep.region_peak_pages)))
        rows.append(Row(f"{p}/e2e_mean_s", rep.e2e_mean_s))
    e2e = {p: reports[p].e2e_mean_s for p in POLICIES}
    assert len(set(e2e.values())) == 1, \
        f"placement changed analytic scheduling: {e2e}"
    aff, ff = reports["affinity"], reports["free-first"]
    assert aff.gather_cost_mean_s < ff.gather_cost_mean_s
    rows.append(Row("serving_placement/sim/cost_affinity_over_free_first",
                    aff.gather_cost_mean_s / ff.gather_cost_mean_s))
    rows.append(Row("serving_placement/sim/cost_interleave_over_free_first",
                    reports["interleave"].gather_cost_mean_s
                    / ff.gather_cost_mean_s,
                    note="> 1: striping pays the NoC injection port"))
    return rows


def run(smoke: bool = False,
        trace_file: Optional[str] = None) -> List[Row]:
    if smoke:
        rows = engine_rows(8, 12, trace_file)
        rows.extend(sim_rows(n_requests=16))
    else:
        rows = engine_rows(N_REQ, MAX_NEW, trace_file)
        rows.extend(sim_rows())
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace-file", type=str, default=None,
                    help="replay a recorded JSON trace instead of the "
                         "synthetic grouped-prefix sweep")
    args = ap.parse_args()
    t0 = time.time()
    emit("serving_placement", run(smoke=args.smoke,
                                  trace_file=args.trace_file),
         time.time() - t0)
