"""Fig. 10 — serving latency (E2E and TBT) under Poisson request rates.

Duplex-style serving framework: H100x8 prefill for all systems; decode on
the device under test (continuous batching, 8K-input / 1K-output requests).
Latencies are reported normalized to SNAKE at each rate, matching the
paper's presentation (GPU ~1.5-3.0x E2E / 1.5-4.0x TBT; MAC tree
~1.1-2.3x / 1.3-2.2x; 48x48 ~1.1-2.4x / 1.1-2.2x; 8x288 worst, TBT up to
~4.5x).
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import Row, geomean
from repro.core.hw import fixed_sa_system, mactree_system, snake_system
from repro.core.operators import PAPER_MODELS
from repro.core.serving_sim import (DecodeLatencyModel, gpu_latency_model,
                                    nmp_latency_model, simulate_serving)

MODELS = ("LLaMA3-70B", "Qwen3-30B-A3B")   # one dense + one MoE
NORM_RATES = (0.3, 0.6, 0.9)               # fraction of saturation rate
N_REQ = 64
TP = 8
IN_LEN, OUT_LEN = 8192, 1024


def _saturation_rate(spec, lat: DecodeLatencyModel) -> float:
    """Request rate at which decode (or the shared prefill engine) saturates:
    min(prefill-limited, decode-limited at a 48-deep continuous batch)."""
    from repro.core.serving_sim import _prefill_time
    r_prefill = 1.0 / _prefill_time(spec, IN_LEN)
    tbt48 = lat(48, IN_LEN + OUT_LEN // 2) or 1e-9
    r_decode = 48 / (OUT_LEN * tbt48)
    return min(r_prefill, r_decode)


def run() -> List[Row]:
    rows: List[Row] = []
    systems = {"MAC-Tree": mactree_system(),
               "SA-48x48": fixed_sa_system(48, 48),
               "SA-8x288": fixed_sa_system(8, 288)}
    for model in MODELS:
        spec = PAPER_MODELS[model]
        lat_snake = nmp_latency_model(snake_system(), spec, tp=TP)
        lats: Dict[str, DecodeLatencyModel] = {
            k: nmp_latency_model(s, spec, tp=TP) for k, s in systems.items()}
        lats["GPU"] = gpu_latency_model(spec, tp=TP)
        sat = _saturation_rate(spec, lat_snake)
        ratios = {k: {"e2e": [], "tbt": []} for k in lats}
        for nr in NORM_RATES:
            rate = nr * sat
            base = simulate_serving(lat_snake, spec, rate, system="SNAKE",
                                    n_requests=N_REQ)
            for k, lm in lats.items():
                rep = simulate_serving(lm, spec, rate, system=k,
                                       n_requests=N_REQ)
                e2e, tbt = rep.normalized_to(base)
                ratios[k]["e2e"].append(e2e)
                ratios[k]["tbt"].append(tbt)
        for k, d in ratios.items():
            rows.append(Row(f"fig10/{model}/e2e_vs_snake_{k}",
                            geomean(d["e2e"])))
            rows.append(Row(f"fig10/{model}/tbt_vs_snake_{k}",
                            geomean(d["tbt"])))
        # paged vs dense KV occupancy on the SNAKE decode substrate: the
        # block-table cache keeps resident KV proportional to the live
        # contexts instead of the max_batch x (in+out) reservation
        rate = 0.6 * sat
        occ = {}
        for mode in ("dense", "paged"):
            rep = simulate_serving(lat_snake, spec, rate, system="SNAKE",
                                   n_requests=N_REQ, cache_mode=mode)
            occ[mode] = rep
            rows.append(Row(f"fig10/{model}/kv_util_{mode}",
                            rep.kv_util_mean))
        rows.append(Row(f"fig10/{model}/kv_peak_tokens_paged_over_dense",
                        occ["paged"].kv_peak_tokens
                        / max(1, occ["dense"].kv_peak_tokens)))
    return rows
