"""Benchmark harness entry point — one module per paper figure/table.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig12      # one module

Prints ``name,us_per_call,derived[,paper=..][,note]`` CSV rows and dumps
raw results to benchmarks/out/<module>.json.
"""
from __future__ import annotations

import importlib
import sys
import time
import traceback

from benchmarks.common import emit

MODULES = [
    "fig1_roofline",       # Fig. 1a/b  roofline + Stratum execution split
    "fig4_buffer_tradeoff",  # Fig. 4a/b  buffer->compute + dataflow pref
    "fig11_area",          # Fig. 11    area / compute-area eff / power
    "fig12_decode_perf",   # Fig. 12    decode speedup + energy efficiency
    "fig13_scheduling",    # Fig. 13    mode distribution + fixed-mode slowdown
    "fig14_array_shapes",  # Fig. 14    shape demand + buffer requirements
    "fig10_serving",       # Fig. 10    serving E2E/TBT vs request rate
    "kernel_bench",        # Pallas kernels vs oracles + chosen mappings
    "tpu_roofline",        # deliverable (g): dry-run roofline table
    "serving_paged",       # paged vs dense engine on a skewed-length trace
    "serving_shared",      # refcounted prefix sharing on shared-prompt traces
    "serving_router",      # multi-replica routing policies (prefix affinity)
]


def main() -> int:
    only = sys.argv[1:] or None
    failures = 0
    for name in MODULES:
        if only and not any(name.startswith(o) for o in only):
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            t0 = time.time()
            rows = mod.run()
            emit(name, rows, time.time() - t0)
        except Exception:
            failures += 1
            print(f"{name},0,NaN,ERROR")
            traceback.print_exc()
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
