"""Benchmark harness entry point — one module per paper figure/table.

  PYTHONPATH=src:. python benchmarks/run.py            # all, full sweeps
  PYTHONPATH=src:. python benchmarks/run.py fig12      # one module
  PYTHONPATH=src:. python benchmarks/run.py --smoke    # CI: every module,
                                                       # reduced sweeps

``--smoke`` passes ``smoke=True`` to every module whose ``run()`` accepts
it (the serving sweeps) and runs the rest at full size — the single CI
entry point replacing the old per-benchmark workflow steps.  Prints
``name,us_per_call,derived[,paper=..][,note]`` CSV rows and dumps raw
results to ``benchmarks/out/<module>.json`` (uploaded as CI artifacts).
A run summary — per-module wall time, ``ok``/``error`` status, and row
count — lands in ``benchmarks/out/summary.json``.
Exit code = number of failed modules.
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import json
import os
import time
import traceback

from benchmarks.common import OUT_DIR, emit

MODULES = [
    "fig1_roofline",       # Fig. 1a/b  roofline + Stratum execution split
    "fig4_buffer_tradeoff",  # Fig. 4a/b  buffer->compute + dataflow pref
    "fig11_area",          # Fig. 11    area / compute-area eff / power
    "fig12_decode_perf",   # Fig. 12    decode speedup + energy efficiency
    "fig13_scheduling",    # Fig. 13    mode distribution + fixed-mode slowdown
    "fig14_array_shapes",  # Fig. 14    shape demand + buffer requirements
    "fig10_serving",       # Fig. 10    serving E2E/TBT vs request rate
    "kernel_bench",        # Pallas kernels vs oracles + chosen mappings
    "tpu_roofline",        # deliverable (g): dry-run roofline table
    "serving_paged",       # paged vs dense engine on a skewed-length trace
    "serving_shared",      # refcounted prefix sharing on shared-prompt traces
    "serving_router",      # multi-replica routing policies (prefix affinity)
    "serving_placement",   # stack-aware page placement (gather-cost sweep)
    "serving_codesign",    # per-tick shape/dataflow co-design vs fixed SAs
    "serving_fused",       # fused decode loop: fusion horizon x batch sweep
    "serving_disagg",      # prefill/decode tiers + page shipping vs colocated
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("modules", nargs="*",
                    help="run only modules matching these prefixes")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweeps where supported (CI entry point)")
    args = ap.parse_args()
    failures = 0
    summary = {"smoke": args.smoke, "modules": {}}
    for name in MODULES:
        if args.modules and not any(name.startswith(o)
                                    for o in args.modules):
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            kwargs = {}
            if args.smoke and "smoke" in \
                    inspect.signature(mod.run).parameters:
                kwargs["smoke"] = True
            rows = mod.run(**kwargs)
            emit(name, rows, time.time() - t0)
            summary["modules"][name] = {"status": "ok",
                                        "wall_s": time.time() - t0,
                                        "rows": len(rows)}
        except Exception:
            failures += 1
            print(f"{name},0,NaN,ERROR")
            traceback.print_exc()
            summary["modules"][name] = {"status": "error",
                                        "wall_s": time.time() - t0,
                                        "rows": 0}
    summary["failures"] = failures
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
