"""Live array-shape/dataflow co-design vs. fixed-shape substrates.

The paper's core claim, measured end-to-end: a reconfigurable decode
substrate (SNAKE) that re-picks its array shape and dataflow *every
scheduler tick* from the actual batch composition beats the best single
fixed-shape array on serving throughput, because no one shape suits the
whole trace — small-batch decode GEMVs, wide chunked-prefill GEMMs, and
MoE expert fan-out each prefer different logical shapes.

Two sections, both written to ``benchmarks/out/serving_codesign.json``:

* real-JAX engine (reduced dense ``yi-6b`` + reduced MoE
  ``qwen3-30b-a3b``, CPU-runnable): identical chunked-prefill traces run
  once per priced substrate (SNAKE + fixed rows x cols at the same PE
  count).  The ``TickLatencyModel`` prices every tick's real composition
  on the *full-size* registry spec at the paper's tp=8 deployment width
  (``codesign_spec`` / ``codesign_tp``) — the modeled clock is an
  accounting channel, so decoded tokens must be identical across
  substrates (asserted);
* analytical mirror (``core/serving_sim.simulate_serving``): the
  paper-scale workload (dense LLaMA3-70B and MoE Qwen3-30B-A3B, long
  prompts, on-device chunked prefill) where the per-tick model *drives*
  the serving clock.  Decoded tokens are identical by construction
  (same trace, run to completion); throughput differences are pure
  substrate effects (asserted: SNAKE > best fixed shape).

Run directly or via ``benchmarks.run``:

  PYTHONPATH=src:. python benchmarks/serving_codesign.py [--smoke]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

from benchmarks.common import Row, emit
from repro.models import registry
from repro.serving.engine import EngineConfig, make_engine
from repro.serving.scheduler import make_trace

ENGINE_ARCHS = ("yi-6b", "qwen3-30b-a3b")   # dense + MoE
ROWS_SWEEP = (8, 16, 32, 64)                # fixed rows x (4096/rows)
CODESIGN_TP = 8                             # paper deployment width

# engine trace: long-enough prompts that chunked prefill contributes
# several wide GEMM ticks per request alongside the decode GEMVs
PROMPT = 512
CHUNK = 128
MAX_NEW = 8
N_REQ = 8
MAX_BATCH = 4
RATE = 200.0                                # back-to-back arrivals

# analytical mirror: paper-scale serving.  The arrival rate is set so
# the trace outruns the on-device prefill stream — continuous batching
# then actually builds the deep decode batches (>= 16) where the
# reconfigurable substrate overtakes fixed arrays on decode ticks too.
SIM_MODELS = {"LLaMA3-70B": "dense", "Qwen3-30B-A3B": "moe"}
SIM_INPUT = 8192
SIM_OUTPUT = 1024
SIM_REQS = 16
SIM_BATCH = 64
SIM_CHUNK = 256
SIM_RATE = 8.0


def _substrates(rows_sweep) -> Dict[str, Optional[int]]:
    """Substrate label -> codesign_rows (None = reconfigurable SNAKE)."""
    subs: Dict[str, Optional[int]] = {"snake": None}
    for r in rows_sweep:
        subs[f"sa{r}"] = r
    return subs


def engine_rows(n_req: int, max_new: int, rows_sweep) -> List[Row]:
    rows: List[Row] = []
    for arch in ENGINE_ARCHS:
        entry = registry.get(arch, reduced=True)
        full_spec = registry.get_config(arch).nmp_spec()
        modeled: Dict[str, float] = {}
        tokens: Dict[str, dict] = {}
        for label, fixed_rows in _substrates(rows_sweep).items():
            ecfg = EngineConfig(
                max_batch=MAX_BATCH, max_seq=PROMPT + max_new + CHUNK,
                max_new_tokens=max_new, paged=True, page_size=16,
                prefill_chunk=CHUNK, codesign=True,
                codesign_rows=fixed_rows, codesign_spec=full_spec,
                codesign_tp=CODESIGN_TP)
            eng = make_engine(entry, ecfg)
            reqs = make_trace(entry.config.vocab, rate_req_s=RATE,
                              n_requests=n_req, prompt_len=PROMPT, seed=0)
            eng.run_trace(reqs)
            cd = eng.codesign_report()
            toks = sum(len(r.tokens_out) for r in eng.completed)
            modeled[label] = toks / cd["modeled_time_s"]
            tokens[label] = {r.rid: r.tokens_out for r in eng.completed}
            p = f"serving_codesign/engine/{arch}/{label}"
            rows.append(Row(f"{p}/modeled_tokens_per_s", modeled[label]))
            rows.append(Row(f"{p}/reconfigurations",
                            cd["reconfigurations"]))
            rows.append(Row(f"{p}/substrate_configs",
                            cd["substrate_configs"]))
            rows.append(Row(f"{p}/array_util_mean", cd["array_util_mean"]))
            if fixed_rows is not None:
                assert cd["reconfigurations"] == 0, \
                    f"fixed {label} reported reconfigurations"
        # the modeled clock is an accounting channel: scheduling stays
        # wall-clock-driven, so every substrate decodes the same tokens
        ref = tokens["snake"]
        for label, t in tokens.items():
            assert t == ref, \
                f"{arch}: substrate {label} changed decoded tokens"
        best_fixed = max((v for k, v in modeled.items() if k != "snake"))
        assert modeled["snake"] > best_fixed, \
            f"{arch}: snake {modeled['snake']:.0f} tok/s did not beat " \
            f"best fixed {best_fixed:.0f} tok/s"
        rows.append(Row(
            f"serving_codesign/engine/{arch}/snake_over_best_fixed",
            modeled["snake"] / best_fixed,
            note="per-tick reconfiguration vs best single fixed shape"))
    return rows


def sim_rows(input_len: int, output_len: int, n_req: int,
             rate: float, rows_sweep) -> List[Row]:
    from repro.core.hw import fixed_sa_system, snake_system
    from repro.core.operators import PAPER_MODELS
    from repro.core.serving_sim import nmp_tick_model, simulate_serving
    rows: List[Row] = []
    snake = snake_system()
    pes = snake.substrate.phys_rows * snake.substrate.phys_cols
    for model in SIM_MODELS:
        spec = PAPER_MODELS[model]
        thru: Dict[str, float] = {}
        toks: Dict[str, int] = {}
        for label, fixed_rows in _substrates(rows_sweep).items():
            sys = (snake if fixed_rows is None
                   else fixed_sa_system(fixed_rows, pes // fixed_rows))
            tick = nmp_tick_model(sys, spec, tp=CODESIGN_TP)
            rep = simulate_serving(
                tick, spec, rate, system=sys.name, n_requests=n_req,
                input_len=input_len, output_len=output_len,
                max_batch=SIM_BATCH, prefill_on_device=True,
                prefill_chunk=SIM_CHUNK)
            thru[label] = rep.tokens_per_s
            toks[label] = rep.decoded_tokens
            p = f"serving_codesign/sim/{model}/{label}"
            rows.append(Row(f"{p}/tokens_per_s", rep.tokens_per_s))
            rows.append(Row(f"{p}/reconfigurations",
                            rep.reconfigurations))
            rows.append(Row(f"{p}/substrate_configs",
                            rep.substrate_configs))
            rows.append(Row(f"{p}/array_util_mean", rep.array_util_mean))
        assert len(set(toks.values())) == 1, \
            f"{model}: substrates decoded different token counts {toks}"
        best_fixed = max((v for k, v in thru.items() if k != "snake"))
        assert thru["snake"] > best_fixed, \
            f"{model}: snake {thru['snake']:.0f} tok/s did not beat " \
            f"best fixed {best_fixed:.0f} tok/s"
        rows.append(Row(
            f"serving_codesign/sim/{model}/snake_over_best_fixed",
            thru["snake"] / best_fixed,
            note="tick model drives the serving clock here"))
    return rows


def run(smoke: bool = False) -> List[Row]:
    if smoke:
        # prefill-heavy short-generation regime: fast, and the chunked
        # prefill GEMMs carry the reconfiguration win at small batch
        rows = engine_rows(4, 4, (16, 32))
        rows.extend(sim_rows(2048, 32, 8, 200.0, (16, 32)))
    else:
        rows = engine_rows(N_REQ, MAX_NEW, ROWS_SWEEP)
        rows.extend(sim_rows(SIM_INPUT, SIM_OUTPUT, SIM_REQS, SIM_RATE,
                             ROWS_SWEEP))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    t0 = time.time()
    emit("serving_codesign", run(smoke=args.smoke), time.time() - t0)
