"""Paged vs. dense serving on the real-JAX engine under a skewed-length
Poisson trace (reduced config, CPU-runnable).

The workload is the serving scenario the paged cache exists for: prompt
lengths drawn from a lognormal (a few long-context requests among many
short ones), Poisson arrivals, more requests than slots.  Both engines see
the IDENTICAL trace; reported per mode:

  * throughput (decoded tokens/s)
  * TTFT (arrival -> first token) and TPOT (inter-token) means
  * peak resident KV tokens (dense: the max_batch x max_seq reservation;
    paged: peak pages x page_size)

Run directly or via ``benchmarks.run``:

  PYTHONPATH=src:. python benchmarks/serving_paged.py
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

import numpy as np

from benchmarks.common import Row, emit
from repro.models import registry
from repro.serving.engine import (EngineConfig, load_trace, make_engine,
                                  make_trace)

ARCH = "yi-6b"
N_REQ = 12
RATE = 8.0
MAX_BATCH = 4
MAX_SEQ = 96
MAX_NEW = 8
PAGE = 8
SEED = 0


def skewed_prompt_lens(n: int, seed: int, lo: int = 4,
                       hi: int = MAX_SEQ - MAX_NEW - 2) -> np.ndarray:
    """Lognormal prompt lengths: mostly short, a heavy long tail."""
    rng = np.random.default_rng(seed + 1234)
    lens = rng.lognormal(mean=2.5, sigma=0.8, size=n)
    return np.clip(lens.astype(np.int64), lo, hi)


def run(trace_file: Optional[str] = None) -> List[Row]:
    entry = registry.get(ARCH, reduced=True)
    lens = skewed_prompt_lens(N_REQ, SEED)
    rows: List[Row] = []
    metrics = {}
    for mode in ("dense", "paged"):
        ecfg = EngineConfig(max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                            max_new_tokens=MAX_NEW,
                            paged=(mode == "paged"), page_size=PAGE,
                            prefill_chunk=16)
        eng = make_engine(entry, ecfg)
        if trace_file:
            reqs = load_trace(trace_file, vocab=entry.config.vocab)
        else:
            reqs = make_trace(entry.config.vocab, rate_req_s=RATE,
                              n_requests=N_REQ, prompt_len=0, seed=SEED,
                              prompt_lens=lens)
        m = eng.run_trace(reqs)
        metrics[mode] = m
        rows.append(Row(f"serving_paged/{mode}/tokens_per_s",
                        m["tokens_per_s"]))
        rows.append(Row(f"serving_paged/{mode}/ttft_mean_s",
                        m["ttft_mean_s"]))
        rows.append(Row(f"serving_paged/{mode}/tpot_mean_s",
                        m["tpot_mean_s"]))
        rows.append(Row(f"serving_paged/{mode}/kv_peak_tokens",
                        m["kv_peak_tokens"]))
    rows.append(Row("serving_paged/kv_peak_paged_over_dense",
                    metrics["paged"]["kv_peak_tokens"]
                    / max(1, metrics["dense"]["kv_peak_tokens"]),
                    note="resident-KV saving from block-table residency"))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace-file", type=str, default=None,
                    help="replay a recorded JSON trace instead of the "
                         "synthetic skewed-length sweep")
    args = ap.parse_args()
    t0 = time.time()
    emit("serving_paged", run(trace_file=args.trace_file),
         time.time() - t0)
