"""Pallas kernel micro-benchmarks (interpret mode vs jnp reference).

CPU wall-clock of interpret-mode Pallas is NOT a TPU performance statement —
what matters here is (a) correctness against the ref.py oracle and (b) the
chosen block mappings (the TPU-native analogue of SNAKE's logical array
shapes), which are printed as derived metrics.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.kernels import ops, ref


def run() -> List[Row]:
    rows: List[Row] = []
    key = jax.random.PRNGKey(0)

    # snake decode_gemm: shape-adaptive small-M GEMM
    for m, n, k in ((8, 2048, 1024), (32, 4096, 2048)):
        ka, kb = jax.random.split(jax.random.fold_in(key, m))
        a = jax.random.normal(ka, (m, k), jnp.float32)
        b = jax.random.normal(kb, (k, n), jnp.float32)
        out = ops.decode_gemm(a, b, interpret=True)
        want = ref.decode_gemm_ref(a, b)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        rows.append(Row(f"kernels/decode_gemm_{m}x{n}x{k}_maxerr", err,
                        note="interpret-mode vs jnp oracle"))
        mp = ops.decode_gemm_mapping(m, n, k, jnp.float32)
        rows.append(Row(f"kernels/decode_gemm_{m}x{n}x{k}_block_n",
                        float(mp.block_n),
                        note=f"dataflow={mp.dataflow}"))

    # flash decode attention
    b_, s, hkv, g, d = 2, 1024, 2, 4, 64
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b_, hkv * g, d), jnp.float32)
    kc = jax.random.normal(kk, (b_, s, hkv, d), jnp.float32)
    vc = jax.random.normal(kv, (b_, s, hkv, d), jnp.float32)
    lengths = jnp.array([s, s // 2], jnp.int32)
    out = ops.attention_decode(q, kc, vc, lengths, interpret=True)
    want = ref.flash_decode_ref(q, kc, vc, lengths)
    rows.append(Row("kernels/flash_decode_maxerr",
                    float(jnp.max(jnp.abs(out - want)))))

    # wkv6 recurrence
    bw, t, h, dh = 2, 128, 2, 32
    ks = jax.random.split(key, 5)
    r_ = jax.random.normal(ks[0], (bw, t, h, dh), jnp.float32) * 0.3
    kk_ = jax.random.normal(ks[1], (bw, t, h, dh), jnp.float32) * 0.3
    vv = jax.random.normal(ks[2], (bw, t, h, dh), jnp.float32) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (bw, t, h, dh),
                                         jnp.float32)) * 0.9
    u = jax.random.normal(ks[4], (h, dh), jnp.float32) * 0.1
    s0 = jnp.zeros((bw, h, dh, dh), jnp.float32)
    out, _ = ops.wkv6_scan(r_, kk_, vv, w, u, s0, interpret=True)
    want, _ = ref.wkv6_ref(r_, kk_, vv, w, u, s0)
    rows.append(Row("kernels/wkv6_maxerr",
                    float(jnp.max(jnp.abs(out - want)))))
    return rows
