"""Fig. 1 — (a) roofline of decode operators on 3D-stacked NMP; (b) Stratum
memory-side execution analysis.

(a) Places every LLaMA3-70B decode operator's arithmetic intensity against
the ridge points of Duplex (~8 FLOP/B), Stratum (3.7-6.7 FLOP/B) and SNAKE,
showing the share of decode FLOPs that lands in the compute-bound regime on
each substrate — the paper's motivating observation.

(b) Reproduces the Stratum (MAC-tree) execution split on LLaMA3 across batch
sizes: with double buffering, array-compute time exceeds memory-supply time,
i.e. the provisioned compute lags the available memory bandwidth.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.hw import mactree_system, snake_system
from repro.core.operators import PAPER_MODELS, layer_ops_tp
from repro.core.pipeline import decode_step

CTX = 8192 + 512
TP = 8


def run() -> List[Row]:
    rows: List[Row] = []
    spec = PAPER_MODELS["LLaMA3-70B"]
    stratum = mactree_system()
    duplex_ridge = 8.0
    snake = snake_system()

    rows.append(Row("fig1a/ridge_stratum_flop_per_byte",
                    stratum.ridge_point, paper=6.7,
                    note="paper quotes 3.7-6.7 for Stratum"))
    rows.append(Row("fig1a/ridge_duplex_flop_per_byte", duplex_ridge,
                    paper=8.0))
    rows.append(Row("fig1a/ridge_snake_flop_per_byte", snake.ridge_point))

    for batch in (8, 16, 32, 64):
        lo = layer_ops_tp(spec, batch, CTX, TP)
        ops = list(lo.projections) + list(lo.attention) + list(lo.experts)
        flops = sum(g.flops for g in ops)
        cb = sum(g.flops for g in ops
                 if g.arithmetic_intensity > stratum.ridge_point)
        rows.append(Row(f"fig1a/computebound_flop_share_b{batch}",
                        cb / flops,
                        note="share of decode FLOPs above Stratum ridge"))

    # (b) Stratum-configured MAC tree: array time vs memory-supply time.
    for batch in (8, 16, 32, 64):
        rep = decode_step(stratum, spec, batch, CTX, tp=TP)
        comp = sum(e.compute_s for e in rep.op_execs)
        mem = sum(e.memory_s for e in rep.op_execs)
        rows.append(Row(f"fig1b/stratum_array_over_memory_time_b{batch}",
                        comp / mem,
                        note=">1 means compute lags memory supply (paper)"))
    return rows
