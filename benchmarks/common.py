"""Shared benchmark plumbing.

Every ``figN_*`` module exposes ``run() -> List[Row]``; ``benchmarks.run``
times each module and prints ``name,us_per_call,derived`` CSV (one row per
reported metric) and dumps the raw rows to ``benchmarks/out/<module>.json``.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


@dataclass
class Row:
    name: str               # metric id, e.g. "fig12/speedup_vs_mactree"
    derived: float          # the reproduced number
    paper: Optional[float] = None   # the paper's value for the same cell
    note: str = ""

    def to_dict(self) -> dict:
        return {"name": self.name, "derived": self.derived,
                "paper": self.paper, "note": self.note}


def emit(module: str, rows: List[Row], elapsed_s: float) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{module}.json"), "w") as f:
        json.dump([r.to_dict() for r in rows], f, indent=1)
    us = elapsed_s * 1e6 / max(1, len(rows))
    for r in rows:
        paper = "" if r.paper is None else f"{r.paper}"
        print(f"{r.name},{us:.1f},{r.derived:.6g}"
              + (f",paper={paper}" if paper else "")
              + (f",{r.note}" if r.note else ""))


def geomean(xs) -> float:
    import numpy as np
    xs = np.asarray(list(xs), dtype=float)
    return float(np.exp(np.mean(np.log(xs))))
