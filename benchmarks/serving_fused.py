"""Fused decode loop: fusion-horizon x batch sweep on the real engine.

The per-tick engine pays a host round-trip per decoded token (dispatch,
fetch, bookkeeping); the fused engine scans K steps on device and
surfaces only at horizon boundaries.  This sweep measures what that
buys on the *decode phase*: every config submits one full batch of
page-aligned prompts (prefill is synchronous, identical serial work on
both engines, and untimed), then times the drain-to-completion decode
loop wall-clock.  Per fusion horizon and batch size:

  * decode tokens/s (wall clock, best of REPS) and speedup vs per-tick
  * host-overhead fraction of the fused ticks (host / (host+device))
  * mean realized horizon (page windows and budgets clip fuse_steps)

Prompt lengths are page multiples ({32, 64, 96}, skewed short) so the
fusion horizon opens to a full page instead of collapsing to the
nearest ragged page edge.  Token streams are asserted identical across
every fusion horizon and every rep — the fused engine is an overhead
optimization, never a decoding change.  Each config reuses one warm
engine across reps (the jit cache is per-engine); the host is shared
and single-core, so the best rep is the config's throughput and the
per-rep values are recorded for transparency.  Also writes the
acceptance artifact ``BENCH_serving_fused.json`` at the repo root
(tokens/s per config + the >=2x @ batch>=64 headline).

  PYTHONPATH=src:. python benchmarks/serving_fused.py
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import List

import numpy as np

from benchmarks.common import Row, emit
from repro.models import registry
from repro.serving.engine import EngineConfig, make_engine, make_trace

ARCH = "yi-6b"
PAGE = 32
MAX_SEQ = 224            # 7 pages: up to 96 prompt + 128 decode
MAX_NEW = 128            # decode-dominated: the loop under test is decode
SEED = 0
REPS = 3
ROOT_ARTIFACT = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_serving_fused.json")


def page_aligned_prompt_lens(n: int, seed: int) -> np.ndarray:
    """Skewed over {32, 64, 96}: mostly short, a long tail — but every
    length a page multiple, so lanes stay phase-locked and the horizon
    opens to the full page instead of the nearest ragged page edge."""
    rng = np.random.default_rng(seed + 1234)
    return rng.choice([PAGE, 2 * PAGE, 3 * PAGE], size=n,
                      p=[0.5, 0.3, 0.2]).astype(np.int64)


def _run_config(entry, batch: int, fuse: int, max_new: int,
                reps: int) -> dict:
    ecfg = EngineConfig(max_batch=batch, max_seq=MAX_SEQ,
                        max_new_tokens=max_new, paged=True,
                        page_size=PAGE, fuse_steps=fuse)
    eng = make_engine(entry, ecfg)
    # warm every jit bucket outside the timed region: one prompt per
    # length (prefill compiles per length) + a full decode (the fused
    # scan and per-tick step compile per batch / horizon bucket)
    warm = make_trace(entry.config.vocab, rate_req_s=1e6, n_requests=3,
                      prompt_len=0,
                      prompt_lens=np.array([PAGE, 2 * PAGE, 3 * PAGE]),
                      seed=99)
    eng.run_trace(warm)
    plens = page_aligned_prompt_lens(batch, SEED)
    tok_s, tokens = [], None
    for _ in range(reps):
        eng.completed.clear()
        eng.reset_fused_counters()
        reqs = make_trace(entry.config.vocab, rate_req_s=1e6,
                          n_requests=batch, prompt_len=0,
                          prompt_lens=plens, seed=SEED)
        for r in reqs:                       # synchronous prefill, untimed
            assert eng.submit(r), "one wave must fit the batch"
        t0 = time.perf_counter()
        while eng.busy():                    # the decode loop under test
            eng.tick()
        wall = time.perf_counter() - t0
        decoded = sum(len(r.tokens_out) for r in eng.completed)
        tok_s.append(decoded / wall)
        rep_tokens = {r.rid: list(r.tokens_out) for r in eng.completed}
        assert tokens is None or rep_tokens == tokens, \
            "decoding must be deterministic across reps"
        tokens = rep_tokens
    fr = eng.fused_report()
    return {"tokens_per_s": max(tok_s), "tokens_per_s_reps": tok_s,
            "_tokens": tokens, "fused_ticks": fr.get("fused_ticks", 0),
            "fused_steps_mean": fr.get("fused_steps_mean", 0.0),
            "host_frac": fr.get("host_frac", 0.0)}


def run(smoke: bool = False) -> List[Row]:
    entry = registry.get(ARCH, reduced=True)
    batches = (8,) if smoke else (8, 64)
    fuses = (1, 8) if smoke else (1, 8, 32)
    max_new = 32 if smoke else MAX_NEW
    reps = 1 if smoke else REPS
    rows: List[Row] = []
    artifact = {"arch": ARCH, "page_size": PAGE, "max_new": max_new,
                "reps": reps, "measured": "decode-phase wall clock",
                "smoke": smoke, "configs": {}}
    for batch in batches:
        base = None
        for fuse in fuses:
            m = _run_config(entry, batch, fuse, max_new, reps)
            tag = f"b{batch}/fuse{fuse}"
            if fuse == fuses[0]:
                base = m
            else:
                assert m["_tokens"] == base["_tokens"], (
                    f"{tag}: fused tokens diverged from per-tick")
            speedup = m["tokens_per_s"] / max(base["tokens_per_s"], 1e-12)
            rows.append(Row(f"serving_fused/{tag}/decode_tokens_per_s",
                            m["tokens_per_s"]))
            rows.append(Row(f"serving_fused/{tag}/speedup_vs_per_tick",
                            speedup))
            rows.append(Row(f"serving_fused/{tag}/host_frac",
                            m["host_frac"]))
            rows.append(Row(f"serving_fused/{tag}/fused_steps_mean",
                            m["fused_steps_mean"]))
            artifact["configs"][tag] = {
                "decode_tokens_per_s": m["tokens_per_s"],
                "decode_tokens_per_s_reps": m["tokens_per_s_reps"],
                "speedup_vs_per_tick": speedup,
                "fused_ticks": m["fused_ticks"],
                "fused_steps_mean": m["fused_steps_mean"],
                "host_frac": m["host_frac"],
                "tokens_identical_to_per_tick": fuse == fuses[0] or
                m["_tokens"] == base["_tokens"],
            }
    if not smoke:
        headline = artifact["configs"]["b64/fuse32"]["speedup_vs_per_tick"]
        artifact["headline_speedup_b64"] = headline
        rows.append(Row("serving_fused/headline_speedup_b64", headline,
                        note="fused(32) vs per-tick decode at batch 64"))
        # acceptance artifact: full sweeps only (smoke must not clobber)
        with open(ROOT_ARTIFACT, "w") as f:
            json.dump(artifact, f, indent=1)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    t0 = time.time()
    emit("serving_fused", run(smoke=args.smoke), time.time() - t0)
