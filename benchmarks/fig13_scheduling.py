"""Fig. 13 — (a) multi-PU scheduling-mode distribution; (b) fixed-mode
slowdown vs the per-operator scheduler.

(a) Distribution of selected {IS-S, IS-ST, OS-S, OS-ST} across all
projection/FFN operators of LLaMA3-70B (dense) and Qwen3-30B-A3B (MoE)
over batch sizes and context lengths.  The paper reports a concentrated
distribution for the dense model (IS-S dominating) and a balanced one for
the MoE model.

(b) Forcing any single mode for every operator must never beat — and for
some (model, batch, ctx) must markedly trail — the per-operator scheduler
(paper: best fixed mode loses 1.04-1.56x on LLaMA3, 1.18-6.43x on Qwen3).
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import Row
from repro.core.hw import snake_system
from repro.core.operators import PAPER_MODELS
from repro.core.pipeline import decode_step
from repro.core.schedule import Mode

BATCHES = (8, 16, 32, 64)
CTXS = (4096, 8192, 16384)
SMOKE_BATCHES = (8, 64)      # sweep corners only — same qualitative shape
SMOKE_CTXS = (4096, 16384)
TP = 8
MODES = ("IS-S", "IS-ST", "OS-S", "OS-ST")


def run(smoke: bool = False) -> List[Row]:
    rows: List[Row] = []
    sys = snake_system()
    batches = SMOKE_BATCHES if smoke else BATCHES
    ctxs = SMOKE_CTXS if smoke else CTXS
    for model in ("LLaMA3-70B", "Qwen3-30B-A3B"):
        spec = PAPER_MODELS[model]
        hist: Dict[str, int] = {m: 0 for m in MODES}
        worst_slow = 1.0
        best_fixed_slow = None
        for b in batches:
            for ctx in ctxs:
                rep = decode_step(sys, spec, b, ctx, tp=TP)
                for ex in rep.op_execs:
                    if ex.mode in hist:
                        hist[ex.mode] += 1
                slows = []
                for m in Mode:
                    rf = decode_step(sys, spec, b, ctx, tp=TP, fixed_mode=m)
                    slows.append(rf.time_s / rep.time_s)
                worst_slow = max(worst_slow, min(slows))
                best_fixed_slow = (min(slows) if best_fixed_slow is None
                                   else min(best_fixed_slow, min(slows)))
        tot = max(1, sum(hist.values()))
        for m in MODES:
            rows.append(Row(f"fig13a/{model}/share_{m}", hist[m] / tot))
        rows.append(Row(f"fig13b/{model}/best_fixed_slowdown_min",
                        best_fixed_slow,
                        paper=1.04 if model == "LLaMA3-70B" else 1.18,
                        note="must be >= 1.0 (scheduler optimality)"))
        rows.append(Row(f"fig13b/{model}/best_fixed_slowdown_max",
                        worst_slow,
                        paper=1.56 if model == "LLaMA3-70B" else 6.43))
    return rows
