"""Prefill/decode disaggregation: tier-ratio sweep vs. colocated.

The workload disaggregation exists for: long shared-prefix prompts with
near-simultaneous arrivals.  Colocated replicas interleave chunked
prefill with resident decodes, so every arriving prompt stretches the
inter-token gaps of whoever is already decoding; a tiered cluster pins
prefill to its own replicas and ships the finished KV pages across the
stack link, keeping the decode tier's token cadence clean at the cost
of one priced shipment per request.

Every cell replays the IDENTICAL trace, and greedy decode is
schedule-independent, so decoded tokens must be bit-identical between
the colocated baseline and every tier split — asserted per request.

Two sections, both written to ``benchmarks/out/serving_disagg.json``:

* real-JAX engine (reduced config, CPU-runnable): 4-replica colocated
  vs. 1P:3D / 2P:2D / 3P:1D tier splits on a long-prompt skewed trace;
  the headline assertion is 1P:3D beating colocated on p99 TPOT;
* analytical mirror (``core/serving_sim.py::simulate_cluster``): the
  paper-scale workload on the SNAKE substrate across the same tier
  ratios on the modeled clock, asserting the decode-heavy ordering
  (1P:3D < 2P:2D < 3P:1D on mean TBT) and reporting the modeled
  cross-stack shipment time.

Run directly or via ``benchmarks.run``:

  PYTHONPATH=src:. python benchmarks/serving_disagg.py [--smoke]
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional, Tuple

from benchmarks.common import Row, emit
from repro.models import registry
from repro.serving.engine import EngineConfig, make_engine, \
    make_grouped_prefix_trace
from repro.serving.router import make_cluster

ARCH = "yi-6b"
N_REQ = 12
RATE = 200.0          # near-simultaneous arrivals: maximum prefill
                      # pressure on the colocated baseline
MAX_BATCH = 4
MAX_SEQ = 128
MAX_NEW = 12
PAGE = 8
NUM_PAGES = 64        # per replica — roomy enough that paging never
                      # preempts; the contrast under test is prefill
                      # interference, not page pressure
N_GROUPS = 2
PREFIX = 64           # 8 full pages of shared system prompt per group
TAIL = 32             # long prompts: 96 tokens = 6 prefill chunks
CHUNK = 16
SKEW = 0.8
SEED = 0
TIERS: Tuple[Tuple[int, int], ...] = ((1, 3), (2, 2), (3, 1))


def _ecfg(max_new: int) -> EngineConfig:
    return EngineConfig(max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                        max_new_tokens=max_new, paged=True,
                        page_size=PAGE, num_pages=NUM_PAGES,
                        prefix_sharing=True, prefill_chunk=CHUNK)


def engine_rows(n_req: int, tiers, max_new: int) -> List[Row]:
    entry = registry.get(ARCH, reduced=True)

    def trace():
        return make_grouped_prefix_trace(
            entry.config.vocab, rate_req_s=RATE, n_requests=n_req,
            n_groups=N_GROUPS, prefix_len=PREFIX, tail_len=TAIL,
            skew=SKEW, seed=SEED)

    rows: List[Row] = []
    # -- colocated baseline: 4 mixed replicas ---------------------------
    colo = make_cluster(entry, _ecfg(max_new), 4, policy="least_loaded")
    m_colo = colo.run_trace(trace())
    base_tokens = {r.rid: r.tokens_out
                   for e in colo.engines for r in e.completed}
    assert len(base_tokens) == n_req, "colocated run dropped requests"
    rows.append(Row("serving_disagg/colocated/tbt_p99_s",
                    m_colo["tbt_p99_s"]))
    rows.append(Row("serving_disagg/colocated/e2e_p99_s",
                    m_colo["e2e_p99_s"]))
    rows.append(Row("serving_disagg/colocated/tokens_per_s",
                    m_colo["tokens_per_s"]))

    # -- tier splits on the identical trace -----------------------------
    metrics = {}
    for p, d in tiers:
        router = make_cluster(entry, _ecfg(max_new), p + d,
                              policy="least_loaded", tiers=(p, d))
        m = router.run_trace(trace())
        toks = {r.rid: r.tokens_out
                for e in router.engines for r in e.completed}
        assert toks == base_tokens, \
            f"{p}P:{d}D changed decoded tokens vs. colocated"
        assert m["shipments"] == n_req, \
            f"{p}P:{d}D shipped {m['shipments']} of {n_req} requests"
        metrics[(p, d)] = m
        pre = f"serving_disagg/t{p}p{d}d"
        rows.append(Row(f"{pre}/tbt_p99_s", m["tbt_p99_s"]))
        rows.append(Row(f"{pre}/e2e_p99_s", m["e2e_p99_s"]))
        rows.append(Row(f"{pre}/tokens_per_s", m["tokens_per_s"]))
        rows.append(Row(f"{pre}/shipments", m["shipments"]))
        rows.append(Row(f"{pre}/shipped_pages", m["shipped_pages"]))
        rows.append(Row(f"{pre}/ship_cost_s", m["ship_cost_s"]))
    rows.append(Row("serving_disagg/token_exact", 1.0,
                    note="all tier splits decode the colocated tokens"))

    # headline: decode-heavy split beats colocated at the decode tail
    if (1, 3) in metrics:
        best = metrics[(1, 3)]["tbt_p99_s"]
        rows.append(Row("serving_disagg/p99_1p3d_over_colo",
                        best / max(1e-9, m_colo["tbt_p99_s"]),
                        note="< 1: disaggregation wins the decode tail"))
        assert best < m_colo["tbt_p99_s"], \
            (f"1P:3D p99 TPOT {best:.4f}s did not beat colocated "
             f"{m_colo['tbt_p99_s']:.4f}s")
    return rows


def sim_rows(tiers, n_requests: int = 48) -> List[Row]:
    from repro.core.hw import snake_system
    from repro.core.operators import PAPER_MODELS
    from repro.core.serving_sim import nmp_latency_model, simulate_cluster
    spec = PAPER_MODELS["LLaMA3-70B"]
    sys = snake_system()
    lat = nmp_latency_model(sys, spec, tp=8)
    rows: List[Row] = []
    reports = {}
    for p, d in tiers:
        rep = simulate_cluster(
            lat, spec, 20.0, policy="least_loaded", n_replicas=p + d,
            n_requests=n_requests, input_len=2048, output_len=512,
            max_batch=8, prefix_sharing=True, shared_prefix_len=1536,
            n_groups=4, skew=0.3, page_size=64, num_pages=120,
            seed=SEED, tiers=(p, d), sys=sys)
        assert rep.shipments == rep.completed, \
            "sim shipped fewer requests than it completed"
        reports[(p, d)] = rep
        pre = f"serving_disagg/sim/t{p}p{d}d"
        rows.append(Row(f"{pre}/tbt_mean_s", rep.tbt_mean_s))
        rows.append(Row(f"{pre}/e2e_p99_s", rep.e2e_p99_s))
        rows.append(Row(f"{pre}/throughput_tok_s", rep.throughput_tok_s))
        rows.append(Row(f"{pre}/shipments", rep.shipments))
        rows.append(Row(f"{pre}/ship_cost_s", rep.ship_cost_s))
    ordered = sorted(reports, key=lambda t: reports[t].tbt_mean_s)
    rows.append(Row("serving_disagg/sim/best_tiers_is_1p3d",
                    1.0 if ordered[0] == (1, 3) else 0.0,
                    note="decode-heavy split wins mean TBT on the "
                         "modeled clock"))
    if len(reports) == 3:
        assert ordered == [(1, 3), (2, 2), (3, 1)], \
            f"modeled tier ordering {ordered} != decode-heavy expected"
    return rows


def run(smoke: bool = False) -> List[Row]:
    if smoke:
        rows = engine_rows(6, ((1, 3),), 6)
        rows.extend(sim_rows(((1, 3), (3, 1)), n_requests=24))
    else:
        rows = engine_rows(N_REQ, TIERS, MAX_NEW)
        rows.extend(sim_rows(TIERS))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    t0 = time.time()
    emit("serving_disagg", run(smoke=args.smoke), time.time() - t0)
