"""Multi-replica router policies on skewed shared-prefix traces.

The workload the front-end router exists for: several tenant groups, each
with its own system prompt, Zipf-skewed popularity, near-simultaneous
arrivals.  Every (replicas x policy) cell replays the IDENTICAL trace, so
decoded tokens are comparable cell-to-cell (greedy decode is
schedule-independent — asserted against the bare engine for the
1-replica router).

Per-replica page pools are deliberately tight: a policy that fragments a
group's prefix pages across replicas (round_robin) duplicates the
communal pages on every replica and pays for it in preemptions and tail
latency, while ``prefix_affinity`` routes each group to the replica whose
``PrefixIndex`` already holds its pages, so PR 2's dedup compounds.

Two sections, both written to ``benchmarks/out/serving_router.json``:

* real-JAX engine (reduced config, CPU-runnable): 1/2/4 replicas x
  policies, plus the 1-replica-router vs. bare-engine token-exactness
  cross-check;
* analytical mirror (``core/serving_sim.py::simulate_cluster``): the
  paper-scale workload (2K-in/512-out on the SNAKE substrate) under the
  same policy set, reporting per-replica utilization, p50/p99, and
  aggregate dedup.

Run directly or via ``benchmarks.run``:

  PYTHONPATH=src:. python benchmarks/serving_router.py [--smoke]
      [--trace-file trace.json]
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

from benchmarks.common import Row, emit
from repro.models import registry
from repro.serving.engine import EngineConfig, load_trace, make_engine, \
    make_grouped_prefix_trace
from repro.serving.router import make_cluster

ARCH = "yi-6b"
N_REQ = 16
RATE = 200.0          # near-simultaneous arrivals: maximum routing overlap
MAX_BATCH = 4
MAX_SEQ = 64
MAX_NEW = 24
PAGE = 8
NUM_PAGES = 22        # per replica — colocated groups fit, fragmented
                      # communal prefixes overflow into preemptions
N_GROUPS = 4
PREFIX = 24           # 3 full pages of shared system prompt per group
TAIL = 6
SKEW = 0.8
SEED = 0
REPLICAS = (1, 2, 4)
POLICIES = ("round_robin", "least_loaded", "session_affinity",
            "prefix_affinity")


def _ecfg(max_new: int) -> EngineConfig:
    return EngineConfig(max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                        max_new_tokens=max_new, paged=True,
                        page_size=PAGE, num_pages=NUM_PAGES,
                        prefix_sharing=True, prefill_chunk=8)


def engine_rows(n_req: int, replicas, policies, max_new: int,
                trace_file: Optional[str] = None) -> List[Row]:
    entry = registry.get(ARCH, reduced=True)

    def trace():
        if trace_file:
            return load_trace(trace_file, vocab=entry.config.vocab)
        return make_grouped_prefix_trace(
            entry.config.vocab, rate_req_s=RATE, n_requests=n_req,
            n_groups=N_GROUPS, prefix_len=PREFIX, tail_len=TAIL,
            skew=SKEW, seed=SEED)

    rows: List[Row] = []
    # -- 1-replica router vs. bare engine: token-exactness --------------
    eng = make_engine(entry, _ecfg(max_new))
    eng.run_trace(trace())
    base_tokens = {r.rid: r.tokens_out for r in eng.completed}
    router = make_cluster(entry, _ecfg(max_new), 1, policy="round_robin")
    router.run_trace(trace())
    got = {r.rid: r.tokens_out
           for e in router.engines for r in e.completed}
    assert got == base_tokens, \
        "1-replica router diverged from the bare engine"
    rows.append(Row("serving_router/router1_token_exact", 1.0,
                    note="1-replica router tokens == bare engine"))

    # -- replicas x policies sweep on the identical trace ----------------
    metrics = {}
    for n_rep in replicas:
        for policy in policies:
            router = make_cluster(entry, _ecfg(max_new), n_rep,
                                  policy=policy)
            m = router.run_trace(trace())
            toks = {r.rid: r.tokens_out
                    for e in router.engines for r in e.completed}
            assert toks == base_tokens, \
                f"{policy} x{n_rep} changed decoded tokens"
            metrics[(n_rep, policy)] = m
            p = f"serving_router/r{n_rep}/{policy}"
            rows.append(Row(f"{p}/tokens_per_s", m["tokens_per_s"]))
            rows.append(Row(f"{p}/e2e_p99_s", m["e2e_p99_s"]))
            rows.append(Row(f"{p}/dedup_agg", m["dedup_ratio_agg"]))
            rows.append(Row(f"{p}/preemptions", m["preemptions"]))
    for n_rep in replicas:
        if n_rep < 2 or (n_rep, "prefix_affinity") not in metrics:
            continue
        pa = metrics[(n_rep, "prefix_affinity")]
        rr = metrics[(n_rep, "round_robin")]
        p = f"serving_router/r{n_rep}"
        rows.append(Row(f"{p}/dedup_pa_over_rr",
                        pa["dedup_ratio_agg"] / max(1e-9,
                                                    rr["dedup_ratio_agg"]),
                        note="prefix_affinity dedup gain vs round_robin"))
        rows.append(Row(f"{p}/p99_pa_over_rr",
                        pa["e2e_p99_s"] / max(1e-9, rr["e2e_p99_s"]),
                        note="<= 1: affinity no worse at the tail"))
        assert pa["dedup_ratio_agg"] > rr["dedup_ratio_agg"], \
            f"prefix_affinity did not raise aggregate dedup (x{n_rep})"
    return rows


SIM_SKEW = 0.3        # group-popularity skew for the analytical sweep —
                      # mild skew keeps affinity's hot replica from
                      # queueing while still fragmenting round robin


def sim_rows(replicas, policies, n_requests: int = 48) -> List[Row]:
    from repro.core.hw import snake_system
    from repro.core.operators import PAPER_MODELS
    from repro.core.serving_sim import nmp_latency_model, simulate_cluster
    spec = PAPER_MODELS["LLaMA3-70B"]
    lat = nmp_latency_model(snake_system(), spec, tp=8)
    rows: List[Row] = []
    reports = {}
    for n_rep in replicas:
        for policy in policies:
            rep = simulate_cluster(
                lat, spec, 20.0, policy=policy, n_replicas=n_rep,
                n_requests=n_requests, input_len=2048, output_len=512,
                max_batch=8, prefix_sharing=True, shared_prefix_len=1536,
                n_groups=4, skew=SIM_SKEW, page_size=64, num_pages=120,
                seed=SEED)
            reports[(n_rep, policy)] = rep
            p = f"serving_router/sim/r{n_rep}/{policy}"
            rows.append(Row(f"{p}/throughput_tok_s",
                            rep.throughput_tok_s))
            rows.append(Row(f"{p}/e2e_p50_s", rep.e2e_p50_s))
            rows.append(Row(f"{p}/e2e_p99_s", rep.e2e_p99_s))
            rows.append(Row(f"{p}/dedup_ratio", rep.dedup_ratio))
            rows.append(Row(f"{p}/preemptions", rep.preemptions))
            rows.append(Row(f"{p}/util_min",
                            min(rep.per_replica_util)))
            rows.append(Row(f"{p}/util_max",
                            max(rep.per_replica_util)))
    for n_rep in replicas:
        if n_rep < 2 or (n_rep, "prefix_affinity") not in reports:
            continue
        pa = reports[(n_rep, "prefix_affinity")]
        rr = reports[(n_rep, "round_robin")]
        p = f"serving_router/sim/r{n_rep}"
        rows.append(Row(f"{p}/dedup_pa_over_rr",
                        pa.dedup_ratio / rr.dedup_ratio))
        rows.append(Row(f"{p}/p99_pa_over_rr",
                        pa.e2e_p99_s / rr.e2e_p99_s,
                        note="<= 1: affinity no worse at the tail"))
        assert pa.dedup_ratio > rr.dedup_ratio
        assert pa.e2e_p99_s <= rr.e2e_p99_s * 1.001
    return rows


def run(smoke: bool = False,
        trace_file: Optional[str] = None) -> List[Row]:
    if smoke:
        rows = engine_rows(8, (1, 2), ("round_robin", "prefix_affinity"),
                           6, trace_file)
        rows.extend(sim_rows((1, 2), ("round_robin", "prefix_affinity"),
                             n_requests=24))
    else:
        rows = engine_rows(N_REQ, REPLICAS, POLICIES, MAX_NEW, trace_file)
        rows.extend(sim_rows(REPLICAS, POLICIES))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace-file", type=str, default=None)
    args = ap.parse_args()
    t0 = time.time()
    emit("serving_router", run(smoke=args.smoke,
                               trace_file=args.trace_file),
         time.time() - t0)
