"""Fig. 12 — decode speedup and logic-die energy efficiency vs baselines.

All five paper models, batches 8-64, ctx 8K+512 (the paper's 8K-input /
1K-output serving point mid-generation), on the 8-device TP=8 system
(paper §6.1.3).  Baselines: Stratum-configured MAC tree, fixed 48x48 and
8x288 SAs (area-normalized, 1 GHz), and 8x H100.

Paper headline averages: 2.90x / 2.40x vs MAC tree, 2.33x / 1.05x vs 48x48,
3.00x / 1.31x vs 8x288, 11.47x / 5.74x vs GPU.
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import Row, geomean
from repro.core.gpu_model import gpu_decode_step
from repro.core.hw import fixed_sa_system, mactree_system, snake_system
from repro.core.operators import PAPER_MODELS
from repro.core.pipeline import decode_step

CTX = 8192 + 512
TP = 8
BATCHES = (8, 16, 32, 64)

PAPER = {"MAC-Tree": (2.90, 2.40), "SA-48x48": (2.33, 1.05),
         "SA-8x288": (3.00, 1.31), "GPU": (11.47, 5.74)}


def collect() -> Dict[str, Dict[str, list]]:
    systems = {"MAC-Tree": mactree_system(),
               "SA-48x48": fixed_sa_system(48, 48),
               "SA-8x288": fixed_sa_system(8, 288)}
    snake = snake_system()
    out = {k: {"speedup": [], "energy_eff": []} for k in
           list(systems) + ["GPU"]}
    per_model = {}
    for name, spec in PAPER_MODELS.items():
        per_model[name] = {}
        for b in BATCHES:
            rs = decode_step(snake, spec, b, CTX, tp=TP)
            for k, sysm in systems.items():
                r = decode_step(sysm, spec, b, CTX, tp=TP)
                out[k]["speedup"].append(r.time_s / rs.time_s)
                out[k]["energy_eff"].append(
                    r.energy.logic_die_j / rs.energy.logic_die_j)
            g = gpu_decode_step(spec, b, CTX, tp=TP)
            out["GPU"]["speedup"].append(g.time_s / rs.time_s)
            out["GPU"]["energy_eff"].append(
                g.energy_j / rs.energy.logic_die_j)
        per_model[name]["snake_ms_b64"] = rs.time_s * 1e3
        per_model[name]["snake_tok_s_b64"] = rs.tokens_per_s
    return out, per_model


def run() -> List[Row]:
    rows: List[Row] = []
    out, per_model = collect()
    for k, d in out.items():
        sp, ee = PAPER[k]
        rows.append(Row(f"fig12/speedup_vs_{k}", geomean(d["speedup"]),
                        paper=sp))
        rows.append(Row(f"fig12/energy_eff_vs_{k}", geomean(d["energy_eff"]),
                        paper=ee))
    for name, d in per_model.items():
        rows.append(Row(f"fig12/{name}/snake_tokens_per_s_b64",
                        d["snake_tok_s_b64"]))
    return rows
