"""Fig. 14 — (a) logical array-shape demand across batch sizes; (b) minimum
buffer capacities per array shape.

(a) For LLaMA3-70B and Qwen3-30B-A3B at batches 8-64, the distribution of
serpentine logical shapes the scheduler selects (the preferred shape tracks
the batch-driven M, though not strictly one-to-one — paper §6.6).

(b) Per logical shape, the minimum weight-side and activation-side buffer
capacity that sustains stall-free double-buffered execution over the
OPT-66B single-core decode tiles: elongated shapes need less weight buffer
but more activation-side buffer (clear trade-off, paper Fig. 14b).
"""
from __future__ import annotations

from typing import Dict, List

from benchmarks.common import Row
from repro.core.gemm import Dataflow, ceil_div
from repro.core.hw import FP16_BYTES, snake_system
from repro.core.operators import PAPER_MODELS, layer_ops_tp
from repro.core.pipeline import decode_step

TP = 8
CTX = 8192 + 512


def run(smoke: bool = False) -> List[Row]:
    rows: List[Row] = []
    sys = snake_system()

    # ---- (a) shape demand ---------------------------------------------------
    for model in ("LLaMA3-70B", "Qwen3-30B-A3B"):
        spec = PAPER_MODELS[model]
        hist: Dict[tuple, int] = {}
        for b in ((8, 64) if smoke else (8, 16, 32, 64)):
            rep = decode_step(sys, spec, b, CTX, tp=TP)
            for ex in rep.op_execs:
                if ex.core is not None:
                    hist[ex.core.logical_shape] = \
                        hist.get(ex.core.logical_shape, 0) + 1
        tot = max(1, sum(hist.values()))
        for shape, n in sorted(hist.items()):
            rows.append(Row(f"fig14a/{model}/share_{shape[0]}x{shape[1]}",
                            n / tot))

    # ---- (b) minimum stall-free buffers per shape ---------------------------
    # For each logical shape and each OPT-66B single-core decode tile:
    #   weight-side  = the stationary-operand panel that must be resident +
    #                  prefetched (double buffered): 2 * rows * cols * 2B
    #                  per spatial tile of the weight matrix staged at once,
    #                  scaled by the K (IS) / N (OS) panel depth;
    #   activation side = the streamed operand/partial-sum panel:
    #                  IS: rows * N_temporal (output accumulation rows)
    #                  OS: rows * K_temporal (input panel).
    spec = PAPER_MODELS["OPT-66B"]
    lo = layer_ops_tp(spec, 8, CTX, TP)
    tiles = [g.split_k(16).split_n(4) for g in lo.projections
             if g.count == 1]
    for rows_, cols in snake_system().substrate.logical_shapes():
        w_need = a_need = 0
        for t in tiles:
            # weight side: the stationary-operand boundary panel injected
            # from L/R (double buffered), proportional to the column count
            w_panel = 2 * cols * min(max(t.n, t.k), 512) * FP16_BYTES
            # activation side: the full row-boundary panel streamed per
            # temporal step (IS: output accumulation rows; OS: input rows),
            # proportional to the row count
            a_panel = 2 * rows_ * min(max(t.n, t.k), 4096) * FP16_BYTES
            w_need = max(w_need, w_panel)
            a_need = max(a_need, a_panel)
        rows.append(Row(f"fig14b/weight_buf_kib_{rows_}x{cols}",
                        w_need / 1024,
                        note="falls as the shape gets less elongated"))
        rows.append(Row(f"fig14b/act_buf_kib_{rows_}x{cols}",
                        a_need / 1024,
                        note="rises as the shape gets less elongated"))
    return rows
