"""Fig. 4 — (a) buffer->compute reallocation sweep; (b) dataflow preference.

(a) Fixed per-core area budget, OPT-66B batch 8 (the paper's most
buffer-conservative point): sweep elongated 8xC arrays from 8x128 to 8x768,
converting SRAM area into PEs using the Fig. 11 RTL calibration
(1 MAC ~ 212 bytes of SRAM area).  Reports array-compute time, exposed
memory-stall time and logic-die energy per decode step.  The paper selects
8x512: cycles fall up to there, stalls/energy rise sharply beyond.

(b) Dataflow preference: single-core tiled decode workloads of OPT-66B
(batch 8), grouped by N>K vs N<=K, executed under forced IS and OS. The
group means show IS preferred when N>K and OS when K>=N (paper Fig. 4b).
"""
from __future__ import annotations

import dataclasses
from typing import List

from benchmarks.common import Row, geomean
from repro.core.dataflow import sa_gemm
from repro.core.gemm import Dataflow
from repro.core.hw import BufferConfig, SystolicArrayConfig, snake_system
from repro.core.operators import PAPER_MODELS, layer_ops_tp
from repro.core.pipeline import decode_step

BYTES_PER_MAC = 212          # Fig. 11 area calibration: SRAM bytes <-> 1 MAC
ANCHOR_PES = 4096            # 8x512 core
ANCHOR_BYTES = 448 * 1024    # its buffer allocation (weight+act+out)
CTX = 8192 + 512
TP = 1                       # paper Fig. 4 is single-device, kernel-level


def _swept_system(cols: int):
    """SNAKE-like system whose per-core array is 8 x cols, with buffers
    resized so (PE + SRAM) area stays at the 8x512 anchor budget."""
    pes = 8 * cols
    byts = max(16 * 1024, ANCHOR_BYTES + (ANCHOR_PES - pes) * BYTES_PER_MAC)
    bufs = BufferConfig(weight=int(byts * 0.60), act=int(byts * 0.15),
                        out=int(byts * 0.25))
    base = snake_system()
    sa = dataclasses.replace(base.substrate, name=f"sa-8x{cols}",
                             phys_rows=8, phys_cols=cols, buffers=bufs,
                             logical_row_options=(8,))
    return dataclasses.replace(base, name=f"SNAKE-8x{cols}", substrate=sa)


def run() -> List[Row]:
    rows: List[Row] = []
    spec = PAPER_MODELS["OPT-66B"]

    # ---- (a) reallocation sweep -------------------------------------------
    base_time = None
    for cols in (128, 256, 384, 512, 640, 768):
        sys = _swept_system(cols)
        rep = decode_step(sys, spec, 8, CTX, tp=TP)
        comp = sum(e.compute_s for e in rep.op_execs)
        stall = sum(max(0.0, e.memory_s - e.compute_s)
                    for e in rep.op_execs)
        if cols == 128:
            base_time = rep.time_s
        rows.append(Row(f"fig4a/time_8x{cols}_norm", rep.time_s / base_time))
        rows.append(Row(f"fig4a/stall_share_8x{cols}",
                        stall / (comp + stall) if comp + stall else 0.0))
        rows.append(Row(f"fig4a/energy_8x{cols}_j",
                        rep.energy.logic_die_j))
    # the paper's chosen configuration must be the fastest of the sweep
    times = {c: decode_step(_swept_system(c), spec, 8, CTX, tp=TP).time_s
             for c in (128, 256, 384, 512, 640, 768)}
    best = min(times, key=times.get)
    rows.append(Row("fig4a/best_cols", float(best), paper=512.0))

    # ---- (b) dataflow preference by N-vs-K group ---------------------------
    # §3.1's first-order rule concerns tile-switching / data-(re)loading
    # amortization, so it is measured on the conventional (un-pipelined)
    # execution model: preferred dataflow = argmin (cycles, tiles, dram).
    lo = layer_ops_tp(spec, 8, CTX, TP)
    sa: SystolicArrayConfig = snake_system().substrate
    pus, cores = 16, 4
    groups = {"ngtk": [], "klen": []}
    for g in lo.projections:
        if g.count != 1:
            continue
        # single-core tiles after the IS-S and OS-S spatial splits
        for tile in (g.split_k(pus).split_n(cores),
                     g.split_n(pus).split_k(cores)):
            e_is = sa_gemm(tile, 8, 512, Dataflow.IS, sa.buffers, False)
            e_os = sa_gemm(tile, 8, 512, Dataflow.OS, sa.buffers, False)
            best = min((e_is, e_os),
                       key=lambda e: (e.array_cycles, e.spatial_tiles,
                                      e.dram_bytes))
            key = "ngtk" if tile.n > tile.k else "klen"
            groups[key].append(1.0 if best.dataflow == Dataflow.IS else 0.0)
    rows.append(Row("fig4b/is_preferred_share_ngtk",
                    sum(groups["ngtk"]) / max(1, len(groups["ngtk"])),
                    note="N>K group: high -> IS preferred (paper)"))
    rows.append(Row("fig4b/is_preferred_share_klen",
                    sum(groups["klen"]) / max(1, len(groups["klen"])),
                    note="N<=K group: low -> OS preferred (paper)"))
    return rows
