"""Fig. 11 — PU-level area breakdown, compute-area efficiency, power.

Pure calibration reproduction: the paper's RTL synthesis found that under
the same 2.35 mm^2 PU budget the MAC tree fits 16x16x16 = 4,096 MACs, a
conventional SA + vector core fits 4 x 48x48 = 9,216, and SNAKE fits
4 x 64x64 = 16,384 (2.25x / 4.00x compute-area efficiency), with SNAKE's
buffering share shrinking from 53.6% to 28.1%.  The energy model must land
on the reported 61.8 W logic-die power breakdown at the 800 MHz thermal
operating point (38.5 matrix / 14.2 vector / 4.4 control / 4.8 NoC).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import Row
from repro.core.energy import peak_power_breakdown
from repro.core.hw import area_model, snake_system

PAPER_POWER = {"matrix_w": 38.5, "vector_w": 14.2, "ctrl_w": 4.4,
               "noc_w": 4.8}


def run() -> List[Row]:
    rows: List[Row] = []
    am = area_model()
    rows.append(Row("fig11/cae_sa_vc_vs_mactree",
                    am["SA+VectorCore"]["compute_area_efficiency"],
                    paper=2.25))
    rows.append(Row("fig11/cae_snake_vs_mactree",
                    am["SNAKE"]["compute_area_efficiency"], paper=4.00))
    rows.append(Row("fig11/snake_buffer_area_share",
                    am["SNAKE"]["breakdown"]["buffers"], paper=0.281))
    rows.append(Row("fig11/sa_vc_buffer_area_share",
                    am["SA+VectorCore"]["breakdown"]["buffers"], paper=0.536))
    rows.append(Row("fig11/snake_vector_area_share",
                    am["SNAKE"]["breakdown"]["vector"], paper=0.088))

    pw = peak_power_breakdown(snake_system())
    total = sum(pw.values()) + pw.pop("sram_w", 0.0) * 0  # sram folded below
    for k, v in pw.items():
        paper = PAPER_POWER.get(k)
        rows.append(Row(f"fig11/power_{k}", v, paper=paper))
    rows.append(Row("fig11/power_total_w",
                    total, paper=61.8,
                    note="logic-die power at the 800 MHz operating point"))
    return rows
