"""TPU dry-run roofline table (deliverable g) — reads the JSON records the
multi-pod dry-run wrote and prints the three-term roofline per (arch x
shape) cell on the single-pod 16x16 mesh, plus the dominant bottleneck and
the MODEL_FLOPS / HLO_FLOPs usefulness ratio.

Run the sweep first:
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""
from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import Row

RESULTS = os.environ.get("REPRO_DRYRUN_OUT", "benchmarks/dryrun_results")


def load_records(pattern: str = "dryrun_single_all_all.json") -> list:
    path = os.path.join(RESULTS, pattern)
    paths = [path] if os.path.exists(path) else \
        sorted(glob.glob(os.path.join(RESULTS, "dryrun_single_*.json")))
    best = {}
    for p in paths:
        try:
            for r in json.load(open(p)):
                key = (r.get("arch"), r.get("shape"))
                if r.get("status") == "OK" or key not in best:
                    best[key] = r
        except Exception:
            continue
    return list(best.values())


def run() -> List[Row]:
    rows: List[Row] = []
    recs = load_records()
    n_ok = n_skip = n_fail = 0
    for r in sorted(recs, key=lambda x: (str(x.get("arch")),
                                         str(x.get("shape")))):
        tag = f"{r.get('arch')}/{r.get('shape')}"
        st = str(r.get("status"))
        if st.startswith("SKIP"):
            n_skip += 1
            rows.append(Row(f"roofline/{tag}/skipped", 1.0, note=st[:40]))
            continue
        if st != "OK":
            n_fail += 1
            rows.append(Row(f"roofline/{tag}/failed", 1.0, note=st[:60]))
            continue
        n_ok += 1
        tc, tm, tx = (r["t_compute_s"], r["t_memory_s"],
                      r["t_collective_s"])
        dom = max(tc, tm, tx)
        rows.append(Row(f"roofline/{tag}/t_compute_s", tc))
        rows.append(Row(f"roofline/{tag}/t_memory_s", tm))
        rows.append(Row(f"roofline/{tag}/t_collective_s", tx))
        rows.append(Row(f"roofline/{tag}/dominant_term_s", dom,
                        note=r["bottleneck"]))
        uf = r.get("useful_flops_fraction")
        if uf is not None:
            rows.append(Row(f"roofline/{tag}/useful_flops_fraction", uf))
    rows.append(Row("roofline/cells_ok", float(n_ok)))
    rows.append(Row("roofline/cells_skipped", float(n_skip)))
    rows.append(Row("roofline/cells_failed", float(n_fail)))
    return rows
