"""Prefix sharing vs. plain paged serving on shared-system-prompt traces.

The workload prefix sharing exists for: every request carries the same
system-prompt prefix plus a unique tail.  The refcounted trie maps each
request's leading full prompt pages onto the pages already resident, so
resident KV grows with *unique* tokens, not total tokens — the dedup
ratio (logical/physical pages) is the admissible-batch multiplier per
resident page on the 3D-stacked substrate.

Two sections, both written to ``benchmarks/out/serving_shared.json``:

* real-JAX engine (reduced config, CPU-runnable): identical traces swept
  over common-prefix lengths, paged (sharing off) vs. shared (sharing on),
  with a token-equality cross-check between the two modes;
* analytical mirror (``core/serving_sim``): the paper-scale workload
  (8K-in/1K-out on the SNAKE substrate) swept over 0/256/1024-token
  shared prefixes.

Run directly or via ``benchmarks.run``:

  PYTHONPATH=src:. python benchmarks/serving_shared.py [--smoke]
"""
from __future__ import annotations

import argparse
import time
from typing import List, Optional

from benchmarks.common import Row, emit
from repro.models import registry
from repro.serving.engine import EngineConfig, load_trace, make_engine, \
    make_shared_prefix_trace

ARCH = "yi-6b"
N_REQ = 10
RATE = 200.0          # near-simultaneous arrivals: maximum sharing overlap
MAX_BATCH = 4
MAX_SEQ = 96
MAX_NEW = 6
PAGE = 8
TAIL = 6              # unique per-request suffix tokens
SEED = 0
PREFIXES = (0, 16, 48)          # common system-prompt tokens (0/2/6 pages)
SIM_PREFIXES = (0, 256, 1024)   # paper-scale analytical sweep


def engine_rows(n_req: int, prefixes, max_new: int,
                trace_file: Optional[str] = None) -> List[Row]:
    entry = registry.get(ARCH, reduced=True)
    rows: List[Row] = []
    if trace_file:
        # a recorded trace has its own (unknown) prefix structure: run
        # the paged-vs-shared comparison once, labeled as a replay,
        # instead of pretending to sweep prefix lengths
        prefixes = ("replay",)
    for prefix_len in prefixes:
        tag = "replay" if trace_file else f"p{prefix_len}"
        metrics, tokens = {}, {}
        for mode in ("paged", "shared"):
            ecfg = EngineConfig(max_batch=MAX_BATCH, max_seq=MAX_SEQ,
                                max_new_tokens=max_new, paged=True,
                                page_size=PAGE,
                                prefix_sharing=(mode == "shared"))
            eng = make_engine(entry, ecfg)
            if trace_file:
                reqs = load_trace(trace_file, vocab=entry.config.vocab)
            else:
                reqs = make_shared_prefix_trace(
                    entry.config.vocab, rate_req_s=RATE, n_requests=n_req,
                    prefix_len=prefix_len, tail_len=TAIL, seed=SEED)
            m = eng.run_trace(reqs)
            metrics[mode] = m
            tokens[mode] = {r.rid: r.tokens_out for r in eng.completed}
            p = f"serving_shared/{tag}/{mode}"
            rows.append(Row(f"{p}/tokens_per_s", m["tokens_per_s"]))
            rows.append(Row(f"{p}/kv_peak_tokens", m["kv_peak_tokens"]))
        assert tokens["paged"] == tokens["shared"], \
            f"sharing changed decoded tokens ({tag})"
        sm = metrics["shared"]
        p = f"serving_shared/{tag}"
        rows.append(Row(f"{p}/dedup_ratio", sm["kv_dedup_ratio_peak"],
                        note="peak logical/physical pages with sharing"))
        rows.append(Row(f"{p}/cow_forks", sm["cow_forks"]))
        rows.append(Row(
            f"{p}/kv_peak_shared_over_paged",
            sm["kv_peak_tokens"] / max(1, metrics["paged"]
                                       ["kv_peak_tokens"]),
            note="resident-KV saving from refcounted prefix pages"))
    return rows


def sim_rows() -> List[Row]:
    from repro.core.hw import snake_system
    from repro.core.operators import PAPER_MODELS
    from repro.core.serving_sim import nmp_latency_model, simulate_serving
    spec = PAPER_MODELS["LLaMA3-70B"]
    lat = nmp_latency_model(snake_system(), spec, tp=8)
    rows: List[Row] = []
    base = simulate_serving(lat, spec, 0.5, system="SNAKE", n_requests=32,
                            cache_mode="paged")
    rows.append(Row("serving_shared/sim/kv_peak_tokens_paged",
                    base.kv_peak_tokens))
    for prefix_len in SIM_PREFIXES:
        rep = simulate_serving(lat, spec, 0.5, system="SNAKE",
                               n_requests=32, cache_mode="paged",
                               prefix_sharing=True,
                               shared_prefix_len=prefix_len)
        p = f"serving_shared/sim/p{prefix_len}"
        rows.append(Row(f"{p}/dedup_ratio", rep.dedup_ratio))
        rows.append(Row(f"{p}/kv_peak_shared_over_paged",
                        rep.kv_peak_tokens
                        / max(1, base.kv_peak_tokens)))
    return rows


def run(smoke: bool = False,
        trace_file: Optional[str] = None) -> List[Row]:
    if smoke:
        rows = engine_rows(4, (0, 16), 4, trace_file)
    else:
        rows = engine_rows(N_REQ, PREFIXES, MAX_NEW, trace_file)
    rows.extend(sim_rows())
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace-file", type=str, default=None,
                    help="replay a recorded JSON trace instead of the "
                         "synthetic shared-prefix sweep")
    args = ap.parse_args()
    t0 = time.time()
    emit("serving_shared", run(smoke=args.smoke,
                               trace_file=args.trace_file),
         time.time() - t0)
