"""End-to-end serving driver: batched Poisson requests through the
continuous-batching engine on two architecture families (a GQA dense LM and
the attention-free RWKV6), with the flash-decode Pallas kernel optionally in
the attention path.

``--paged`` switches both engines to the block-table paged KV cache (the
RWKV state has no sequence axis, so its paged cache degenerates to the
slot-dense layout and the comparison shows zero pages); ``--prefill-chunk``
co-schedules Sarathi prefill chunks with the hot decode batch (written
directly into pages on the paged engine); ``--share`` turns on refcounted
prefix sharing and drives a shared-system-prompt trace (16 common + 8
unique tokens per request) so the dedup ratio is visible.

``--replicas N`` (with ``--share``) stands the dense-LM engine up N times
behind the front-end router and dispatches a 2-group multi-tenant trace
under ``--policy`` — ``prefix_affinity`` keeps each group's pages on one
replica, so the aggregate dedup compounds instead of fragmenting.

``--fuse-steps K`` fuses up to K decode steps per device-resident tick
and ``--trace-out FILE`` records a Perfetto timeline of any of the runs
(plus a lossless ``.jsonl`` event log and a printed phase report).

  PYTHONPATH=src python examples/serve_decode.py
  PYTHONPATH=src python examples/serve_decode.py --pallas --paged
  PYTHONPATH=src python examples/serve_decode.py --paged --share
  PYTHONPATH=src python examples/serve_decode.py --share --replicas 2 \
      --policy prefix_affinity
  PYTHONPATH=src python examples/serve_decode.py --share --fuse-steps 4 \
      --trace-out /tmp/serve.trace.json
"""
import argparse

from repro.models import registry
from repro.serving.engine import (EngineConfig, make_engine,
                                  make_grouped_prefix_trace,
                                  make_shared_prefix_trace)
from repro.serving.router import POLICIES, make_cluster


def _make_tracer(args):
    if not args.trace_out:
        return None
    from repro.obs import Tracer
    return Tracer()


def _dump_trace(tracer, args):
    if tracer is None:
        return
    from repro.obs import export_perfetto, save_jsonl, trace_report
    export_perfetto(tracer.events, args.trace_out)
    save_jsonl(tracer.events, args.trace_out + ".jsonl")
    rep = trace_report(tracer.events)
    print(f"[serve_decode] trace: {len(tracer.events)} events -> "
          f"{args.trace_out}")
    print(f"[serve_decode] phases: {rep['phases']} "
          f"makespan={rep['makespan_s']:.3f}s")


def run_cluster(args):
    entry = registry.get("yi-6b", reduced=True)
    ecfg = EngineConfig(max_batch=4, max_seq=64, max_new_tokens=12,
                        use_pallas_decode=args.pallas, paged=True,
                        page_size=16, prefix_sharing=True,
                        fuse_steps=args.fuse_steps,
                        prefill_chunk=args.prefill_chunk)
    router = make_cluster(entry, ecfg, args.replicas, policy=args.policy)
    tracer = _make_tracer(args)
    if tracer is not None:
        router.set_tracer(tracer)
    reqs = make_grouped_prefix_trace(entry.config.vocab,
                                     rate_req_s=args.rate,
                                     n_requests=args.n_requests,
                                     n_groups=2, prefix_len=16, tail_len=8,
                                     skew=0.5)
    m = router.run_trace(reqs)
    print(f"[serve_decode] yi-6b x{args.replicas} ({args.policy})  "
          f"{m['requests']} reqs  {m['decoded_tokens']} toks  "
          f"{m['tokens_per_s']:.1f} tok/s  "
          f"p99 e2e {m['e2e_p99_s'] * 1e3:.0f}ms  "
          f"dedup x{m['dedup_ratio_agg']:.2f}")
    for rep in m["per_replica"]:
        print(f"[serve_decode]   replica {rep['replica']}: "
              f"{rep['requests']} reqs  {rep['decoded_tokens']} toks  "
              f"dedup x{rep['dedup_ratio_peak']:.2f}")
    _dump_trace(tracer, args)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pallas", action="store_true")
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--share", action="store_true",
                    help="prefix sharing on a shared-prompt trace "
                         "(implies --paged)")
    ap.add_argument("--prefill-chunk", type=int, default=None)
    ap.add_argument("--fuse-steps", type=int, default=1,
                    help="decode steps fused per device-resident tick "
                         "(needs --paged or --share)")
    ap.add_argument("--n-requests", type=int, default=10)
    ap.add_argument("--rate", type=float, default=6.0)
    ap.add_argument("--replicas", type=int, default=1,
                    help="with --share: replicas behind the router")
    ap.add_argument("--policy", choices=POLICIES, default="prefix_affinity")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write a Perfetto timeline (+ .jsonl event log) "
                         "of the run")
    args = ap.parse_args()
    if args.replicas > 1 and not args.share:
        ap.error("--replicas needs --share (the router demo drives a "
                 "grouped shared-prefix trace)")
    if args.fuse_steps > 1 and not (args.paged or args.share):
        ap.error("--fuse-steps needs --paged or --share (the fused scan "
                 "runs on the block-table decode step)")

    if args.share and args.replicas > 1:
        run_cluster(args)
        return

    tracer = _make_tracer(args)
    for replica, arch in enumerate(("yi-6b", "rwkv6-7b")):
        entry = registry.get(arch, reduced=True)
        ecfg = EngineConfig(max_batch=4, max_seq=64, max_new_tokens=12,
                            use_pallas_decode=args.pallas,
                            paged=args.paged or args.share, page_size=16,
                            prefix_sharing=args.share,
                            fuse_steps=(args.fuse_steps
                                        if args.paged or args.share else 1),
                            prefill_chunk=args.prefill_chunk)
        eng = make_engine(entry, ecfg)
        if tracer is not None:
            eng.set_tracer(tracer, replica=replica)
        if args.share:
            reqs = make_shared_prefix_trace(entry.config.vocab,
                                            rate_req_s=args.rate,
                                            n_requests=args.n_requests,
                                            prefix_len=16, tail_len=8)
            m = eng.run_trace(reqs)
        else:
            m = eng.run_workload(rate_req_s=args.rate,
                                 n_requests=args.n_requests, prompt_len=24)
        extra = (f"  dedup x{m['kv_dedup_ratio_peak']:.2f} "
                 f"cow {m['cow_forks']}" if args.share else "")
        print(f"[serve_decode] {arch:10s} {m['requests']} reqs  "
              f"{m['decoded_tokens']} toks  {m['tokens_per_s']:.1f} tok/s  "
              f"TBT mean {m['tbt_mean_s'] * 1e3:.1f}ms "
              f"p99 {m['tbt_p99_s'] * 1e3:.1f}ms  "
              f"kv={m['kv_mode']} peak {m['kv_peak_tokens']} tok{extra}")
    _dump_trace(tracer, args)


if __name__ == "__main__":
    main()
