"""End-to-end training driver: a ~100M-parameter dense LM trained for a few
hundred steps with the full production path — sharded train step, ZeRO-1
optimizer states, atomic checkpoints, resume, straggler detection.

Default sizing (`--size 10m`) finishes on this CPU container in minutes;
`--size 100m` is the full deliverable sizing for a beefier host or TPU.

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300
"""
import argparse
import dataclasses

from repro.launch.train import train
from repro.models import registry

SIZES = {
    # ~9.8M params: d=256, 6L, ff=1024, vocab=8192
    "10m": dict(d_model=256, num_layers=6, d_ff=1024, vocab=8192,
                num_q_heads=8, num_kv_heads=4, d_head=32),
    # ~101M params: d=640, 12L, ff=2560, vocab=16384
    "100m": dict(d_model=640, num_layers=12, d_ff=2560, vocab=16384,
                 num_q_heads=10, num_kv_heads=5, d_head=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=list(SIZES), default="10m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # register a custom-size dense config under the yi-6b (llama-arch) family
    base = registry.get_config("yi-6b")
    cfg = dataclasses.replace(base, name=f"lm-{args.size}",
                              max_seq=args.seq, dtype="float32",
                              **SIZES[args.size])
    entry = registry.from_config(cfg)
    import jax
    n = sum(p.size for p in jax.tree.leaves(
        jax.eval_shape(lambda: entry.module.init(jax.random.PRNGKey(0),
                                                 cfg, 1))))
    print(f"[train_lm] size={args.size}: {n / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.global_batch} x seq {args.seq}")

    # monkey-patch registry resolution so launch.train can drive it
    registry._CUSTOM = {cfg.name: entry}
    orig_get = registry.get

    def patched_get(name, reduced=False, **over):
        if name == cfg.name:
            return entry
        return orig_get(name, reduced=reduced, **over)

    registry.get = patched_get
    try:
        out = train(cfg.name, steps=args.steps,
                    global_batch=args.global_batch, seq=args.seq,
                    ckpt_dir=args.ckpt_dir, save_every=50, reduced=False,
                    log_every=10)
    finally:
        registry.get = orig_get
    print(f"[train_lm] loss {out['first_loss']:.3f} -> "
          f"{out['final_loss']:.3f} over {out['steps']} steps "
          f"({out['wall_s']:.0f}s)")
    assert out["final_loss"] < out["first_loss"], "loss must decrease"


if __name__ == "__main__":
    main()
