"""Quickstart — the two halves of the repo in ~60 seconds.

1. The paper's evaluation stack: schedule one LLaMA3-70B decode step on the
   SNAKE 3D-NMP system vs the Stratum-configured MAC-tree baseline.
2. The TPU-native half: run a reduced yi-6b end to end (one train step, a
   prefill and a few decode steps) on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hw import mactree_system, snake_system
from repro.core.operators import PAPER_MODELS
from repro.core.pipeline import decode_step
from repro.models import registry
from repro.optim import adamw as axw


def nmp_half():
    print("=== 1. NMP substrate study (paper reproduction) ===")
    spec = PAPER_MODELS["LLaMA3-70B"]
    for sys in (snake_system(), mactree_system()):
        rep = decode_step(sys, spec, batch=32, ctx=8704, tp=8)
        print(f"{sys.name:10s} decode step {rep.time_s * 1e3:7.2f} ms "
              f"({rep.tokens_per_s:8.0f} tok/s)  "
              f"logic-die {rep.energy.logic_die_j:6.3f} J  "
              f"modes={rep.mode_histogram()}")
    snake = decode_step(snake_system(), spec, 32, 8704, tp=8)
    mac = decode_step(mactree_system(), spec, 32, 8704, tp=8)
    print(f"SNAKE speedup vs MAC tree: {mac.time_s / snake.time_s:.2f}x  "
          f"(paper avg across models/batches: 2.90x)")


def tpu_half():
    print("\n=== 2. JAX framework (reduced yi-6b on CPU) ===")
    entry = registry.get("yi-6b", reduced=True)
    cfg = entry.config
    params = entry.module.init(jax.random.PRNGKey(0), cfg, 1)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch=yi-6b(reduced) params={n_params / 1e6:.1f}M")

    # one train step
    ocfg = axw.AdamWConfig()
    opt = axw.init(params, ocfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (2, 64)).astype(np.int32)
    batch = {"tokens": toks, "labels": np.roll(toks, -1, axis=1)}
    loss, grads = jax.value_and_grad(
        lambda p: entry.module.loss(p, cfg, batch, tp=1))(params)
    params, opt, metrics = axw.update(grads, opt, params, ocfg)
    print(f"train: loss={float(loss):.3f} "
          f"grad_norm={float(metrics['grad_norm']):.3f}")

    # prefill + 4 decode steps
    logits, cache = entry.module.prefill(params, cfg,
                                         jnp.asarray(toks[:, :32]),
                                         tp=1, max_seq=64)
    out = []
    tok = jnp.argmax(logits[:, : cfg.vocab], -1).astype(jnp.int32)
    for _ in range(4):
        logits, cache = entry.module.decode_step(params, cfg, tok, cache,
                                                 tp=1)
        tok = jnp.argmax(logits[:, : cfg.vocab], -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    print(f"decode: generated {np.stack(out, 1).tolist()}")


if __name__ == "__main__":
    nmp_half()
    tpu_half()
