"""Schedule explorer — walk the paper's §5 scheduling space for any model.

For a chosen (model, batch, ctx):
  * evaluates all four multi-PU partitioning modes per projection operator
    on the SNAKE system and prints the per-mode times + the winner,
  * shows the TPU-side translation: the partition planner's column/row
    (OS-S/IS-S) choice and collective bytes per GEMM.

  PYTHONPATH=src python examples/schedule_explorer.py \
      --model Qwen3-30B-A3B --batch 16 --ctx 8192
"""
import argparse

from repro.core.hw import snake_system
from repro.core.operators import PAPER_MODELS, layer_ops_tp
from repro.core.schedule import mode_candidates
from repro.distributed import planner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=list(PAPER_MODELS),
                    default="Qwen3-30B-A3B")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ctx", type=int, default=8192)
    ap.add_argument("--tp", type=int, default=8)
    args = ap.parse_args()

    spec = PAPER_MODELS[args.model]
    sys = snake_system()
    lo = layer_ops_tp(spec, args.batch, args.ctx, args.tp)

    print(f"=== {args.model} batch={args.batch} ctx={args.ctx} tp={args.tp}"
          f" on {sys.name} ===")
    print(f"{'operator':18s} {'M':>5s} {'N':>7s} {'K':>7s} | "
          f"{'IS-S':>8s} {'IS-ST':>8s} {'OS-S':>8s} {'OS-ST':>8s} | best")
    for g in lo.projections:
        if g.count != 1:
            continue
        cands = mode_candidates(sys, g)
        times = {c.mode: c.time_s * 1e6 for c in cands}
        best = min(cands, key=lambda c: c.time_s)
        print(f"{g.name:18s} {g.m:5d} {g.n:7d} {g.k:7d} | "
              + " ".join(f"{times[m]:8.2f}" for m in
                         ("IS-S", "IS-ST", "OS-S", "OS-ST"))
              + f" | {best.mode}")

    print("\n--- TPU partition plan (planner.py: column=OS-S row=IS-S) ---")
    plans = []
    from repro.core.operators import _ROW_PARALLEL
    for g in lo.projections:
        if g.count != 1:
            continue
        leaf = g.name.split(".")[-1]
        # reconstruct the full (unsharded) GEMM dims from the per-device op
        if leaf in _ROW_PARALLEL:
            full_n, full_k = g.n, g.k * args.tp
        else:
            full_n, full_k = g.n * args.tp, g.k
        plans.append(planner.plan_projection(
            g.name, g.m, full_n, full_k, args.tp,
            consumer_contracts_n=leaf in ("up_gate", "up")))
    plans.append(planner.plan_decode_attention(
        args.batch, args.ctx, spec.num_q_heads, spec.d_head, args.tp))
    print(planner.describe(plans))


if __name__ == "__main__":
    main()
