"""Fixture: a scheduler metric emitted without a contract entry (PR 9).

``metrics`` returns the legitimate serving keys plus ``decode_watts`` —
a metric never registered in ``repro.obs.metrics``'s
``SCHEDULER_METRIC_CONTRACT``.  ``mirror_drift.check_metrics_registered``
must flag the undeclared key (``unregistered-metric``): a metric the
registry never learns about is invisible to the exporters and the
mirror checker's report diffing, exactly the drift class PR 9's
contract exists to catch.
"""


class Scheduler:
    """Minimal stand-in — only the ``metrics`` surface is analyzed."""

    def metrics(self, wall: float, t0: float) -> dict:
        return {"wall_s": wall,
                "requests": 0,
                "decoded_tokens": 0,
                "tokens_per_s": 0.0,
                # drifted: emitted but never declared in the contract
                "decode_watts": 0.0}
