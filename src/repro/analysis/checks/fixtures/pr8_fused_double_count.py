"""Regression fixture: PR-8 fused tick double-counting a finished lane.

Stripped-down copy of the fused-tick bookkeeping from
``repro.serving.engine.PagedServingEngine._apply_fused`` with the
per-step emit guard removed.  The fused scan always runs the padded
``n_steps`` iterations and reports which steps each lane actually
executed in ``emit_seq`` — a lane that hits eos mid-horizon (or a
horizon shorter than the padded scan length) keeps producing frozen
tokens for the remaining steps.  Appending without consulting the mask
pushes those frozen duplicates into ``tokens_out``: the finished lane's
final token is double-counted and the fused token stream silently
diverges from the per-tick engine.

This file is never imported by the engine; the mirror-drift pass's
``check_fused_emit_guard`` is pointed at it to prove the AST check
still catches the bug class.
"""


class PagedServingEngine:
    def _apply_fused(self, tok_seq, emit_seq, k, t0, t1):
        times = [t0 + (j + 1) * (t1 - t0) / k for j in range(k)]
        finished = 0
        for slot, req in list(self.active.items()):
            last_t = t1
            for j in range(k):
                # BUG: no `if not emit_seq[j, slot]: continue` guard —
                # frozen steps of an eos'd lane are appended as if they
                # had run, double-counting its final token.
                req.tokens_out.append(int(tok_seq[j, slot]))
                req.token_times.append(times[j])
                last_t = times[j]
                self._lengths_host[slot] += 1
            if len(req.tokens_out) >= self._budget(req):
                req.finish_s = last_t
                self.completed.append(req)
                del self.active[slot]
                finished += 1
        return finished
