"""PR-2 inactive-lane reproduction (AST fixture, never executed).

The engine hands block tables to the paged Pallas kernel without first
routing *inactive* lanes' rows to the scratch page.  The kernel writes
every lane unconditionally, so a parked slot's stale table — possibly
pointing at refcounted shared pages — gets corrupted.
``kernel_lint.check_inactive_lane_ast`` must flag this function.
"""


def _decode_paged_pallas(self, toks):
    # BUG: no jnp.where(active[:, None], tables, num_pages) scratch
    # route — parked slots' stale rows go straight to the kernel
    tables = self.paged.tables_device()
    lengths = self.paged.lengths_device()
    return self._paged_step_fn(self.params, toks, tables, lengths)
