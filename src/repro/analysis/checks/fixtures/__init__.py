"""Regression fixtures that re-introduce historical bugs.

Each module here reproduces one seed-era defect class so the checker
suite can be tested against a known-bad input (`python -m
repro.analysis.checks --fixture <name>` must exit non-zero):

* ``pr2_scatter_clip`` — the clipped token scatter (PR-2 clip-aliasing)
* ``pr2_inactive_lane`` — table handoff without the inactive-lane
  scratch route (PR-2 inactive-lane corruption)
* ``pr2_refcount_free`` — an allocator that frees shared pages, and a
  defrag mapping that moves pages across placement regions
* ``pr6_metrics_drift`` — a cluster roll-up that drops a per-replica
  co-design metric (PR-6 ad-hoc name-matching drift)
* ``pr10_ship_trie_drop`` — a shipment import that skips destination
  trie re-registration (PR-10 silent dedup loss on the decode tier)

Nothing in this package is imported by production code.
"""
