"""PR-2 clip-aliasing reproduction: the seed-era token scatter.

Clips the block index into the table instead of *detecting* an
out-of-window position, ignores the ``active`` mask, and never routes to
the scratch page — a write past the mapped window lands on the window's
last live page and an inactive slot writes through its stale table.
``kernel_lint.lint_scatter_token`` must flag all three invariants.
"""
import jax.numpy as jnp

BATCH_AXIS = 1
SEQ_AXIS = 2


def scatter_token_clipped(pool, leaf, tables, pos, active, page_size):
    b = leaf.shape[BATCH_AXIS]
    blk = pos // page_size
    off = pos % page_size
    nblk = tables.shape[1]
    blk = jnp.clip(blk, 0, nblk - 1)     # BUG: clip, never detect
    page = jnp.take_along_axis(tables, blk[:, None], axis=1)[:, 0]
    pos = jnp.clip(pos, 0, leaf.shape[SEQ_AXIS] - 1)
    val = jnp.take_along_axis(
        leaf, pos.reshape((1, b) + (1,) * (leaf.ndim - 2)),
        axis=SEQ_AXIS)
    val = jnp.squeeze(val, axis=SEQ_AXIS)
    return pool.at[:, page, off].set(val)  # BUG: `active` unused
