"""Allocator-discipline reproductions for the small-scope model checker.

``RefcountIgnoringAllocator`` frees a page on its first decref no matter
how many references remain — the shared-prefix free-while-referenced
class.  ``cross_region_defrag_mapping`` compacts to the lowest free
index anywhere, ignoring placement regions — the cross-region move the
stack-aware layout forbids.  ``allocator_model.explore`` must produce a
minimal counterexample trace for each.
"""
from repro.serving.paged_cache import PageAllocator


class RefcountIgnoringAllocator(PageAllocator):
    """decref frees unconditionally (refcount forced to 1 first)."""

    def decref(self, page: int) -> bool:
        if page in self._refs:           # keep the unallocated-page
            self._refs[page] = 1         # ValueError path intact
        return super().decref(page)


def cross_region_defrag_mapping(alloc, placement, movable):
    """Compaction that ignores regions: lowest free index anywhere."""
    mapping = {}
    taken = set(alloc.live_pages())
    for old in sorted(movable):
        candidates = [p for p in range(old) if p not in taken]
        if candidates:
            new = min(candidates)
            mapping[old] = new
            taken.discard(old)
            taken.add(new)
    return mapping
