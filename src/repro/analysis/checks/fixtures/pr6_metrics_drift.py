"""PR-6 mirror-drift reproduction (AST fixture, never executed).

A cluster roll-up that aggregates the co-design metrics it knew about
when it was written — and silently drops ``substrate_configs``, which
``Scheduler.metrics`` also emits.  This is exactly how the real
``Router.metrics`` drifted: per-replica keys are picked up by ad-hoc
name matching, so a new key on the scheduler side changes nothing here
and the cluster report under-reports.
``mirror_drift.check_router_aggregation`` must flag the missing key.
"""


class Router:
    def metrics(self, wall, t0):
        reconfigs = 0
        modeled_rate = 0.0
        util_sum, util_n = 0.0, 0
        for sch in self.schedulers:
            m = sch.metrics(wall, t0)
            reconfigs += m.get("reconfigurations", 0)
            modeled_rate += m.get("modeled_tokens_per_s", 0.0)
            if m.get("modeled_time_s", 0.0) > 0:
                util_sum += m.get("array_util_mean", 0.0)
                util_n += 1
            # BUG: m["substrate_configs"] is never read — the scheduler
            # emits it, the cluster report silently drops it
        return {
            "reconfigurations": reconfigs,
            "modeled_tokens_per_s": modeled_rate,
            "array_util_mean": util_sum / util_n if util_n else 0.0,
        }
