"""Page-shipment reproduction for the ship-integrity checker (PR 10).

``TrieDroppingCache`` imports a :class:`PageShipment` correctly at the
allocator level — pages mapped, refcounts balanced, payload written —
but skips re-registering the imported prefix coverage in the
destination trie.  The pool *looks* healthy (ledger and mirror both
check out) yet every later same-prefix arrival re-allocates pages it
should have deduped, silently doubling KV residency on the decode
tier.  ``allocator_model.check_ship_integrity`` must flag it.
"""
from repro.serving.paged_cache import PagedCache


class TrieDroppingCache(PagedCache):
    """Shipment import that forgets the destination trie."""

    def import_slot_pages(self, slot, shipment):
        self._importing = True
        try:
            return super().import_slot_pages(slot, shipment)
        finally:
            self._importing = False

    def commit_prefix(self, slot):
        if getattr(self, "_importing", False):
            self._pending_prompt.pop(slot, None)
            return
        super().commit_prefix(slot)
