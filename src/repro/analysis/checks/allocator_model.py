"""Pass 2 — allocator small-scope model checker.

Exhaustive bounded exploration of ``PageAllocator`` + ``PrefixIndex``
op sequences (alloc / free / incref / fork / defrag / migrate /
rebuild) over a small scope — few pages, two regions, shallow depth —
in the small-scope-hypothesis tradition: allocator bugs in this repo's
history (refcount leaks, freeing shared pages, cross-region defrag
moves) all have counterexamples within a handful of operations.

The checker drives the *real* classes next to an independent ledger of
what the refcounts/trie must be, and asserts after every operation:

* **refcount conservation** — the allocator's live map equals the
  ledger exactly; no page is freed while references remain, none leaks.
* **free/used partition** — ``free + used == num_pages`` and every free
  page sits in its own region's free list.
* **no double-free / foreign incref** — ``decref``/``incref`` of an
  unallocated page must raise, and must not mutate state.
* **alloc atomicity** — a failed allocation leaves the allocator
  untouched.
* **region-preserving defrag** — a defrag move never crosses a
  placement region, and rebuild+remap keeps the refcount multiset.
* **trie↔physical consistency** — every page the prefix trie points at
  is live; remap/remove keep the reverse index exact.

Violations are reported as findings whose detail is the **minimal op
trace** (BFS order guarantees minimality) that reproduces them.
"""
from __future__ import annotations

import copy
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .common import Finding

PASS = "allocator-model"


@dataclass
class ModelConfig:
    num_pages: int = 5
    n_regions: int = 2
    communal_pages: int = 1
    policy: str = "affinity"
    page_size: int = 2
    depth: int = 7
    max_refcount: int = 3
    max_findings: int = 3
    max_states: int = 200_000     # safety valve, not expected to bind
    placed: bool = True


def default_defrag_mapping(alloc, placement, movable) -> Dict[int, int]:
    """Region-preserving compaction: each movable page goes to the lowest
    free index *inside its own region* (mirrors ``PagedCache.defrag``)."""
    mapping: Dict[int, int] = {}
    taken = set(alloc.live_pages())
    for old in sorted(movable):
        region = placement.region_of(old) if placement is not None else None
        if placement is not None:
            candidates = [p for p in placement.region_pages(region)
                          if p not in taken and p < old]
        else:
            candidates = [p for p in range(old)
                          if p not in taken]
        if candidates:
            new = min(candidates)
            mapping[old] = new
            taken.discard(old)
            taken.add(new)
    return mapping


@dataclass
class _State:
    alloc: object
    prefix: object
    refs: Dict[int, int]            # ledger: page -> expected refcount
    trie: set                       # ledger: pages published in the trie
    home: Dict[int, int]            # ledger: page -> intended home region
    trace: Tuple[str, ...] = ()

    def clone(self) -> "_State":
        return _State(copy.deepcopy(self.alloc), copy.deepcopy(self.prefix),
                      dict(self.refs), set(self.trie), dict(self.home),
                      self.trace)

    def key(self):
        a = self.alloc
        if getattr(a, "placed", False):
            free = tuple(sorted(
                (r, tuple(v)) for r, v in a._region_lists.items()))
        else:
            free = tuple(a._free)
        return (tuple(sorted(self.refs.items())),
                frozenset(self.trie),
                tuple(sorted(self.home.items())),
                free)


class _Violation(Exception):
    pass


def _tokens_for(page: int, page_size: int) -> np.ndarray:
    return np.arange(page_size, dtype=np.int64) + page * page_size


def check_state(s: _State, cfg: ModelConfig, placement) -> Optional[str]:
    a = s.alloc
    live = {p: a.refcount(p) for p in a.live_pages()}
    if live != s.refs:
        return (f"refcount conservation violated: allocator holds {live}, "
                f"ledger expects {s.refs}")
    if a.free_pages + a.used_pages != cfg.num_pages:
        return (f"free/used partition broken: {a.free_pages} free + "
                f"{a.used_pages} used != {cfg.num_pages}")
    if getattr(a, "placed", False):
        for r, pool in a._region_lists.items():
            for p in pool:
                if placement.region_of(p) != r:
                    return (f"free page {p} filed under region {r} but "
                            f"placed in region {placement.region_of(p)}")
                if p in live:
                    return f"page {p} simultaneously free and live"
    by_page = set(getattr(s.prefix, "_by_page", {}))
    if by_page != s.trie:
        return (f"trie reverse index {sorted(by_page)} diverged from "
                f"ledger {sorted(s.trie)}")
    for p in s.trie:
        if a.refcount(p) <= 0:
            return f"prefix trie points at dead page {p}"
    # exception discipline probed exhaustively at every state: touching
    # an unallocated page must raise and must not mutate
    freed = [p for p in range(cfg.num_pages) if p not in s.refs]
    for p in freed[:2]:
        probe = copy.deepcopy(a)
        for opname, op in (("decref", probe.decref), ("incref", probe.incref)):
            try:
                op(p)
            except ValueError:
                pass
            else:
                return (f"{opname}({p}) of a free page did not raise — "
                        "double-free / foreign-page discipline lost")
        if (probe.free_pages, sorted(probe.live_pages())) != \
                (a.free_pages, sorted(a.live_pages())):
            return f"failed decref/incref of page {p} mutated the allocator"
    return None


def _enabled_ops(s: _State, cfg: ModelConfig, placement):
    """(op_label, apply_fn) pairs applicable in state ``s``.  Each apply
    mutates its (cloned) state and may raise :class:`_Violation`."""
    ops: List[Tuple[str, Callable[[_State], None]]] = []
    regions = (list(range(cfg.n_regions)) if placement is not None
               else [None])

    for r in regions:
        def _alloc(st, r=r):
            a = st.alloc
            before = (a.free_pages, sorted(a.live_pages()))
            got = a.alloc(1, home=r) if r is not None else a.alloc(1)
            if got is None:
                after = (a.free_pages, sorted(a.live_pages()))
                if after != before:
                    raise _Violation(
                        "failed alloc mutated the allocator "
                        f"({before} -> {after})")
                return
            st.refs[got[0]] = 1
            if r is not None:
                st.home[got[0]] = r
        ops.append((f"alloc(1, home={r})", _alloc))
    if placement is not None and cfg.communal_pages:
        def _alloc_communal(st):
            got = st.alloc.alloc(1, home=0, communal=1)
            if got is None:
                return
            st.refs[got[0]] = 1
            st.home[got[0]] = 0
        ops.append(("alloc(1, communal=1)", _alloc_communal))

    for p in sorted(s.refs):
        if s.refs[p] < cfg.max_refcount:
            def _incref(st, p=p):
                st.alloc.incref(p)
                st.refs[p] += 1
            ops.append((f"incref({p})", _incref))

    for p in sorted(s.refs):
        def _decref(st, p=p):
            freed = st.alloc.decref(p)
            st.refs[p] -= 1
            if st.refs[p] == 0:
                del st.refs[p]
                st.home.pop(p, None)
                if not freed:
                    raise _Violation(
                        f"last decref of page {p} did not free it")
                if p in st.trie:
                    st.prefix.remove(p)
                    st.trie.discard(p)
            elif freed:
                raise _Violation(
                    f"page {p} freed while {st.refs[p]} reference(s) "
                    "remain (shared-page free)")
        ops.append((f"decref({p})", _decref))

    for p in sorted(s.refs):
        if p not in s.trie:
            def _register(st, p=p):
                st.prefix.register(_tokens_for(p, cfg.page_size), [p],
                                   cfg.page_size)
                st.trie.add(p)
            ops.append((f"register({p})", _register))

    for p in sorted(s.refs):
        if s.refs[p] >= 2:
            def _fork(st, p=p):
                # copy-on-write at the allocator level: the writer takes
                # a fresh exclusive page and drops its shared reference
                got = st.alloc.alloc(1, home=st.home.get(p))
                if got is None:
                    return
                st.refs[got[0]] = 1
                if p in st.home:
                    st.home[got[0]] = st.home[p]
                st.alloc.decref(p)
                st.refs[p] -= 1
            ops.append((f"fork({p})", _fork))

    if placement is not None:
        def _migrate(st):
            # move every spilled exclusive non-trie page home (mirrors
            # PagedCache.migrate_spilled)
            for p in sorted(st.refs):
                if (st.refs[p] != 1 or p in st.trie
                        or p not in st.home):
                    continue
                if placement.region_of(p) == st.home[p]:
                    continue
                got = st.alloc.alloc_in(st.home[p], 1)
                if got is None:
                    continue
                if placement.region_of(got[0]) != st.home[p]:
                    raise _Violation(
                        f"alloc_in({st.home[p]}) handed out page "
                        f"{got[0]} from region "
                        f"{placement.region_of(got[0])}")
                st.refs[got[0]] = 1
                st.home[got[0]] = st.home[p]
                st.alloc.decref(p)
                del st.refs[p]
                del st.home[p]
        ops.append(("migrate_spilled()", _migrate))

    def _defrag(st, mapping_fn):
        movable = [p for p in st.refs
                   if st.refs[p] == 1 and p not in st.trie]
        mapping = mapping_fn(st.alloc, placement, movable)
        for old, new in mapping.items():
            if placement is not None and \
                    placement.region_of(new) != placement.region_of(old):
                raise _Violation(
                    f"defrag moved page {old} (region "
                    f"{placement.region_of(old)}) to page {new} (region "
                    f"{placement.region_of(new)}) — cross-region move")
        new_refs = {mapping.get(p, p): rc for p, rc in st.refs.items()}
        if len(new_refs) != len(st.refs):
            raise _Violation(
                f"defrag mapping {mapping} collapses distinct live pages")
        st.alloc.rebuild(new_refs)
        st.prefix.remap(mapping)
        st.refs = new_refs
        st.home = {mapping.get(p, p): h for p, h in st.home.items()}
        st.trie = {mapping.get(p, p) for p in st.trie}
    ops.append(("defrag()", _defrag))

    def _rebuild(st):
        st.alloc.rebuild(dict(st.refs))
    ops.append(("rebuild(ledger)", _rebuild))
    return ops


def explore(cfg: Optional[ModelConfig] = None,
            allocator_cls=None,
            defrag_mapping: Optional[Callable] = None,
            log: Optional[Callable[[str], None]] = None) -> List[Finding]:
    """BFS over op sequences up to ``cfg.depth``; returns findings whose
    detail is the minimal counterexample trace."""
    from repro.core.placement import PlacementMap
    from repro.serving import paged_cache as pc

    cfg = cfg or ModelConfig()
    allocator_cls = allocator_cls or pc.PageAllocator
    defrag_mapping = defrag_mapping or default_defrag_mapping
    src_file = None
    try:
        import inspect
        src_file = inspect.getsourcefile(allocator_cls)
    except (TypeError, OSError):
        pass

    placement = None
    if cfg.placed:
        placement = PlacementMap(cfg.num_pages, cfg.n_regions,
                                 communal_pages=cfg.communal_pages)
        root_alloc = allocator_cls(cfg.num_pages, placement=placement,
                                   policy=cfg.policy)
    else:
        root_alloc = allocator_cls(cfg.num_pages)
    root = _State(root_alloc, pc.PrefixIndex(), {}, set(), {})

    findings: List[Finding] = []
    t0 = time.time()
    seen = {root.key()}
    frontier = [root]
    n_states = 1

    def record(trace, msg):
        findings.append(Finding(
            PASS, "allocator-invariant", msg, file=src_file,
            detail="minimal op trace:\n" + "\n".join(
                f"  {i + 1}. {op}" for i, op in enumerate(trace))
            + f"\n  => {msg}"))

    for depth in range(cfg.depth):
        nxt: List[_State] = []
        for s in frontier:
            for label, apply_fn in _enabled_ops(s, cfg, placement):
                if len(findings) >= cfg.max_findings:
                    return findings
                child = s.clone()
                child.trace = s.trace + (label,)
                try:
                    if label == "defrag()":
                        apply_fn(child, defrag_mapping)
                    else:
                        apply_fn(child)
                except _Violation as v:
                    record(child.trace, str(v))
                    continue
                except Exception as e:          # unexpected crash
                    record(child.trace,
                           f"unexpected {type(e).__name__}: {e}")
                    continue
                bad = check_state(child, cfg, placement)
                if bad is not None:
                    record(child.trace, bad)
                    continue
                k = child.key()
                if k not in seen and n_states < cfg.max_states:
                    seen.add(k)
                    nxt.append(child)
                    n_states += 1
        frontier = nxt
        if not frontier:
            break
    if log is not None:
        log(f"allocator-model: explored {n_states} states to depth "
            f"{cfg.depth} in {time.time() - t0:.1f}s")
    return findings


def check_table_mirror(log: Optional[Callable[[str], None]] = None
                       ) -> List[Finding]:
    """Scripted drive of the real ``PagedCache`` device-table mirror.

    PR-8 made the ``(B, nblk)`` device block-table mirror incrementally
    maintained (per-row refresh on alloc/extend/free, per-entry on
    copy-on-write forks) instead of rebuilt from the host tables every
    tick.  This check runs a short op sequence covering every mutation
    class — shared-prefix mapping, CoW fork, growth, free, defrag — and
    after each op asserts (a) the mirror equals a fresh rebuild
    (``mirror_consistent``) and (b) the hot-path ops kept the mirror
    alive instead of cheating by invalidating it (defrag alone may drop
    it: renumbering rewrites every row anyway).
    """
    import jax.numpy as jnp
    from repro.serving.paged_cache import PagedCache
    import inspect

    class _Entry:
        """Minimal cache-bearing model stub: one layer, one KV head."""

        def cache_zeros(self, max_batch, max_seq, tp=1):
            return {"k": jnp.zeros((1, max_batch, max_seq, 1, 2),
                                   jnp.float32),
                    "v": jnp.zeros((1, max_batch, max_seq, 1, 2),
                                   jnp.float32),
                    "lengths": jnp.zeros((max_batch,), jnp.int32)}

    entry = _Entry()
    cache = PagedCache(entry, max_batch=3, max_seq=8, page_size=2,
                       num_pages=6, share=True)
    src_file = inspect.getsourcefile(PagedCache)
    findings: List[Finding] = []
    t0 = time.time()
    toks = np.arange(4, dtype=np.int64)

    # (label, op, must_keep_mirror_alive)
    script = [
        ("tables_device()", lambda: cache.tables_device(), True),
        ("alloc_slot(0, 4, tokens)",
         lambda: cache.alloc_slot(0, 4, tokens=toks), True),
        ("write_slot(0, cache1, 4)",
         lambda: cache.write_slot(0, entry.cache_zeros(1, 4), 4), True),
        ("alloc_slot(1, 4, tokens)   # maps shared prefix",
         lambda: cache.alloc_slot(1, 4, tokens=toks), True),
        ("cow_for_write(1, 0)        # forks shared page",
         lambda: cache.cow_for_write(1, 0), True),
        ("extend_slot(1, 6)", lambda: cache.extend_slot(1, 6), True),
        ("free_slot(0)", lambda: cache.free_slot(0), True),
        ("defrag()", lambda: cache.defrag(), False),
        ("tables_device()            # rebuild after defrag",
         lambda: cache.tables_device(), True),
        ("alloc_slot(2, 3)", lambda: cache.alloc_slot(2, 3), True),
    ]
    done: List[str] = []
    for label, op, keep_alive in script:
        try:
            op()
        except Exception as e:          # noqa: BLE001 — report, don't crash CI
            findings.append(Finding(
                PASS, "table-mirror",
                f"scripted op {label.split('#')[0].strip()} raised "
                f"{type(e).__name__}: {e}", file=src_file,
                detail="after ops:\n" + "\n".join(
                    f"  {i + 1}. {o}" for i, o in enumerate(done))))
            return findings
        done.append(label)
        if keep_alive and cache._tables_dev is None:
            findings.append(Finding(
                PASS, "table-mirror",
                f"{label.split('#')[0].strip()} dropped the device table "
                f"mirror — hot-path ops must refresh it in place, not "
                f"invalidate it", file=src_file,
                detail="op trace:\n" + "\n".join(
                    f"  {i + 1}. {o}" for i, o in enumerate(done))))
        if not cache.mirror_consistent():
            findings.append(Finding(
                PASS, "table-mirror",
                f"device table mirror diverged from host tables after "
                f"{label.split('#')[0].strip()}", file=src_file,
                detail="op trace:\n" + "\n".join(
                    f"  {i + 1}. {o}" for i, o in enumerate(done))))
    if log is not None:
        log(f"allocator-model: table-mirror script ({len(script)} ops) "
            f"in {time.time() - t0:.1f}s")
    return findings


def check_ship_integrity(cache_cls=None,
                         log: Optional[Callable[[str], None]] = None
                         ) -> List[Finding]:
    """The ship op (PR 10), driven on two real ``PagedCache`` pools.

    A page shipment must leave BOTH allocators and BOTH prefix tries in
    a state indistinguishable from the request having prefilled on the
    destination: the source frees every exported page, the destination's
    refcount ledger balances, the shipped prefix coverage is
    re-registered in the destination trie (so a follow-up import of the
    same prefix dedups against it), and the device table mirrors stay
    consistent on both sides.
    """
    import jax.numpy as jnp
    import inspect
    from repro.serving.paged_cache import PagedCache

    cache_cls = cache_cls or PagedCache

    class _Entry:
        """Minimal cache-bearing model stub: one layer, one KV head."""

        def cache_zeros(self, max_batch, max_seq, tp=1):
            return {"k": jnp.zeros((1, max_batch, max_seq, 1, 2),
                                   jnp.float32),
                    "v": jnp.zeros((1, max_batch, max_seq, 1, 2),
                                   jnp.float32),
                    "lengths": jnp.zeros((max_batch,), jnp.int32)}

    entry = _Entry()
    kw = dict(max_batch=3, max_seq=8, page_size=2, num_pages=6,
              share=True)
    src = cache_cls(entry, **kw)
    dst = cache_cls(entry, **kw)
    src_file = inspect.getsourcefile(cache_cls)
    findings: List[Finding] = []
    t0 = time.time()

    def bad(msg):
        findings.append(Finding(PASS, "ship-integrity", msg,
                                file=src_file))
        return findings

    toks = np.arange(6, dtype=np.int64)
    src.alloc_slot(0, 6, tokens=toks)
    src.write_slot(0, entry.cache_zeros(1, 6), 6)
    src.commit_prefix(0)
    ship = src.export_slot_pages(0, 6, tokens=toks, hops=1)
    if ship.n_pages != 3:
        return bad(f"export of 6 tokens at page_size=2 shipped "
                   f"{ship.n_pages} pages, expected 3")
    if ship.cost_s <= 0.0 or ship.bytes_on_wire <= 0:
        return bad("shipment is not priced: cost_s="
                   f"{ship.cost_s}, bytes={ship.bytes_on_wire}")
    if src.alloc.used_pages != 0 or src.alloc.free_pages != 6:
        return bad(f"source pool leaked after export: "
                   f"{src.alloc.used_pages} used, "
                   f"{src.alloc.free_pages} free (expected 0/6)")
    if not src.mirror_consistent():
        return bad("source device-table mirror diverged after export")
    if not dst.import_slot_pages(0, ship):
        return bad("import refused with an empty destination pool")
    if dst.alloc.used_pages != 3 or dst.alloc.free_pages != 3:
        return bad(f"destination ledger off after import: "
                   f"{dst.alloc.used_pages} used / "
                   f"{dst.alloc.free_pages} free (expected 3/3)")
    live = {p: dst.alloc.refcount(p) for p in dst.alloc.live_pages()}
    if any(rc != 1 for rc in live.values()):
        return bad(f"imported pages must arrive exclusive (refcount 1), "
                   f"got {live}")
    matched = dst.prefix.match(toks, 2)
    if len(matched) != 3:
        return bad(f"imported prefix coverage not re-registered in the "
                   f"destination trie: match found {len(matched)} of 3 "
                   f"pages — a same-prefix follow-up cannot dedup")
    if not dst.mirror_consistent():
        return bad("destination device-table mirror diverged after "
                   "import")
    # second shipment of the same prefix must dedup against the trie
    src.alloc_slot(0, 6, tokens=toks)
    src.write_slot(0, entry.cache_zeros(1, 6), 6)
    src.commit_prefix(0)
    ship2 = src.export_slot_pages(0, 6, tokens=toks, hops=1)
    if not dst.import_slot_pages(1, ship2):
        return bad("second import refused despite shared-prefix headroom")
    if dst.alloc.shared_pages != 3:
        return bad(f"same-prefix re-import shares "
                   f"{dst.alloc.shared_pages} pages, expected all 3 "
                   f"(trie dedup on import)")
    live = {p: dst.alloc.refcount(p) for p in dst.alloc.live_pages()}
    if sum(live.values()) != 6 or len(live) != 3:
        return bad(f"refcount ledger after dedup import should be 3 "
                   f"pages x refcount 2, got {live}")
    if not dst.mirror_consistent():
        return bad("destination mirror diverged after dedup import")
    dst.free_slot(0)
    dst.free_slot(1)
    if dst.alloc.used_pages != 0 or dst.prefix._by_page:
        return bad("freeing both imported slots leaked pages or trie "
                   f"entries: {dst.alloc.used_pages} used, trie "
                   f"{sorted(dst.prefix._by_page)}")
    if log is not None:
        log(f"allocator-model: ship-integrity script in "
            f"{time.time() - t0:.1f}s")
    return findings


def run(log: Optional[Callable[[str], None]] = None) -> List[Finding]:
    """Both scopes: placed (regions + communal + migration/defrag) and
    the legacy unplaced free-list; plus the scripted device-table-mirror
    and page-shipment drives over the real ``PagedCache``."""
    findings = explore(ModelConfig(), log=log)
    findings += explore(ModelConfig(num_pages=4, placed=False),
                        log=log)
    findings += check_table_mirror(log=log)
    findings += check_ship_integrity(log=log)
    return findings
