"""CLI: ``python -m repro.analysis.checks`` — exit 0 iff no findings.

CI runs the bare command as a fail-fast gate.  ``--pass`` restricts to a
subset; ``--fixture`` runs the owning pass against a seeded regression
(historical bug reproduction) and therefore must exit non-zero.
"""
from __future__ import annotations

import argparse
import sys

from . import (FIXTURE_NAMES, PASS_NAMES, render_report, run_all,
               run_fixture)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.checks",
        description="static invariant checks: kernel aliasing lint, "
                    "allocator small-scope model checker, engine/sim "
                    "mirror-drift analysis")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=PASS_NAMES, metavar="NAME",
                    help=f"run only this pass (repeatable; "
                         f"choices: {', '.join(PASS_NAMES)})")
    ap.add_argument("--fixture", choices=FIXTURE_NAMES, metavar="NAME",
                    help="run the owning pass against a seeded "
                         "regression fixture (expected to FAIL; "
                         f"choices: {', '.join(FIXTURE_NAMES)})")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress progress lines (report still printed)")
    args = ap.parse_args(argv)
    if args.fixture and args.passes:
        ap.error("--fixture and --pass are mutually exclusive")
    log = (lambda msg: None) if args.quiet else \
        (lambda msg: print(msg, file=sys.stderr))
    if args.fixture:
        findings = run_fixture(args.fixture, log=log)
    else:
        findings = run_all(args.passes, log=log)
    print(render_report(findings))
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
