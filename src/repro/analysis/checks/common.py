"""Shared finding record + report formatting for the invariant checkers.

Every pass returns a list of :class:`Finding`.  A finding carries an
actionable location (``file:line`` when the pass can resolve one), the
invariant it belongs to, and free-form detail — for the allocator model
checker the detail is the minimal counterexample op trace.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str          # "kernel-aliasing" | "allocator-model" | "mirror-drift"
    invariant: str          # short machine-ish id, e.g. "scatter-scratch-route"
    message: str            # one-line human statement of the violation
    file: Optional[str] = None
    line: Optional[int] = None
    detail: Optional[str] = None   # counterexample trace / extra context

    @property
    def location(self) -> str:
        if self.file is None:
            return "<traced>"
        return f"{self.file}:{self.line}" if self.line else self.file

    def render(self) -> str:
        head = f"[{self.pass_name}] {self.location}: {self.invariant}: {self.message}"
        if self.detail:
            body = "\n".join("    " + ln for ln in self.detail.splitlines())
            return head + "\n" + body
        return head


def render_report(findings: List[Finding]) -> str:
    if not findings:
        return "invariant checks: OK (0 findings)"
    lines = [f.render() for f in findings]
    lines.append(f"invariant checks: {len(findings)} finding(s)")
    return "\n".join(lines)
