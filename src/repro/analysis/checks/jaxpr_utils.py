"""jaxpr traversal/slicing helpers shared by the kernel aliasing lint.

Nothing here executes device code: every analysis operates on the jaxpr
produced by ``jax.make_jaxpr`` (abstract tracing) or on the tiny pure
index-map jaxprs embedded in ``pallas_call`` equations.
"""
from __future__ import annotations

import itertools
from typing import Any, Dict, Iterable, Iterator, List, Optional, Set, Tuple

from jax import core as jcore

Literal = jcore.Literal

#: primitives that write through computed indices into an existing operand
SCATTER_PRIMS = frozenset({
    "scatter", "scatter-add", "scatter_add", "scatter-mul", "scatter-min",
    "scatter-max", "dynamic_update_slice",
})

#: comparison primitives that can express a bounds guard
CMP_PRIMS = frozenset({"lt", "le", "gt", "ge"})


def subjaxprs(eqn) -> List[Any]:
    """All jaxprs nested in one equation's params (pjit/cond/scan/...)."""
    out = []
    for v in eqn.params.values():
        if hasattr(v, "jaxpr"):            # ClosedJaxpr
            out.append(v.jaxpr)
        elif isinstance(v, jcore.Jaxpr):
            out.append(v)
        elif isinstance(v, (list, tuple)):
            for x in v:
                if hasattr(x, "jaxpr"):
                    out.append(x.jaxpr)
                elif isinstance(x, jcore.Jaxpr):
                    out.append(x)
    return out


def iter_eqns(jaxpr, recursive: bool = True) -> Iterator[Any]:
    """Yield equations, optionally descending into nested jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        if recursive:
            for sub in subjaxprs(eqn):
                yield from iter_eqns(sub, recursive=True)


def prim_names(jaxpr, recursive: bool = True) -> Set[str]:
    return {e.primitive.name for e in iter_eqns(jaxpr, recursive)}


def literal_values(eqn) -> List[Any]:
    """Python values of the equation's literal operands."""
    out = []
    for v in eqn.invars:
        if isinstance(v, Literal):
            try:
                out.append(v.val.item() if hasattr(v.val, "item") else v.val)
            except (ValueError, AttributeError):
                out.append(v.val)
    return out


def eqn_mentions_literal(eqn, value, recursive: bool = True) -> bool:
    """True when the equation (or a nested jaxpr's equation) carries a
    literal operand equal to ``value``."""
    if any(v == value for v in literal_values(eqn)):
        return True
    if recursive:
        for sub in subjaxprs(eqn):
            for e in sub.eqns:
                if eqn_mentions_literal(e, value, recursive=True):
                    return True
    return False


def eqn_is_select(eqn) -> bool:
    """select_n, or a pjit call whose body is a select (jnp.where)."""
    if eqn.primitive.name == "select_n":
        return True
    if eqn.primitive.name in ("pjit", "closed_call", "custom_jvp_call"):
        return any(e.primitive.name == "select_n"
                   for sub in subjaxprs(eqn) for e in iter_eqns(sub))
    return False


def eqn_is_compare(eqn) -> bool:
    if eqn.primitive.name in CMP_PRIMS:
        return True
    if eqn.primitive.name in ("pjit", "closed_call"):
        return any(e.primitive.name in CMP_PRIMS
                   for sub in subjaxprs(eqn) for e in iter_eqns(sub))
    return False


def backward_slice(jaxpr, seed_vars) -> Tuple[List[Any], Set[Any]]:
    """Top-level backward slice from ``seed_vars``.

    Returns ``(eqns, sources)`` where ``eqns`` are the top-level equations
    the seeds transitively depend on and ``sources`` the jaxpr invars
    reached.  Nested jaxprs are treated as opaque nodes (their operands at
    the call site keep the slice sound for dependency questions).
    """
    needed = {v for v in seed_vars if not isinstance(v, Literal)}
    sliced: List[Any] = []
    for eqn in reversed(jaxpr.eqns):
        if any(ov in needed for ov in eqn.outvars):
            sliced.append(eqn)
            for iv in eqn.invars:
                if not isinstance(iv, Literal):
                    needed.add(iv)
    sources = {v for v in jaxpr.invars if v in needed}
    return list(reversed(sliced)), sources


def find_scatters(jaxpr, page_axis_size: int, recursive: bool = True):
    """Scatter-family equations whose written operand has a dimension equal
    to ``page_axis_size`` (the pool's page axis, scratch included)."""
    hits = []
    for eqn in iter_eqns(jaxpr, recursive):
        if eqn.primitive.name in SCATTER_PRIMS:
            aval = getattr(eqn.invars[0], "aval", None)
            if aval is not None and page_axis_size in tuple(aval.shape):
                hits.append(eqn)
    return hits


def find_pallas_calls(jaxpr) -> List[Any]:
    return [e for e in iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


# --- pallas index-map interpretation -----------------------------------
class UnanalyzableIndexMap(Exception):
    pass


def eval_index_map(index_map_jaxpr, grid: Tuple[int, ...],
                   point: Tuple[int, ...]) -> Tuple[Any, ...]:
    """Evaluate a *pure* index map (no state reads) at one grid point.

    The map's invars are ``grid indices + scalar-prefetch refs``; only
    grid-passthrough and literal outputs are interpreted — anything else
    (arithmetic, smem reads) raises :class:`UnanalyzableIndexMap` so the
    caller can apply the table-deref rules instead.
    """
    jx = index_map_jaxpr.jaxpr if hasattr(index_map_jaxpr, "jaxpr") \
        else index_map_jaxpr
    if jx.eqns:
        raise UnanalyzableIndexMap("index map has equations")
    env: Dict[Any, int] = {v: point[i]
                           for i, v in enumerate(jx.invars[:len(grid)])}
    out = []
    for ov in jx.outvars:
        if isinstance(ov, Literal):
            out.append(int(ov.val))
        elif ov in env:
            out.append(env[ov])
        else:
            raise UnanalyzableIndexMap(f"output {ov} not a grid index")
    return tuple(out)


def classify_index_map(index_map_jaxpr, grid_rank: int) -> str:
    """'pure' (grid passthrough), 'table' (smem deref passthrough), or
    'other' (needs manual review)."""
    jx = index_map_jaxpr.jaxpr if hasattr(index_map_jaxpr, "jaxpr") \
        else index_map_jaxpr
    if not jx.eqns:
        return "pure"
    gets = [e for e in jx.eqns if e.primitive.name == "get"]
    if len(gets) == len(jx.eqns) and gets:
        grid_vars = set(jx.invars[:grid_rank])
        for g in gets:
            # indices into the prefetched table must be raw grid indices
            for iv in g.invars[1:]:
                if not isinstance(iv, Literal) and iv not in grid_vars:
                    return "other"
        get_outs = {g.outvars[0] for g in gets}
        for ov in jx.outvars:
            ok = (isinstance(ov, Literal) or ov in grid_vars
                  or ov in get_outs)
            if not ok:
                return "other"
        return "table"
    return "other"


def grid_points(grid: Tuple[int, ...]) -> Iterable[Tuple[int, ...]]:
    return itertools.product(*(range(int(g)) for g in grid))


# --- guarded-store analysis inside kernel jaxprs ------------------------
def unguarded_writes_to(kernel_jaxpr, target_refs) -> List[Any]:
    """Swaps (ref stores) to any of ``target_refs`` that execute
    unconditionally on every grid step — i.e. not under a ``cond``
    (``pl.when``).  Loop bodies (scan/while) count as unconditional:
    they run on every step too.
    """
    hits: List[Any] = []
    targets = set(target_refs)

    def walk(jaxpr, env: Dict[Any, Any], guarded: bool):
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if "swap" in name and eqn.invars:
                root = env.get(eqn.invars[0], eqn.invars[0])
                if root in targets and not guarded:
                    hits.append(eqn)
            if name == "cond":
                operands = eqn.invars[1:]
                for br in eqn.params.get("branches", ()):
                    sub = br.jaxpr if hasattr(br, "jaxpr") else br
                    sub_env = _bind(sub.invars, operands, env)
                    walk(sub, sub_env, True)
            elif name in ("scan", "while", "pjit", "closed_call"):
                for key in ("jaxpr", "body_jaxpr", "cond_jaxpr"):
                    cj = eqn.params.get(key)
                    if cj is None:
                        continue
                    sub = cj.jaxpr if hasattr(cj, "jaxpr") else cj
                    sub_env = _bind(sub.invars, eqn.invars, env)
                    walk(sub, sub_env, guarded)
            else:
                for sub in subjaxprs(eqn):
                    walk(sub, _bind(sub.invars, eqn.invars, env), guarded)

    def _bind(sub_invars, operands, env):
        out = dict(env)
        # positional best-effort: refs thread through call boundaries in
        # operand order; extra consts shift positions, so match by aval
        # identity first and position second.
        by_pos = list(operands)
        n = min(len(sub_invars), len(by_pos))
        for sv, ov in zip(sub_invars[-n:], by_pos[-n:]):
            if not isinstance(ov, Literal):
                out[sv] = env.get(ov, ov)
        return out

    walk(kernel_jaxpr, {}, False)
    return hits
