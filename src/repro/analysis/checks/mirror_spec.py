"""The engine <-> analytic-mirror contract, written down.

The live serving engine (``repro.serving``) and its analytic mirror
(``repro.core.serving_sim``) grew hand-synchronized across six PRs:
config knobs, metric keys, and report fields correspond by naming
convention only.  This module makes every correspondence explicit so
:mod:`mirror_drift` can diff both code bases against it — adding a field
on one side without either mirroring it or *declaring* why it is
one-sided becomes a checker finding, as does letting a stale entry rot
in this file after a rename.

Three kinds of entry:

* ``*_PAIRS`` — (left name, right name) correspondences.  Both names
  must exist in the extracted surfaces.
* ``*_ONLY`` — one-sided names mapped to the *reason* they have no
  mirror.  Every name must still exist on its own side.
* ``ROUTER_MUST_AGGREGATE`` — scheduler metric keys the cluster router
  is required to consume (the PR-6 bug class: per-replica co-design
  metrics silently dropped at the cluster roll-up).
"""
from __future__ import annotations

# The metric-name contracts themselves live next to the registry that
# produces them (PR 9); re-exported here so the checker has one spec
# module to import
from repro.obs.metrics import (ROUTER_METRIC_CONTRACT,       # noqa: F401
                               SCHEDULER_METRIC_CONTRACT)

# --------------------------------------------------------------------------
# EngineConfig (serving/engine.py)  <->  simulate_serving kwargs
# (core/serving_sim.py)
# --------------------------------------------------------------------------
ENGINE_SIM_PAIRS = [
    ("max_batch", "max_batch"),
    ("max_new_tokens", "output_len"),
    ("paged", "cache_mode"),              # bool <-> "dense"/"paged"
    ("page_size", "page_size"),
    ("num_pages", "num_pages"),
    ("prefill_chunk", "prefill_chunk"),
    ("prefix_sharing", "prefix_sharing"),
    ("placement", "placement"),
    ("placement_regions", "n_regions"),
    ("fuse_steps", "fuse_steps"),
]

ENGINE_ONLY_CONFIG = {
    "max_seq": "sim derives the KV window from input_len + output_len",
    "eos_id": "sim traces carry sampled decode lengths instead of a "
              "token-level stop id",
    "use_pallas_decode": "kernel choice is invisible to the analytic "
                         "latency model",
    "defrag_threshold": "host-side hole-tracking trigger; the sim prices "
                        "migration, not fragmentation",
    "communal_frac": "sim placement carves its communal region internally",
    "codesign": "the sim receives the tick model itself as `latency`",
    "codesign_rows": "fixed-shape baselines are priced by passing a "
                     "different tick model to the sim",
    "codesign_spec": "the sim is always constructed from an explicit spec",
    "codesign_tp": "the sim is always constructed from an explicit spec",
    "codesign_reconfig_cost_s": "priced inside the tick model handed to "
                                "the sim as `latency`",
}

SIM_ONLY_PARAMS = {
    "system": "substrate label; the live engine reads it off the tick model",
    "n_requests": "trace shape — the live engine consumes an explicit trace",
    "input_len": "trace shape — the live engine consumes an explicit trace",
    "seed": "trace shape — the live engine consumes an explicit trace",
    "shared_prefix_len": "trace shape — the live engine consumes an "
                         "explicit trace",
    "prefill_on_device": "sim-only switch for pricing prefill off-device",
    "hw": "NMP system object for gather pricing; the engine wires it "
          "through the paged cache",
    "tracer": "the engine attaches its tracer via set_tracer, not a "
              "config knob",
}

# --------------------------------------------------------------------------
# Scheduler.metrics keys  <->  ServingReport fields
# --------------------------------------------------------------------------
SERVING_REPORT_PAIRS = [
    # (ServingReport field, Scheduler.metrics key)
    ("completed", "requests"),
    ("decoded_tokens", "decoded_tokens"),
    ("tokens_per_s", "tokens_per_s"),
    ("tbt_mean_s", "tbt_mean_s"),
    ("ttft_mean_s", "ttft_mean_s"),
    ("preemptions", "preemptions"),
    ("kv_peak_tokens", "kv_peak_tokens"),
    ("dedup_ratio", "kv_dedup_ratio_peak"),
    ("gather_cost_mean_s", "kv_gather_cost_mean_s"),
    ("gather_concentration", "kv_gather_concentration"),
    ("region_peak_pages", "kv_region_peak"),
    ("reconfigurations", "reconfigurations"),
    ("substrate_configs", "substrate_configs"),
    ("array_util_mean", "array_util_mean"),
    ("fused_ticks", "fused_ticks"),
    ("fused_steps_mean", "fused_steps_mean"),
    ("makespan_s", "modeled_time_s"),     # both are the modeled clock
]

SERVING_REPORT_ONLY = {
    "system": "workload identity, not a runtime metric",
    "model": "workload identity, not a runtime metric",
    "rate_req_s": "workload identity, not a runtime metric",
    "e2e_mean_s": "sim-clock statistic; the live path reports e2e "
                  "percentiles at the cluster level",
    "e2e_p90_s": "sim-clock statistic; the live path reports e2e "
                 "percentiles at the cluster level",
    "kv_util_mean": "per-tick occupancy integral only the sim clock can "
                    "average cheaply",
    "max_decode_stall_s": "sim-clock statistic (worst decode gap)",
}

SCHEDULER_METRICS_ONLY = {
    "wall_s": "wall-clock only exists on the live path",
    "tbt_p99_s": "live-path tail metric; sim reports the mean",
    "tpot_mean_s": "alias of tbt_mean_s kept for benchmark scripts",
    "finish_eos": "live traces finish on sampled eos; sim uses lengths",
    "finish_budget": "live traces finish on sampled eos; sim uses lengths",
    "kv_mode": "echoed config, not a metric",
    "kv_reserved_tokens": "echoed config, not a metric",
    "kv_logical_peak_pages": "folded into dedup_ratio on the sim side",
    "kv_shared_pages": "folded into dedup_ratio on the sim side",
    "cow_forks": "host-allocator detail the sim does not model",
    "defrag_runs": "host-allocator detail the sim does not model",
    "prefill_skipped_tokens": "host-allocator detail the sim does not model",
    "kv_migrated_pages": "sim prices migration inside gather cost",
    "kv_migration_cost_s": "sim prices migration inside gather cost",
    "placement_policy": "echoed config, not a metric",
    "codesign_substrate": "echoed config, not a metric",
    "modeled_tokens_per_s": "derived from decoded_tokens / makespan_s on "
                            "the sim side",
    "fused_host_frac": "wall-clock host/device split only exists on the "
                       "live path",
    "hists": "bucketed distribution summaries from the live metrics "
             "registry; the sim reports scalar statistics",
}

# --------------------------------------------------------------------------
# Router.metrics keys  <->  ClusterReport fields
# --------------------------------------------------------------------------
CLUSTER_REPORT_PAIRS = [
    # (ClusterReport field, Router.metrics key)
    ("policy", "policy"),
    ("replicas", "replicas"),
    ("completed", "requests"),
    ("throughput_tok_s", "tokens_per_s"),
    ("e2e_p50_s", "e2e_p50_s"),
    ("e2e_p99_s", "e2e_p99_s"),
    ("tbt_mean_s", "tbt_mean_s"),
    ("dedup_ratio", "dedup_ratio_agg"),
    ("preemptions", "preemptions"),
    ("reconfigurations", "reconfigurations"),
    ("substrate_configs", "substrate_configs"),
    ("array_util_mean", "array_util_mean"),
    # prefill/decode disaggregation (PR 10)
    ("tiers", "tiers"),
    ("shipments", "shipments"),
    ("shipped_pages", "shipped_pages"),
    ("ship_cost_s", "ship_cost_s"),
]

CLUSTER_REPORT_ONLY = {
    "rate_req_s": "workload identity, not a runtime metric",
    "per_replica_util": "router reports the richer per_replica table",
    "per_replica_completed": "router reports the richer per_replica table",
}

ROUTER_METRICS_ONLY = {
    "wall_s": "wall-clock only exists on the live path",
    "decoded_tokens": "cluster sim reports throughput directly",
    "tbt_p99_s": "live-path tail metric; sim reports the mean",
    "finish_eos": "live traces finish on sampled eos; sim uses lengths",
    "finish_budget": "live traces finish on sampled eos; sim uses lengths",
    "modeled_tokens_per_s": "live cluster only: the sim clock IS the "
                            "modeled clock",
    "per_replica": "live-path breakdown table",
    "hists": "bucketed distribution summaries from the live metrics "
             "registry; the sim reports scalar statistics",
}

# --------------------------------------------------------------------------
# Scheduler metric keys the Router roll-up must consume (or explicitly
# drop here with a reason).  This is the PR-6 drift class: Scheduler
# grows a co-design metric, Router's ad-hoc name matching never picks it
# up, and the cluster report silently under-reports.
# --------------------------------------------------------------------------
ROUTER_MUST_AGGREGATE = [
    "reconfigurations",
    "modeled_tokens_per_s",
    "array_util_mean",
    "substrate_configs",
]

ROUTER_AGGREGATE_DROPS: dict = {}

# --------------------------------------------------------------------------
# The replica protocol (PR 10): methods every routable replica — live
# engine, analytic ``serving_sim._Replica``, router test stubs — must
# define.  The canonical tuple lives next to the Protocol class itself;
# re-exported so the checker has one spec module to import.  The typed
# report field lists pin the LoadReport/PlacementReport dataclasses the
# dict-shaped payloads were replaced with: ``to_dict()`` at the JSON
# boundary must keep emitting exactly these names.
# --------------------------------------------------------------------------
from repro.serving.replica_api import (                       # noqa: F401,E402
    REPLICA_METHODS as REPLICA_PROTOCOL_METHODS)

LOAD_REPORT_FIELDS = (
    "active", "prefilling", "queue_depth", "free_slots", "free_pages",
    "min_region_free", "region_free",
)

PLACEMENT_REPORT_FIELDS = (
    "placement_policy", "n_regions", "communal_pages", "region_used",
    "region_free",
)

#: implementations the replica-protocol pass checks: (path, class name)
REPLICA_IMPLEMENTATIONS = [
    ("src/repro/serving/engine.py", "ServingEngine"),
    ("src/repro/core/serving_sim.py", "_Replica"),
    ("tests/test_serving_router.py", "_StubReplica"),
]
