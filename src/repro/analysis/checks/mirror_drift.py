"""Pass 3: engine/sim mirror-drift analysis (pure AST, no imports of jax).

Extracts the real surfaces — ``EngineConfig`` fields, ``simulate_serving``
keyword parameters, ``Scheduler.metrics`` / ``Router.metrics`` emitted
keys and consumed keys, ``ServingReport`` / ``ClusterReport`` fields, and
the ``kv_report`` / ``codesign_report`` key sets — then diffs each one,
in both directions, against the contract in :mod:`mirror_spec`.

Every check is path-parameterizable so the regression fixtures can point
it at a source file that re-introduces a historical drift.
"""
from __future__ import annotations

import ast
import importlib.util
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from .common import Finding
from . import mirror_spec as SPEC

PASS = "mirror-drift"

#: name -> line of first occurrence
Surface = Dict[str, int]


# --- source resolution ----------------------------------------------------
def module_path(dotted: str) -> str:
    spec = importlib.util.find_spec(dotted)
    if spec is None or spec.origin is None:
        raise ImportError(f"cannot locate source for {dotted}")
    return spec.origin


def _rel(path: str) -> str:
    p = Path(path).resolve()
    for parent in p.parents:
        if parent.name == "src":
            return str(p.relative_to(parent.parent))
    return str(p)


_TREES: Dict[str, ast.Module] = {}


def _tree(path: str) -> ast.Module:
    if path not in _TREES:
        _TREES[path] = ast.parse(Path(path).read_text())
    return _TREES[path]


def _find_class(tree: ast.Module, name: str) -> ast.ClassDef:
    for node in tree.body:
        if isinstance(node, ast.ClassDef) and node.name == name:
            return node
    raise LookupError(f"class {name} not found")


def _find_func(scope, name: str) -> ast.FunctionDef:
    for node in scope.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    raise LookupError(f"function {name} not found")


# --- surface extraction ---------------------------------------------------
def dataclass_fields(path: str, cls: str) -> Surface:
    """Annotated field names of a (data)class body."""
    out: Surface = {}
    for node in _find_class(_tree(path), cls).body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                          ast.Name):
            out.setdefault(node.target.id, node.lineno)
    return out


def kwonly_params(path: str, func: str) -> Surface:
    f = _find_func(_tree(path), func)
    return {a.arg: a.lineno for a in f.args.kwonlyargs}


def _dict_keys(node: ast.Dict) -> List[Tuple[str, int]]:
    return [(k.value, k.lineno) for k in node.keys
            if isinstance(k, ast.Constant) and isinstance(k.value, str)]


def produced_keys(path: str, cls: Optional[str], func: str,
                  resolve: Optional[Dict[str, Tuple[str, Optional[str],
                                                    str]]] = None
                  ) -> Surface:
    """Keys a dict-returning method can produce.

    Follows the *returned* dict only: ``return {...}`` keys directly, or
    for ``return rep`` the keys of ``rep = {...}`` assignments,
    ``rep["k"] = ...`` subscript stores, and — via ``resolve`` — the keys
    of helper reports merged with ``rep.update(self.x.helper())`` where
    ``resolve`` maps ``helper`` to its own ``(path, cls, func)``.
    Side dicts built for nested structures (e.g. a per-replica
    breakdown) do not leak into the surface.
    """
    scope = _find_class(_tree(path), cls) if cls else _tree(path)
    f = _find_func(scope, func)
    ret_names: Set[str] = set()
    out: Surface = {}
    for node in ast.walk(f):
        if isinstance(node, ast.Return):
            if isinstance(node.value, ast.Dict):
                for k, ln in _dict_keys(node.value):
                    out.setdefault(k, ln)
            elif isinstance(node.value, ast.Name):
                ret_names.add(node.value.id)
    if not ret_names:
        return out
    for node in ast.walk(f):
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id in ret_names
                and isinstance(node.value, ast.Dict)):
            for k, ln in _dict_keys(node.value):
                out.setdefault(k, ln)
        elif (isinstance(node, ast.Assign) and len(node.targets) == 1
              and isinstance(node.targets[0], ast.Subscript)
              and isinstance(node.targets[0].value, ast.Name)
              and node.targets[0].value.id in ret_names):
            sl = node.targets[0].slice
            if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
                out.setdefault(sl.value, node.lineno)
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr == "update"
              and isinstance(node.func.value, ast.Name)
              and node.func.value.id in ret_names and node.args):
            arg = node.args[0]
            helper = (arg.func.attr if isinstance(arg, ast.Call)
                      and isinstance(arg.func, ast.Attribute) else None)
            if helper == "to_dict" and isinstance(arg.func.value,
                                                  ast.Call) \
                    and isinstance(arg.func.value.func, ast.Attribute):
                # typed report at the dict boundary:
                # rep.update(self.x.helper().to_dict()) — the helper is
                # one call deeper
                helper = arg.func.value.func.attr
            if resolve and helper in resolve:
                out.update(produced_keys(*resolve[helper]))
    return out


def bound_receivers(f: ast.FunctionDef, method_names: Set[str]) -> Set[str]:
    """Local variables bound to ``x.method()`` calls (also through the
    ``getattr(x, "method", dict)()`` idiom)."""
    names: Set[str] = set()
    for node in ast.walk(f):
        if not (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)):
            continue
        fn = node.value.func
        attr = None
        if isinstance(fn, ast.Attribute):
            attr = fn.attr
        elif (isinstance(fn, ast.Call) and isinstance(fn.func, ast.Name)
              and fn.func.id == "getattr" and len(fn.args) >= 2
              and isinstance(fn.args[1], ast.Constant)):
            attr = fn.args[1].value
        if attr in method_names:
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
    return names


def read_keys(path: str, cls: Optional[str], func: str,
              source_methods: Set[str]) -> Surface:
    """String keys the method reads (``m["k"]`` / ``m.get("k")``) off
    variables bound to any of ``source_methods``."""
    scope = _find_class(_tree(path), cls) if cls else _tree(path)
    f = _find_func(scope, func)
    receivers = bound_receivers(f, source_methods)
    out: Surface = {}
    for node in ast.walk(f):
        if (isinstance(node, ast.Subscript)
                and isinstance(node.value, ast.Name)
                and node.value.id in receivers
                and isinstance(node.ctx, ast.Load)
                and isinstance(node.slice, ast.Constant)
                and isinstance(node.slice.value, str)):
            out.setdefault(node.slice.value, node.lineno)
        elif (isinstance(node, ast.Call)
              and isinstance(node.func, ast.Attribute)
              and node.func.attr == "get"
              and isinstance(node.func.value, ast.Name)
              and node.func.value.id in receivers
              and node.args and isinstance(node.args[0], ast.Constant)
              and isinstance(node.args[0].value, str)):
            out.setdefault(node.args[0].value, node.lineno)
    return out


# --- contract diffing -----------------------------------------------------
def _two_way(pairs, left: Surface, right: Surface,
             left_only: Dict[str, str], right_only: Dict[str, str],
             *, invariant: str, left_desc: str, right_desc: str,
             left_file: str, right_file: str) -> List[Finding]:
    """Diff two surfaces against a pair list + one-sided allowlists.

    Flags: surface names with neither a mirror nor a declared exemption
    (both directions), and contract entries naming things that no longer
    exist (stale contract)."""
    out: List[Finding] = []
    pair_l = {a for a, _ in pairs}
    pair_r = {b for _, b in pairs}

    def _f(msg, file, line, inv=invariant):
        return Finding(PASS, inv, msg, file=_rel(file), line=line)

    for a, b in pairs:
        if a not in left:
            out.append(_f(f"contract pairs {left_desc} '{a}' <-> "
                          f"{right_desc} '{b}', but '{a}' does not exist",
                          left_file, None, inv="stale-contract"))
        if b not in right:
            out.append(_f(f"contract pairs {left_desc} '{a}' <-> "
                          f"{right_desc} '{b}', but '{b}' does not exist",
                          right_file, None, inv="stale-contract"))
    for name, reason_map, file in ((left_only, left, left_file),
                                   (right_only, right, right_file)):
        for k in name:
            if k not in reason_map:
                out.append(_f(f"contract exempts '{k}' but it no longer "
                              f"exists", file, None, inv="stale-contract"))
    for k, ln in left.items():
        if k not in pair_l and k not in left_only:
            out.append(_f(f"{left_desc} '{k}' has no {right_desc} mirror "
                          f"and no declared exemption", left_file, ln))
    for k, ln in right.items():
        if k not in pair_r and k not in right_only:
            out.append(_f(f"{right_desc} '{k}' has no {left_desc} mirror "
                          f"and no declared exemption", right_file, ln))
    return out


# --- the four checks ------------------------------------------------------
def check_engine_sim_config(engine_path: Optional[str] = None,
                            sim_path: Optional[str] = None
                            ) -> List[Finding]:
    """EngineConfig fields <-> simulate_serving keyword parameters."""
    engine_path = engine_path or module_path("repro.serving.engine")
    sim_path = sim_path or module_path("repro.core.serving_sim")
    return _two_way(
        SPEC.ENGINE_SIM_PAIRS,
        dataclass_fields(engine_path, "EngineConfig"),
        kwonly_params(sim_path, "simulate_serving"),
        SPEC.ENGINE_ONLY_CONFIG, SPEC.SIM_ONLY_PARAMS,
        invariant="config-mirror",
        left_desc="EngineConfig field", right_desc="simulate_serving param",
        left_file=engine_path, right_file=sim_path)


def check_serving_report(sched_path: Optional[str] = None,
                         sim_path: Optional[str] = None) -> List[Finding]:
    """Scheduler.metrics keys <-> ServingReport fields."""
    sched_path = sched_path or module_path("repro.serving.scheduler")
    sim_path = sim_path or module_path("repro.core.serving_sim")
    return _two_way(
        SPEC.SERVING_REPORT_PAIRS,
        dataclass_fields(sim_path, "ServingReport"),
        produced_keys(sched_path, "Scheduler", "metrics"),
        SPEC.SERVING_REPORT_ONLY, SPEC.SCHEDULER_METRICS_ONLY,
        invariant="report-mirror",
        left_desc="ServingReport field", right_desc="Scheduler.metrics key",
        left_file=sim_path, right_file=sched_path)


def check_cluster_report(router_path: Optional[str] = None,
                         sim_path: Optional[str] = None) -> List[Finding]:
    """Router.metrics keys <-> ClusterReport fields."""
    router_path = router_path or module_path("repro.serving.router")
    sim_path = sim_path or module_path("repro.core.serving_sim")
    return _two_way(
        SPEC.CLUSTER_REPORT_PAIRS,
        dataclass_fields(sim_path, "ClusterReport"),
        produced_keys(router_path, "Router", "metrics"),
        SPEC.CLUSTER_REPORT_ONLY, SPEC.ROUTER_METRICS_ONLY,
        invariant="report-mirror",
        left_desc="ClusterReport field", right_desc="Router.metrics key",
        left_file=sim_path, right_file=router_path)


def check_router_aggregation(router_path: Optional[str] = None,
                             router_cls: str = "Router",
                             sched_path: Optional[str] = None
                             ) -> List[Finding]:
    """Router.metrics must consume every scheduler key listed in
    ROUTER_MUST_AGGREGATE (or drop it with a declared reason), and every
    key it does read by name must actually be emitted by
    Scheduler.metrics — the ad-hoc name matching both ways."""
    router_path = router_path or module_path("repro.serving.router")
    sched_path = sched_path or module_path("repro.serving.scheduler")
    emitted = produced_keys(sched_path, "Scheduler", "metrics")
    reads = read_keys(router_path, router_cls, "metrics", {"metrics"})
    scope = _find_class(_tree(router_path), router_cls)
    fline = _find_func(scope, "metrics").lineno
    out: List[Finding] = []
    for k in SPEC.ROUTER_MUST_AGGREGATE:
        if k not in emitted:
            out.append(Finding(PASS, "stale-contract",
                               f"ROUTER_MUST_AGGREGATE lists '{k}' but "
                               f"Scheduler.metrics does not emit it",
                               file=_rel(sched_path)))
        elif k not in reads and k not in SPEC.ROUTER_AGGREGATE_DROPS:
            out.append(Finding(
                PASS, "cluster-aggregation",
                f"Scheduler.metrics emits '{k}' but {router_cls}.metrics "
                f"never aggregates it (and no drop is declared)",
                file=_rel(router_path), line=fline))
    for k, ln in reads.items():
        if k not in emitted:
            out.append(Finding(
                PASS, "phantom-read",
                f"{router_cls}.metrics reads scheduler key '{k}' that "
                f"Scheduler.metrics never emits",
                file=_rel(router_path), line=ln))
    return out


def check_kv_report_reads(sched_path: Optional[str] = None,
                          router_path: Optional[str] = None,
                          engine_path: Optional[str] = None
                          ) -> List[Finding]:
    """Every kv_report / codesign_report key read by Scheduler.metrics or
    Router.metrics must be produced by some engine's report method."""
    sched_path = sched_path or module_path("repro.serving.scheduler")
    router_path = router_path or module_path("repro.serving.router")
    engine_path = engine_path or module_path("repro.serving.engine")
    cache_path = module_path("repro.serving.paged_cache")
    api_path = module_path("repro.serving.replica_api")
    resolve = {"sharing_report": (cache_path, "PagedCache",
                                  "sharing_report"),
               # placement_report returns a typed PlacementReport; its
               # to_dict() is the JSON-boundary key producer
               "placement_report": (api_path, "PlacementReport",
                                    "to_dict")}
    tree = _tree(engine_path)
    kv_produced: Surface = {}
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            try:
                _find_func(node, "kv_report")
            except LookupError:
                continue
            kv_produced.update(produced_keys(engine_path, node.name,
                                             "kv_report", resolve))
    cd_produced: Surface = {}
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            try:
                _find_func(node, "codesign_report")
            except LookupError:
                continue
            cd_produced.update(produced_keys(engine_path, node.name,
                                             "codesign_report"))
    out: List[Finding] = []
    for path, cls in ((sched_path, "Scheduler"), (router_path, "Router")):
        for src, produced, label in ((("kv_report",), kv_produced,
                                      "kv_report"),
                                     (("codesign_report",), cd_produced,
                                      "codesign_report")):
            for k, ln in read_keys(path, cls, "metrics", set(src)).items():
                if k not in produced:
                    out.append(Finding(
                        PASS, "phantom-read",
                        f"{cls}.metrics reads {label} key '{k}' that no "
                        f"engine produces", file=_rel(path), line=ln))
    return out


def check_fused_emit_guard(engine_path: Optional[str] = None,
                           cls: str = "PagedServingEngine",
                           func: str = "_apply_fused") -> List[Finding]:
    """Fused-tick token accounting: every ``req.tokens_out.append`` in the
    fused apply path must sit behind the per-step emit mask.

    The fused scan emits a fixed ``n_steps``-long token sequence per slot
    and a boolean emit mask saying which steps actually ran (the slot may
    finish on eos mid-horizon, or the traced horizon may be shorter than
    the padded scan length).  Appending a token without consulting the
    mask double-counts a finished slot's final token — the token stream
    silently diverges from the per-tick engine.  Statement order decides
    guardedness: an ``if`` whose test mentions the emit mask guards its
    body, and a guarded branch that ends in ``continue``/``break``/
    ``return``/``raise`` guards everything after it in the same body.
    """
    engine_path = engine_path or module_path("repro.serving.engine")
    try:
        scope = _find_class(_tree(engine_path), cls)
        f = _find_func(scope, func)
    except LookupError:
        return [Finding(PASS, "fused-emit-guard",
                        f"{cls}.{func} not found — fused apply path "
                        f"missing or renamed", file=_rel(engine_path))]

    def _is_emit_test(test: ast.expr) -> bool:
        return "emit" in ast.unparse(test)

    def _append_calls(stmts, guarded: bool, out: List[Finding]) -> None:
        shielded = guarded
        for st in stmts:
            if isinstance(st, ast.If) and _is_emit_test(st.test):
                _append_calls(st.body, True, out)
                _append_calls(st.orelse, shielded, out)
                # `if not emit: continue` shields the rest of this body
                if st.body and isinstance(
                        st.body[-1], (ast.Continue, ast.Break,
                                      ast.Return, ast.Raise)):
                    shielded = True
                continue
            if isinstance(st, (ast.For, ast.While)):
                _append_calls(st.body, shielded, out)
                _append_calls(st.orelse, shielded, out)
                continue
            if isinstance(st, ast.If):
                _append_calls(st.body, shielded, out)
                _append_calls(st.orelse, shielded, out)
                continue
            for node in ast.walk(st):
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "append"
                        and isinstance(node.func.value, ast.Attribute)
                        and node.func.value.attr == "tokens_out"
                        and not shielded):
                    out.append(Finding(
                        PASS, "fused-emit-guard",
                        f"{func} appends to tokens_out without consulting "
                        f"the per-step emit mask — a finished slot's token "
                        f"is double-counted on fused ticks",
                        file=_rel(engine_path), line=node.lineno))

    out: List[Finding] = []
    _append_calls(f.body, False, out)
    return out


def check_metrics_registered(sched_path: Optional[str] = None,
                             router_path: Optional[str] = None,
                             sched_cls: str = "Scheduler",
                             router_cls: str = "Router") -> List[Finding]:
    """Every key ``Scheduler.metrics`` / ``Router.metrics`` emits must be
    declared in the metric-name contract next to the registry
    (``repro.obs.metrics``), and — for the real modules — every declared
    name must still be emitted.  The PR-9 drift class: a scheduler grows
    a metric the registry (and its exporters/dashboards) never learn
    about, or a rename leaves a dead name in the contract.

    Fixture paths check the *unregistered* direction only, so a minimal
    fixture class need not re-emit the whole contract.
    """
    out: List[Finding] = []
    for path, dflt_mod, cls, contract, label in (
            (sched_path, "repro.serving.scheduler", sched_cls,
             SPEC.SCHEDULER_METRIC_CONTRACT, "SCHEDULER_METRIC_CONTRACT"),
            (router_path, "repro.serving.router", router_cls,
             SPEC.ROUTER_METRIC_CONTRACT, "ROUTER_METRIC_CONTRACT")):
        is_real = path is None
        if path is None and sched_path is None and router_path is None:
            path = module_path(dflt_mod)
        elif path is None:
            continue                # fixture run: only the given side
        emitted = produced_keys(path, cls, "metrics")
        for k, ln in emitted.items():
            if k not in contract:
                out.append(Finding(
                    PASS, "unregistered-metric",
                    f"{cls}.metrics emits '{k}' but {label} does not "
                    f"declare it — register the metric name in "
                    f"repro.obs.metrics", file=_rel(path), line=ln))
        if is_real:
            for k in contract:
                if k not in emitted:
                    out.append(Finding(
                        PASS, "stale-contract",
                        f"{label} declares '{k}' but {cls}.metrics no "
                        f"longer emits it", file=_rel(path)))
    return out


def check_replica_protocol(impls: Optional[List[Tuple[str, str]]] = None,
                           api_path: Optional[str] = None
                           ) -> List[Finding]:
    """Every declared replica implementation must define the full
    ``replica_api.Replica`` surface (PR 10), and the typed-report
    dataclasses must carry exactly the field lists the spec pins — the
    drift class where the engine grows a replica method (or a report
    field) the sim mirror and the router test stubs never learn about.
    """
    impls = SPEC.REPLICA_IMPLEMENTATIONS if impls is None else impls
    api_path = api_path or module_path("repro.serving.replica_api")
    # src/repro/serving/engine.py -> repo root is three levels up
    root = Path(module_path("repro.serving.engine")).resolve().parents[3]
    out: List[Finding] = []
    for rel, cls in impls:
        path = root / rel
        if not path.exists():
            out.append(Finding(PASS, "stale-contract",
                               f"REPLICA_IMPLEMENTATIONS lists {rel} but "
                               f"the file does not exist", file=rel))
            continue
        try:
            node = _find_class(_tree(str(path)), cls)
        except LookupError:
            out.append(Finding(PASS, "stale-contract",
                               f"REPLICA_IMPLEMENTATIONS lists class "
                               f"{cls} but {rel} does not define it",
                               file=rel))
            continue
        methods = {n.name for n in node.body
                   if isinstance(n, ast.FunctionDef)}
        for m in SPEC.REPLICA_PROTOCOL_METHODS:
            if m not in methods:
                out.append(Finding(
                    PASS, "replica-protocol",
                    f"{cls} ({rel}) does not define replica-protocol "
                    f"method '{m}' — the router drives all replicas "
                    f"through replica_api.Replica", file=rel,
                    line=node.lineno))
    for cls, spec_fields, label in (
            ("LoadReport", SPEC.LOAD_REPORT_FIELDS,
             "LOAD_REPORT_FIELDS"),
            ("PlacementReport", SPEC.PLACEMENT_REPORT_FIELDS,
             "PLACEMENT_REPORT_FIELDS")):
        fields = dataclass_fields(api_path, cls)
        for f in spec_fields:
            if f not in fields:
                out.append(Finding(
                    PASS, "stale-contract",
                    f"{label} pins '{f}' but {cls} no longer has it",
                    file=_rel(api_path)))
        for f, ln in fields.items():
            if f not in spec_fields:
                out.append(Finding(
                    PASS, "replica-protocol",
                    f"{cls} field '{f}' is not pinned in {label} — "
                    f"register it in mirror_spec so the JSON boundary "
                    f"stays audited", file=_rel(api_path), line=ln))
    return out


def run() -> List[Finding]:
    findings: List[Finding] = []
    findings += check_engine_sim_config()
    findings += check_serving_report()
    findings += check_cluster_report()
    findings += check_router_aggregation()
    findings += check_kv_report_reads()
    findings += check_fused_emit_guard()
    findings += check_metrics_registered()
    findings += check_replica_protocol()
    return findings
