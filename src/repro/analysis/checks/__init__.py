"""Invariant checker suite (CI gate: ``python -m repro.analysis.checks``).

Three static passes over the serving stack, each returning
:class:`~repro.analysis.checks.common.Finding` records:

* **kernel-aliasing** (:mod:`kernel_lint`) — traces Pallas kernels and
  jitted scatter paths to their jaxprs and verifies bounds-guarded block
  mappings, scratch routing for inactive/out-of-window lanes, and
  guarded stores to revisited output blocks.
* **allocator-model** (:mod:`allocator_model`) — exhaustive small-scope
  exploration of ``PageAllocator``/``PrefixIndex`` op sequences with
  minimal counterexample traces.
* **mirror-drift** (:mod:`mirror_drift`) — AST diff of the live engine
  against its analytic mirror (config knobs, metric keys, report
  fields) driven by the explicit contract in :mod:`mirror_spec`.

``run_fixture`` points a pass at a regression fixture re-introducing a
historical bug; the CLI must exit non-zero on every one of them.
"""
from __future__ import annotations

from pathlib import Path
from typing import Callable, List, Optional, Sequence

from .common import Finding, render_report  # noqa: F401  (CLI re-export)

PASS_NAMES = ("kernel-aliasing", "allocator-model", "mirror-drift")

_FIXDIR = Path(__file__).resolve().parent / "fixtures"


def run_pass(name: str,
             log: Optional[Callable[[str], None]] = None) -> List[Finding]:
    if name == "kernel-aliasing":
        from . import kernel_lint
        return kernel_lint.run()
    if name == "allocator-model":
        from . import allocator_model
        return allocator_model.run(log=log)
    if name == "mirror-drift":
        from . import mirror_drift
        return mirror_drift.run()
    raise ValueError(f"unknown pass {name!r} (know {PASS_NAMES})")


def run_all(passes: Optional[Sequence[str]] = None,
            log: Optional[Callable[[str], None]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for name in passes or PASS_NAMES:
        findings += run_pass(name, log=log)
    return findings


# --- regression fixtures (seeded historical bugs) -------------------------
def _fx_scatter_clip(log=None) -> List[Finding]:
    from . import kernel_lint
    from .fixtures import pr2_scatter_clip as fx
    return kernel_lint.lint_scatter_token(fx.scatter_token_clipped)


def _fx_inactive_lane(log=None) -> List[Finding]:
    from . import kernel_lint
    return kernel_lint.check_inactive_lane_ast(
        path=str(_FIXDIR / "pr2_inactive_lane.py"))


def _fx_refcount_free(log=None) -> List[Finding]:
    from . import allocator_model as am
    from .fixtures import pr2_refcount_free as fx
    findings = am.explore(am.ModelConfig(num_pages=4, depth=4,
                                         placed=False),
                          allocator_cls=fx.RefcountIgnoringAllocator,
                          log=log)
    findings += am.explore(am.ModelConfig(depth=3),
                           defrag_mapping=fx.cross_region_defrag_mapping,
                           log=log)
    return findings


def _fx_metrics_drift(log=None) -> List[Finding]:
    from . import mirror_drift
    return mirror_drift.check_router_aggregation(
        router_path=str(_FIXDIR / "pr6_metrics_drift.py"))


def _fx_fused_double_count(log=None) -> List[Finding]:
    from . import mirror_drift
    return mirror_drift.check_fused_emit_guard(
        engine_path=str(_FIXDIR / "pr8_fused_double_count.py"))


def _fx_metrics_unregistered(log=None) -> List[Finding]:
    from . import mirror_drift
    return mirror_drift.check_metrics_registered(
        sched_path=str(_FIXDIR / "pr9_metrics_unregistered.py"))


def _fx_ship_trie_drop(log=None) -> List[Finding]:
    from . import allocator_model
    from .fixtures import pr10_ship_trie_drop as fx
    return allocator_model.check_ship_integrity(
        cache_cls=fx.TrieDroppingCache, log=log)


FIXTURES = {
    "pr2-scatter-clip": _fx_scatter_clip,
    "pr2-inactive-lane": _fx_inactive_lane,
    "pr2-refcount-free": _fx_refcount_free,
    "pr6-metrics-drift": _fx_metrics_drift,
    "pr8-fused-double-count": _fx_fused_double_count,
    "pr9-metrics-unregistered": _fx_metrics_unregistered,
    "pr10-ship-trie-drop": _fx_ship_trie_drop,
}
FIXTURE_NAMES = tuple(sorted(FIXTURES))


def run_fixture(name: str,
                log: Optional[Callable[[str], None]] = None
                ) -> List[Finding]:
    try:
        fn = FIXTURES[name]
    except KeyError:
        raise ValueError(
            f"unknown fixture {name!r} (know {FIXTURE_NAMES})") from None
    return fn(log=log)
