"""Pass 1 — kernel aliasing lint.

Traces every Pallas kernel and jitted scatter path to its jaxpr
(abstract eval only, nothing runs) and statically verifies the scratch/
bounds discipline the paged cache depends on:

* **scatter-window-guard** — a write position past the mapped block-table
  window must be *detected* (a comparison against the window length on the
  index dataflow), not silently clipped onto the last live page (the PR-2
  clip-aliasing bug).
* **scatter-scratch-route** — detected out-of-window / inactive lanes must
  be routed to the pool's scratch page (a select whose branch is the
  scratch page index) so no refcount>1 page can be aliased by the write.
* **scatter-active-guard** — the jitted token scatter's destination must
  depend on the ``active`` lane mask (the PR-2 inactive-lane bug wrote
  through stale tables of parked slots).
* **pallas block mappings** — block-table index maps in ``pallas_call``
  grid specs must pass prefetched table values through unmodified (no
  arithmetic that could push a valid page id out of bounds), pure grid
  index maps must stay inside the padded operand, revisited output blocks
  must only be stored under ``pl.when``, and length-prefetching kernels
  must mask invalid positions.
* **host-side guards** (AST) — the engine routes inactive lanes' table
  rows to scratch before invoking the paged Pallas kernel, chunked
  scatter routes shared-prefix blocks to scratch, and the decode step
  resolves copy-on-write *before* any device write.
"""
from __future__ import annotations

import ast
import inspect
import math
from typing import Callable, List, Optional, Sequence

from .common import Finding
from . import jaxpr_utils as JU

PASS = "kernel-aliasing"

# distinctive small-scope dims so guard literals (nblk, scratch page) do
# not collide with unrelated constants in the traced computation
_NBLK = 7
_POOL_PAGES = 13          # scratch page index == 13, page axis size 14


def _loc(fn) -> tuple:
    try:
        target = inspect.unwrap(fn)
        return (inspect.getsourcefile(target),
                inspect.getsourcelines(target)[1])
    except (TypeError, OSError):
        return (None, None)


def _f(invariant: str, message: str, file=None, line=None, detail=None):
    return Finding(PASS, invariant, message, file=file, line=line,
                   detail=detail)


def _has_window_compare(eqns, nblk: int) -> bool:
    """A comparison primitive carrying the window length as *its own*
    literal operand (searched through nested jaxprs eqn-by-eqn, so an
    unrelated pjit that happens to contain both a compare and the
    constant elsewhere does not satisfy the guard)."""
    for e in eqns:
        if e.primitive.name in JU.CMP_PRIMS \
                and nblk in JU.literal_values(e):
            return True
        for sub in JU.subjaxprs(e):
            for se in JU.iter_eqns(sub):
                if se.primitive.name in JU.CMP_PRIMS \
                        and nblk in JU.literal_values(se):
                    return True
    return False


def _routes_to_scratch(eqns, scratch_page: int) -> bool:
    """A select in the slice one of whose branches is the scratch page —
    either as a call-site literal (jnp.where lowers to a pjit taking the
    scalar) or via a one-hop broadcast/convert of the literal."""
    producers = {ov: e for e in eqns for ov in e.outvars}
    for e in eqns:
        if not JU.eqn_is_select(e):
            continue
        cand = [e] + [producers[iv] for iv in e.invars
                      if not isinstance(iv, JU.Literal)
                      and iv in producers]
        if any(JU.eqn_mentions_literal(c, scratch_page) for c in cand):
            return True
    return False


# ----------------------------------------------------------------------
# scatter-path checks (jitted token/chunk scatter, decode_step_paged)
# ----------------------------------------------------------------------
def check_scatter_guards(closed, *, scratch_page: int, nblk: int,
                         active_invar: Optional[int], label: str,
                         file=None, line=None) -> List[Finding]:
    """Verify the guard dataflow of every pool scatter in a traced jaxpr.

    When the scatter sits at the jaxpr's top level the check is a precise
    backward slice from the scatter's index operand; when it is nested in
    a loop (``decode_step_paged`` scatters per layer inside ``fori_loop``
    while the page index is computed once outside) the guard chain is
    checked on the top-level computation feeding the loop: the scratch
    select's predicate must descend from an in-window comparison.
    """
    findings: List[Finding] = []
    jaxpr = closed.jaxpr
    page_axis = scratch_page + 1
    top = JU.find_scatters(jaxpr, page_axis, recursive=False)
    nested = JU.find_scatters(jaxpr, page_axis, recursive=True)
    if not nested:
        return [_f("scatter-missing",
                   f"{label}: traced no write into a {page_axis}-page pool "
                   "(lint target misconfigured?)", file, line)]

    def slice_findings(eqns, sources, where: str) -> List[Finding]:
        out = []
        if not _routes_to_scratch(eqns, scratch_page):
            out.append(_f(
                "scatter-scratch-route",
                f"{label}: {where} has no select routing to the scratch "
                f"page ({scratch_page}) — an out-of-window or inactive "
                "lane would alias a live (possibly shared) page",
                file, line))
        if not _has_window_compare(eqns, nblk):
            out.append(_f(
                "scatter-window-guard",
                f"{label}: {where} never compares the block index against "
                f"the table window ({nblk} blocks) — positions past the "
                "window are clipped onto the last live page instead of "
                "detected (PR-2 clip-aliasing class)",
                file, line))
        if active_invar is not None and sources is not None:
            if jaxpr.invars[active_invar] not in sources:
                out.append(_f(
                    "scatter-active-guard",
                    f"{label}: {where} does not depend on the active-lane "
                    "mask — inactive slots would write through their "
                    "stale block tables (PR-2 inactive-lane class)",
                    file, line))
        return out

    if top:
        for eqn in top:
            if eqn.primitive.name == "dynamic_update_slice":
                seeds = eqn.invars[2:]
            else:
                seeds = [eqn.invars[1]]
            eqns, sources = JU.backward_slice(jaxpr, seeds)
            findings += slice_findings(
                eqns, sources, "the scatter's index dataflow")
        return findings

    # nested scatter: guard chain lives at top level, before the loop.
    selects = [e for e in jaxpr.eqns
               if JU.eqn_is_select(e)
               and _routes_to_scratch([e], scratch_page)]
    if not selects:
        findings.append(_f(
            "scatter-scratch-route",
            f"{label}: no top-level select routes the page index to the "
            f"scratch page ({scratch_page}) before the layer loop",
            file, line))
        # without the select there is no predicate to trace
        eqns = list(jaxpr.eqns)
        findings += [f for f in slice_findings(eqns, None,
                                               "the traced computation")
                     if f.invariant == "scatter-window-guard"]
        return findings
    ok = False
    for sel in selects:
        eqns, _ = JU.backward_slice(jaxpr, list(sel.invars))
        eqns.append(sel)
        if _has_window_compare(eqns, nblk):
            ok = True
    if not ok:
        findings.append(_f(
            "scatter-window-guard",
            f"{label}: the scratch-routing select's predicate does not "
            f"descend from an in-window comparison (< {nblk} blocks)",
            file, line))
    return findings


def lint_scatter_token(fn: Optional[Callable] = None) -> List[Finding]:
    """`paged_cache._scatter_token_jit` (or a fixture reintroducing the
    seed-era clipped variant)."""
    import jax
    import jax.numpy as jnp

    if fn is None:
        from repro.serving import paged_cache as pc
        fn = pc._scatter_token_jit
    raw = inspect.unwrap(fn)
    file, line = _loc(fn)
    L, B, D, ps = 1, 2, 8, 4
    pool = jnp.zeros((L, _POOL_PAGES + 1, ps, D))
    leaf = jnp.zeros((L, B, _NBLK * ps, D))
    tables = jnp.zeros((B, _NBLK), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)
    active = jnp.zeros((B,), bool)
    closed = jax.make_jaxpr(raw)(pool, leaf, tables, pos, active, ps)
    return check_scatter_guards(
        closed, scratch_page=_POOL_PAGES, nblk=_NBLK, active_invar=4,
        label="paged_cache._scatter_token_jit", file=file, line=line)


def lint_decode_step_paged(fn: Optional[Callable] = None) -> List[Finding]:
    """`transformer.decode_step_paged`: the page index feeding the
    per-layer KV scatters must carry the window guard + scratch route."""
    import jax
    import jax.numpy as jnp
    from repro.models import registry
    from repro.models import transformer as T

    fn = fn or T.decode_step_paged
    file, line = _loc(fn)
    entry = registry.get("yi-6b", reduced=True)
    cfg = entry.config
    params = T.init(jax.random.PRNGKey(0), cfg)
    hq, hkv = cfg.padded_heads(1)
    B, ps = 2, 4
    kp = jnp.zeros((cfg.num_layers, _POOL_PAGES + 1, ps, hkv, cfg.d_head))
    vp = jnp.zeros_like(kp)
    tables = jnp.zeros((B, _NBLK), jnp.int32)
    lengths = jnp.zeros((B,), jnp.int32)
    toks = jnp.zeros((B,), jnp.int32)
    closed = jax.make_jaxpr(
        lambda *a: fn(params, cfg, *a))(toks, kp, vp, tables, lengths)
    return check_scatter_guards(
        closed, scratch_page=_POOL_PAGES, nblk=_NBLK, active_invar=None,
        label="transformer.decode_step_paged", file=file, line=line)


# ----------------------------------------------------------------------
# pallas_call block-mapping / output-aliasing lint
# ----------------------------------------------------------------------
def _block_sizes(block_shape) -> Sequence[int]:
    return [b if isinstance(b, int) else 1 for b in block_shape]


def lint_pallas_jaxpr(closed, label: str, file=None, line=None
                      ) -> List[Finding]:
    findings: List[Finding] = []
    calls = JU.find_pallas_calls(closed.jaxpr)
    if not calls:
        return [_f("pallas-missing",
                   f"{label}: traced no pallas_call", file, line)]
    for eqn in calls:
        gm = eqn.params["grid_mapping"]
        grid = tuple(int(g) for g in gm.grid)
        ni = int(getattr(gm, "num_index_operands", 0))
        nin = int(gm.num_inputs)
        nout = int(gm.num_outputs)
        kj = eqn.params["jaxpr"]
        bms = list(gm.block_mappings)
        in_bms, out_bms = bms[:nin], bms[nin:nin + nout]
        base = int(getattr(gm, "num_dynamic_grid_bounds", 0)) + ni
        in_avals = [v.aval for v in eqn.invars[base:base + nin]]
        out_avals = [v.aval for v in eqn.outvars[:nout]]
        table_shapes = [tuple(v.aval.shape)
                        for v in eqn.invars[base - ni:base]]

        pts = list(JU.grid_points(grid)) if math.prod(grid) <= 65536 else \
            list(JU.grid_points(tuple(2 if g > 1 else 1 for g in grid)))

        def analyze(bm, aval, role, j):
            kind = JU.classify_index_map(bm.index_map_jaxpr, len(grid))
            block = _block_sizes(bm.block_shape)
            visits = {}
            if kind == "pure":
                for pt in pts:
                    try:
                        idx = JU.eval_index_map(bm.index_map_jaxpr, grid, pt)
                    except JU.UnanalyzableIndexMap:
                        kind = "other"
                        break
                    for d, (i, bsz) in enumerate(zip(idx, block)):
                        nblocks = -(-int(aval.shape[d]) // bsz)
                        if not (0 <= i < nblocks):
                            findings.append(_f(
                                "pallas-block-bounds",
                                f"{label}: {role} block mapping {j} maps "
                                f"grid point {pt} to block {idx}, outside "
                                f"the padded operand {tuple(aval.shape)}",
                                file, line))
                            return kind, visits
                    visits[idx] = visits.get(idx, 0) + 1
            if kind == "table":
                if role == "output":
                    findings.append(_f(
                        "pallas-output-table-deref",
                        f"{label}: output block mapping {j} addresses the "
                        "output through prefetched table data — data-"
                        "dependent output aliasing cannot be bounded "
                        "statically", file, line))
                else:
                    imj = bm.index_map_jaxpr
                    jx = imj.jaxpr if hasattr(imj, "jaxpr") else imj
                    grid_vars = list(jx.invars[:len(grid)])
                    for g in (e for e in jx.eqns
                              if e.primitive.name == "get"):
                        for pos_i, iv in enumerate(g.invars[1:]):
                            if isinstance(iv, JU.Literal):
                                continue
                            axis = grid_vars.index(iv)
                            tdim = None
                            for ts in table_shapes:
                                if len(ts) > pos_i:
                                    tdim = ts[pos_i]
                            # conservative: the grid axis indexing the
                            # table must not exceed any prefetched
                            # operand's matching dim
                            if tdim is not None and grid[axis] > tdim:
                                findings.append(_f(
                                    "pallas-table-index-bounds",
                                    f"{label}: {role} block mapping {j} "
                                    f"indexes the prefetched table with "
                                    f"grid axis {axis} (size "
                                    f"{grid[axis]}) past the table dim "
                                    f"({tdim})", file, line))
            elif kind == "other":
                findings.append(_f(
                    "pallas-index-map-opaque",
                    f"{label}: {role} block mapping {j} applies arithmetic "
                    "to a table-derived or non-grid index — a valid page "
                    "id could be pushed out of bounds; pass table values "
                    "through unmodified", file, line))
            return kind, visits

        for j, (bm, aval) in enumerate(zip(in_bms, in_avals)):
            analyze(bm, aval, "input", j)
        for j, (bm, aval) in enumerate(zip(out_bms, out_avals)):
            kind, visits = analyze(bm, aval, "output", j)
            if kind == "pure" and visits and max(visits.values()) > 1:
                out_ref = kj.invars[ni + nin + j]
                bad = JU.unguarded_writes_to(kj, [out_ref])
                if bad:
                    findings.append(_f(
                        "pallas-output-aliasing",
                        f"{label}: output block {j} is revisited by "
                        f"{max(visits.values())} grid steps but stored "
                        "unconditionally — later steps clobber earlier "
                        "ones; guard the store with pl.when on the final "
                        "visit", file, line))
        if ni >= 2:
            prims = JU.prim_names(kj)
            if not ({"lt", "le", "gt", "ge"} & prims
                    and "select_n" in prims):
                findings.append(_f(
                    "pallas-length-mask",
                    f"{label}: kernel prefetches lengths but has no "
                    "compare+select masking — scratch/garbage positions "
                    "would contribute to the softmax", file, line))
    return findings


def lint_flash_decode() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.kernels import flash_decode as FD

    findings: List[Finding] = []
    B, Hq, Hkv, D, ps = 2, 4, 2, 16, 8
    q = jnp.zeros((B, Hq, D))
    kp = jnp.zeros((_POOL_PAGES + 1, ps, Hkv, D))
    vp = jnp.zeros_like(kp)
    tables = jnp.zeros((B, _NBLK), jnp.int32)
    lengths = jnp.zeros((B,), jnp.int32)
    file, line = _loc(FD.paged_flash_decode)
    closed = jax.make_jaxpr(
        lambda *a: FD.paged_flash_decode(*a))(q, kp, vp, tables, lengths)
    findings += lint_pallas_jaxpr(closed, "flash_decode.paged_flash_decode",
                                  file, line)
    T = 32
    k = jnp.zeros((B, T, Hkv, D))
    v = jnp.zeros((B, T, Hkv, D))
    file, line = _loc(FD.flash_decode)
    closed = jax.make_jaxpr(
        lambda *a: FD.flash_decode(*a))(q, k, v, lengths)
    findings += lint_pallas_jaxpr(closed, "flash_decode.flash_decode",
                                  file, line)
    return findings


def lint_snake_gemm() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.kernels import snake_gemm as SG

    findings: List[Finding] = []
    m, n, k = 4, 256, 256
    a = jnp.zeros((m, k), jnp.float32)
    b = jnp.zeros((k, n), jnp.float32)
    file, line = _loc(SG.snake_decode_gemm)
    for mp in (SG.GemmMapping("IS", 8, 128, k),
               SG.GemmMapping("OS", 8, 128, 128)):
        closed = jax.make_jaxpr(
            lambda x, y, mp=mp: SG.snake_decode_gemm(x, y, mp))(a, b)
        findings += lint_pallas_jaxpr(
            closed, f"snake_gemm.snake_decode_gemm[{mp.dataflow}]",
            file, line)
    return findings


def lint_wkv6() -> List[Finding]:
    import jax
    import jax.numpy as jnp
    from repro.kernels import wkv6 as W

    b, t, h, hs = 1, 4, 2, 8
    r = jnp.zeros((b, t, h, hs), jnp.float32)
    u = jnp.zeros((h, hs), jnp.float32)
    s0 = jnp.zeros((b, h, hs, hs), jnp.float32)
    file, line = _loc(W.wkv6)
    closed = jax.make_jaxpr(
        lambda *a: W.wkv6(*a))(r, r, r, r, u, s0)
    return lint_pallas_jaxpr(closed, "wkv6.wkv6", file, line)


# ----------------------------------------------------------------------
# host-side guard checks (AST)
# ----------------------------------------------------------------------
def _parse(path: str) -> ast.Module:
    with open(path, "r") as fh:
        return ast.parse(fh.read(), filename=path)


def _find_funcs(tree: ast.Module, name: str) -> List[ast.FunctionDef]:
    return [n for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef) and n.name == name]


def _has_where_guard(func: ast.FunctionDef, *needles: str) -> bool:
    """A ``*.where(...)`` call whose argument source mentions every
    needle — the host-side scratch-routing idiom."""
    for node in ast.walk(func):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "where"):
            src = " ".join(ast.unparse(a) for a in node.args)
            if all(n in src for n in needles):
                return True
    return False


def _calls_in_order(func: ast.FunctionDef, first: str, second: str) -> bool:
    """``first(...)`` is invoked at a smaller line than ``second(...)``."""
    lines = {first: None, second: None}
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            name = (node.func.attr if isinstance(node.func, ast.Attribute)
                    else getattr(node.func, "id", ""))
            if name in lines and lines[name] is None:
                lines[name] = node.lineno
    return (lines[first] is not None and lines[second] is not None
            and lines[first] < lines[second])


def check_inactive_lane_ast(path: Optional[str] = None,
                            func_name: str = "_decode_paged_pallas"
                            ) -> List[Finding]:
    """The engine must route *inactive* lanes' block-table rows to the
    scratch page before handing tables to the Pallas kernel: the kernel
    writes every lane unconditionally, so an inactive lane with mapped
    (possibly shared) pages would be corrupted (PR-2 inactive-lane bug)."""
    if path is None:
        from repro.serving import engine as E
        path = inspect.getsourcefile(E)
    tree = _parse(path)
    funcs = _find_funcs(tree, func_name)
    if not funcs:
        return [_f("host-inactive-lane",
                   f"no function {func_name} found", path)]
    out = []
    for fn in funcs:
        if not _has_where_guard(fn, "active", "num_pages"):
            out.append(_f(
                "host-inactive-lane",
                f"{func_name} never routes inactive lanes to the scratch "
                "page (expected a where(active, ..., num_pages) on the "
                "table rows before the kernel call)",
                path, fn.lineno))
    return out


def check_scatter_chunk_ast(path: Optional[str] = None) -> List[Finding]:
    """`PagedCache.scatter_chunk` must route shared-prefix blocks to the
    scratch page — chunked prefill over a CoW-shared prefix would
    otherwise overwrite pages other slots still read."""
    if path is None:
        from repro.serving import paged_cache as PC
        path = inspect.getsourcefile(PC)
    tree = _parse(path)
    funcs = _find_funcs(tree, "scatter_chunk")
    if not funcs:
        return [_f("host-shared-chunk-route",
                   "no scatter_chunk found", path)]
    out = []
    for fn in funcs:
        if not _has_where_guard(fn, "shared_count", "num_pages"):
            out.append(_f(
                "host-shared-chunk-route",
                "scatter_chunk does not route shared-prefix blocks to "
                "the scratch page (expected where(blk < shared_count, "
                "num_pages, ...))", path, fn.lineno))
    return out


def check_cow_order_ast(path: Optional[str] = None) -> List[Finding]:
    """CoW-before-write: the per-step grow hook must fork shared pages
    (`cow_for_write`) and run *before* the device decode write."""
    if path is None:
        from repro.serving import engine as E
        path = inspect.getsourcefile(E)
    tree = _parse(path)
    out = []
    grows = _find_funcs(tree, "_pre_decode_grow")
    paged_grow = [g for g in grows
                  if any(isinstance(n, ast.Call)
                         and isinstance(n.func, ast.Attribute)
                         and n.func.attr == "cow_for_write"
                         for n in ast.walk(g))]
    if not paged_grow:
        out.append(_f(
            "host-cow-before-write",
            "no _pre_decode_grow variant calls cow_for_write — shared "
            "pages would be written in place", path,
            grows[0].lineno if grows else None))
    steps = [s for s in _find_funcs(tree, "step")
             if _calls_in_order(s, "_pre_decode_grow", "_decode_batch")]
    if not steps:
        out.append(_f(
            "host-cow-before-write",
            "no step() invokes _pre_decode_grow before _decode_batch — "
            "the CoW fork must precede the device write", path))
    return out


# ----------------------------------------------------------------------
def run() -> List[Finding]:
    findings: List[Finding] = []
    findings += lint_scatter_token()
    findings += lint_decode_step_paged()
    findings += lint_flash_decode()
    findings += lint_snake_gemm()
    findings += lint_wkv6()
    findings += check_inactive_lane_ast()
    findings += check_scatter_chunk_ast()
    findings += check_cow_order_ast()
    return findings
