"""Roofline analysis from the compiled dry-run artifact (no real hardware).

Three terms per (arch x shape x mesh) cell, all in seconds-per-step on a
TPU v5e chip (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):

  compute    = HLO_FLOPs_per_device / peak_FLOPs
  memory     = HLO_bytes_per_device / HBM_bw
  collective = wire_bytes_per_device / ICI_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` of the SPMD-partitioned
module (already per-device).  Collective bytes are NOT in cost_analysis —
they are parsed from the partitioned HLO text: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute contributes
ring-schedule wire bytes derived from its shape and replica-group size.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.hw import (TPU_V5E_HBM_BW, TPU_V5E_HBM_GB, TPU_V5E_ICI_BW,
                           TPU_V5E_PEAK_FLOPS)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?P<lhs>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(?P<dt>pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|"
                       r"s64|u64)\[(?P<dims>[0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _shape_bytes(lhs: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(lhs):
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group("dt")]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclass
class CollectiveProfile:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    wire_bytes: int = 0           # ring-schedule bytes per device
    count: int = 0

    def add(self, op: str, payload: int, wire: int):
        self.bytes_by_op[op] = self.bytes_by_op.get(op, 0) + payload
        self.wire_bytes += wire
        self.count += 1


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveProfile:
    prof = CollectiveProfile()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        out_bytes = _shape_bytes(m.group("lhs"))
        p = max(2, _group_size(line, n_devices))
        if op == "all-reduce":
            wire = int(2 * (p - 1) / p * out_bytes)
        elif op == "all-gather":
            # output is the gathered tensor; each device receives (p-1)/p
            wire = int((p - 1) / p * out_bytes)
        elif op == "reduce-scatter":
            # output is the scattered shard; input = p * output
            wire = int((p - 1) * out_bytes)
        elif op == "all-to-all":
            wire = int((p - 1) / p * out_bytes)
        else:  # collective-permute
            wire = out_bytes
        prof.add(op, out_bytes, wire)
    return prof


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective: CollectiveProfile
    memory_stats: Optional[dict] = None
    model_flops: Optional[float] = None   # 6*N*D (dense) / 6*N_active*D

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / TPU_V5E_PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / TPU_V5E_HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective.wire_bytes / TPU_V5E_ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """max(term)/sum-ish utilization proxy: dominant term over the sum —
        1.0 means perfectly overlapped single bottleneck."""
        tot = self.t_compute + self.t_memory + self.t_collective
        return max(self.t_compute, self.t_memory, self.t_collective) / tot \
            if tot else 0.0

    @property
    def useful_flops_fraction(self) -> Optional[float]:
        if self.model_flops is None or not self.flops_per_device:
            return None
        return self.model_flops / self.n_devices / self.flops_per_device

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "devices": self.n_devices,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_wire_bytes": self.collective.wire_bytes,
            "collective_by_op": self.collective.bytes_by_op,
            "collective_count": self.collective.count,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_fraction": self.useful_flops_fraction,
            "memory_stats": self.memory_stats,
        }


def analyze(compiled, *, arch: str, shape: str, mesh_name: str,
            n_devices: int, model_flops: Optional[float] = None
            ) -> RooflineReport:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # older JAX: one dict per device
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    prof = parse_collectives(compiled.as_text(), n_devices)
    ms = None
    try:
        m = compiled.memory_analysis()
        ms = {k: int(getattr(m, k)) for k in
              ("argument_size_in_bytes", "output_size_in_bytes",
               "temp_size_in_bytes", "alias_size_in_bytes")}
        ms["total_hbm_bytes"] = (ms["argument_size_in_bytes"]
                                 + ms["temp_size_in_bytes"]
                                 + ms["output_size_in_bytes"]
                                 - ms["alias_size_in_bytes"])
        ms["fits_v5e_16gb"] = ms["total_hbm_bytes"] <= TPU_V5E_HBM_GB * 2**30
    except Exception:
        pass
    return RooflineReport(arch=arch, shape=shape, mesh=mesh_name,
                          n_devices=n_devices, flops_per_device=flops,
                          hbm_bytes_per_device=hbm, collective=prof,
                          memory_stats=ms, model_flops=model_flops)


# ---------------------------------------------------------------------------
# Scan-undercount corrections.
#
# XLA's cost_analysis counts a while-loop (lax.scan / lax.map) body ONCE, not
# times the trip count.  Three loops matter in this codebase:
#   1. the layer scan           -> corrected by L-differential extrapolation
#                                  (compile at L0 and 2*L0 layers, take the
#                                  per-layer slope) — see launch/dryrun.py;
#   2. blocked attention's (q-block x kv-block) loops inside each layer
#                                  -> corrected analytically below;
#   3. the chunked-CE loss scan over sequence chunks (train only)
#                                  -> corrected analytically below.
# wkv6 / RG-LRU associative scans are bandwidth-shaped, contribute <1% of
# FLOPs, and are left uncorrected (documented in EXPERIMENTS.md).
# ---------------------------------------------------------------------------
_Q_BLOCK = 512   # layers.blocked_attention defaults
_KV_BLOCK = 512
_CE_CHUNK = 512


def _attn_layer_flops(b: int, s_q: int, s_kv: int, hq: int, dh: int) -> float:
    """QK + AV flops for one blocked-attention call (full S^2; masking does
    not skip blocks in the reference implementation)."""
    return 4.0 * b * hq * s_q * s_kv * dh


def _attn_layer_kv_bytes(b: int, s_kv: int, hkv: int, dh: int,
                         nq: int) -> float:
    """K+V bytes re-streamed once per q-block beyond the first."""
    return 2.0 * b * s_kv * hkv * dh * 2 * max(0, nq - 1)


def analytic_corrections(cfg, shape, tp: int, n_devices: int) -> dict:
    """Per-DEVICE (flops, bytes) to ADD to the L-extrapolated measured cost.

    Only applies to train/prefill kinds (decode attention is a plain einsum
    and is fully counted).  All totals are divided by the device count —
    attention shards over (batch x heads) and the CE head over the model
    axis, so per-device work is total/devices to first order.
    """
    kind = shape.kind
    out = {"flops": 0.0, "bytes": 0.0}
    if kind not in ("train", "prefill"):
        return out
    b, s = shape.global_batch, shape.seq_len
    hq, hkv = cfg.padded_heads(tp)
    dh = cfg.d_head
    L = cfg.num_layers

    def add_attn(n_layers, s_q, s_kv, b_=None):
        b_ = b_ or b
        nq = -(-s_q // _Q_BLOCK)
        nk = -(-s_kv // _KV_BLOCK)
        fl = _attn_layer_flops(b_, s_q, s_kv, hq, dh)
        out["flops"] += n_layers * fl * (1.0 - 1.0 / (nq * nk))
        out["bytes"] += n_layers * _attn_layer_kv_bytes(b_, s_kv, hkv, dh, nq)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        add_attn(L, s, s)
    elif fam == "hybrid":
        n_attn = sum(1 for x in cfg.block_pattern if x == "attn")
        periods = L / max(1, len(cfg.block_pattern))
        add_attn(periods * n_attn, s, s)
    elif fam == "audio":
        f = cfg.encoder_frames
        add_attn(cfg.encoder_layers, f, f)      # encoder self
        add_attn(L, s, s)                       # decoder self
        add_attn(L, s, f)                       # decoder cross
    # ssm: no attention loops

    if kind == "train":
        v = cfg.padded_vocab(tp)
        d = cfg.d_model
        nch = max(1, s // _CE_CHUNK)
        ce_flops = 2.0 * b * s * d * v
        out["flops"] += ce_flops * (1.0 - 1.0 / nch)
        # the (d x V) head weight is re-read once per chunk beyond the first
        out["bytes"] += (nch - 1) * d * v * 4.0   # f32 in the loss

    out["flops"] /= n_devices
    out["bytes"] /= n_devices
    return out


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for train;
    2*N_active*tokens for inference steps."""
    spec = cfg.nmp_spec()
    n_active = spec.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch          # one token per request
    return 2.0 * n_active * tokens
