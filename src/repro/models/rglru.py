"""RecurrentGemma: RG-LRU recurrent blocks + local sliding-window attention,
interleaved 2:1 (rec, rec, attn).

The RG-LRU recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
is a data-gated linear recurrence -> parallelized over the sequence with
``lax.associative_scan`` for train/prefill and O(1) state for decode, which
is what makes the 500k-context decode cell runnable.

Layer schedule: the 38 layers are executed as scan over 12 homogeneous
(rec, rec, attn) groups plus a 2-layer recurrent tail (38 = 12*3 + 2).
Local attention keeps a ``window``-sized rolling KV cache.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ArchConfig

Params = Dict[str, Any]
C_LRU = 8.0


def _pattern_counts(cfg: ArchConfig) -> Tuple[int, int]:
    """(#groups, #tail-rec-layers) with group = (rec, rec, attn)."""
    groups = cfg.num_layers // 3
    tail = cfg.num_layers - groups * 3
    return groups, tail


class RGState(NamedTuple):
    lru_h: jax.Array      # (Lr, B, W) recurrent hidden (float32)
    conv: jax.Array       # (Lr, B, conv_width-1, W) conv lookback
    k_cache: jax.Array    # (La, B, window, Hkv, D)
    v_cache: jax.Array
    pos_cache: jax.Array  # (La, B, window) absolute positions, -1 = empty
    lengths: jax.Array    # (B,)

    @staticmethod
    def zeros(cfg: ArchConfig, batch: int):
        groups, tail = _pattern_counts(cfg)
        lr, la = groups * 2 + tail, groups
        w = cfg.lru_width or cfg.d_model
        _, hkv = cfg.padded_heads(1)
        dt = L._dtype(cfg.dtype)
        return RGState(
            jnp.zeros((lr, batch, w), jnp.float32),
            jnp.zeros((lr, batch, cfg.conv_width - 1, w), dt),
            jnp.zeros((la, batch, cfg.window, hkv, cfg.d_head), dt),
            jnp.zeros((la, batch, cfg.window, hkv, cfg.d_head), dt),
            jnp.full((la, batch, cfg.window), -1, jnp.int32),
            jnp.zeros((batch,), jnp.int32))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_rec_layer(key, cfg: ArchConfig, dtype) -> Params:
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 8)
    scale_o = 0.02 / math.sqrt(2 * cfg.num_layers)
    return {
        "ln": L.init_norm(cfg.norm, d),
        "w_in_x": L.dense_init(ks[0], d, w, dtype),
        "w_in_gate": L.dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32)
                   * 0.02).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "lru": {
            "lam": jax.random.uniform(ks[3], (w,), jnp.float32, 0.9, 0.999),
            "w_a": L.dense_init(ks[4], w, w, dtype),
            "b_a": jnp.zeros((w,), jnp.float32),
            "w_i": L.dense_init(ks[5], w, w, dtype),
            "b_i": jnp.zeros((w,), jnp.float32),
        },
        "w_out": L.dense_init(ks[6], w, d, dtype, scale=scale_o),
        "mlp": L.init_ffn(ks[7], d, cfg.d_ff, cfg.gated_ffn, dtype,
                          cfg.num_layers),
        "ln_mlp": L.init_norm(cfg.norm, d),
    }


def _init_attn_layer(key, cfg: ArchConfig, dtype, hq, hkv) -> Params:
    ka, kf = jax.random.split(key)
    return {
        "ln": L.init_norm(cfg.norm, cfg.d_model),
        "attn": L.init_attention(ka, cfg, dtype, hq, hkv),
        "ln_mlp": L.init_norm(cfg.norm, cfg.d_model),
        "mlp": L.init_ffn(kf, cfg.d_model, cfg.d_ff, cfg.gated_ffn, dtype,
                          cfg.num_layers),
    }


def init(key, cfg: ArchConfig, tp: int = 1) -> Params:
    dtype = L._dtype(cfg.dtype)
    hq, hkv = cfg.padded_heads(tp)
    groups, tail = _pattern_counts(cfg)
    ke, kr, ka, kt = jax.random.split(key, 4)
    rec_grp = jax.vmap(lambda k: jax.vmap(
        lambda k2: _init_rec_layer(k2, cfg, dtype))(jax.random.split(k, 2)))(
        jax.random.split(kr, groups))                    # (G, 2, ...)
    attn_grp = jax.vmap(lambda k: _init_attn_layer(k, cfg, dtype, hq, hkv))(
        jax.random.split(ka, groups))                    # (G, ...)
    p = {"embed": L.init_embed(ke, cfg.padded_vocab(tp), cfg.d_model, dtype,
                               cfg.tie_embeddings),
         "rec_groups": rec_grp, "attn_groups": attn_grp,
         "ln_f": L.init_norm(cfg.norm, cfg.d_model)}
    if tail:
        p["rec_tail"] = jax.vmap(lambda k: _init_rec_layer(k, cfg, dtype))(
            jax.random.split(kt, tail))
    return p


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------
def _lru_gates(lp, x):
    """x: (..., W) -> (a, gated_input) both float32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ lp["w_a"].astype(jnp.float32) + lp["b_a"])
    i = jax.nn.sigmoid(xf @ lp["w_i"].astype(jnp.float32) + lp["b_i"])
    log_a = -C_LRU * jax.nn.softplus(lp["lam"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, gated


def _lru_seq(lp, x, h0):
    """Associative scan over the sequence.  x: (B,S,W); h0: (B,W)."""
    a, b = _lru_gates(lp, x)                              # (B,S,W)
    # fold initial state into the first step: b0' = a0*h0 + b0
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]                                    # (B,S,W), (B,W)


def _lru_step(lp, x_t, h):
    a, b = _lru_gates(lp, x_t)                            # (B,W)
    h = a * h + b
    return h, h


def _conv1d_seq(lp, x, lookback):
    """Causal temporal conv, width cw.  x: (B,S,W); lookback: (B,cw-1,W)."""
    cw = lp["conv_w"].shape[0]
    xx = jnp.concatenate([lookback.astype(x.dtype), x], axis=1)
    out = sum(xx[:, i:i + x.shape[1]] * lp["conv_w"][i][None, None, :]
              for i in range(cw))
    new_lookback = xx[:, -(cw - 1):] if cw > 1 else lookback
    return out + lp["conv_b"], new_lookback


def _rec_block_seq(cfg, lp, x, h0, conv0):
    """x: (B,S,d)."""
    h = L.apply_norm(cfg.norm, lp["ln"], x)
    gate = jax.nn.gelu(h @ lp["w_in_gate"])
    xx = h @ lp["w_in_x"]
    xx, conv = _conv1d_seq(lp, xx, conv0)
    y, h_last = _lru_seq(lp["lru"], xx, h0)
    y = (y.astype(gate.dtype) * gate) @ lp["w_out"]
    x = x + y
    m = L.apply_norm(cfg.norm, lp["ln_mlp"], x)
    return x + L.apply_ffn(lp["mlp"], m, cfg.act), h_last, conv


def _attn_block_seq(cfg, lp, x, positions, hq, hkv):
    h = L.apply_norm(cfg.norm, lp["ln"], x)
    q, k, v = L.qkv_project(lp["attn"], h, hq, hkv, cfg.d_head)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    attn = L.blocked_attention(q, k, v, causal=True, window=cfg.window)
    b, s = x.shape[:2]
    x = x + attn.reshape(b, s, hq * cfg.d_head) @ lp["attn"]["wo"]
    m = L.apply_norm(cfg.norm, lp["ln_mlp"], x)
    return x + L.apply_ffn(lp["mlp"], m, cfg.act), k, v


def forward_seq(params, cfg: ArchConfig, tokens, tp: int = 1,
                remat: bool = True, collect_cache: bool = False):
    hq, hkv = cfg.padded_heads(tp)
    groups, tail = _pattern_counts(cfg)
    x = L.embed(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    w = cfg.lru_width or cfg.d_model

    def group(carry, gp):
        x = carry
        rec2, attnp = gp
        kv = None
        for i in range(2):
            lp = jax.tree.map(lambda a: a[i], rec2)
            x, _, _ = _rec_block_seq(cfg, lp, x,
                                     jnp.zeros((b, w), jnp.float32),
                                     jnp.zeros((b, cfg.conv_width - 1, w),
                                               x.dtype))
        x, k, v = _attn_block_seq(cfg, attnp, x, positions, hq, hkv)
        return x, (k, v)

    if remat:
        group = jax.checkpoint(group)
    x, kv = lax.scan(group, x, (params["rec_groups"], params["attn_groups"]),
                     unroll=cfg.scan_unroll)
    tail_state = []
    if tail:
        def tail_block(x, lp):
            x, h_last, conv = _rec_block_seq(
                cfg, lp, x, jnp.zeros((b, w), jnp.float32),
                jnp.zeros((b, cfg.conv_width - 1, w), x.dtype))
            return x, (h_last, conv)
        x, tail_state = lax.scan(tail_block, x, params["rec_tail"])
    x = L.apply_norm(cfg.norm, params["ln_f"], x)
    return x, kv


def loss(params, cfg: ArchConfig, batch, tp: int = 1):
    h, _ = forward_seq(params, cfg, batch["tokens"], tp=tp)
    return L.lm_loss_chunked(params["embed"], h, batch["labels"],
                             batch.get("mask"))


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------
def prefill(params, cfg: ArchConfig, tokens, tp: int = 1, max_seq=None):
    """Returns (last_logits, RGState).  Processes the whole prompt with the
    parallel scan, keeping the final recurrent states and the last `window`
    keys/values for the local-attention layers."""
    hq, hkv = cfg.padded_heads(tp)
    groups, tail = _pattern_counts(cfg)
    x = L.embed(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    w = cfg.lru_width or cfg.d_model
    win = cfg.window
    state = RGState.zeros(cfg, b)

    def group(carry, gp):
        x = carry
        rec2, attnp = gp
        hs, convs = [], []
        for i in range(2):
            lp = jax.tree.map(lambda a: a[i], rec2)
            x, h_last, conv = _rec_block_seq(
                cfg, lp, x, jnp.zeros((b, w), jnp.float32),
                jnp.zeros((b, cfg.conv_width - 1, w), x.dtype))
            hs.append(h_last)
            convs.append(conv)
        x, k, v = _attn_block_seq(cfg, attnp, x, positions, hq, hkv)
        # rolling window: keep last `win` entries
        if s >= win:
            kw, vw = k[:, -win:], v[:, -win:]
            pw = jnp.broadcast_to(jnp.arange(s - win, s)[None, :], (b, win))
        else:
            # left-pad: newest entry must sit at the END so the decode-time
            # left-roll evicts padding first, then the true oldest token.
            pad = win - s
            kw = jnp.pad(k, [(0, 0), (pad, 0), (0, 0), (0, 0)])
            vw = jnp.pad(v, [(0, 0), (pad, 0), (0, 0), (0, 0)])
            pw = jnp.concatenate(
                [jnp.full((b, pad), -1, jnp.int32),
                 jnp.broadcast_to(jnp.arange(s)[None], (b, s))], axis=1)
        return x, (jnp.stack(hs), jnp.stack(convs), kw, vw, pw)

    x, (hs, convs, kc, vc, pc) = lax.scan(
        group, x, (params["rec_groups"], params["attn_groups"]),
        unroll=cfg.scan_unroll)
    lru_h = hs.reshape(groups * 2, b, w)
    conv = convs.reshape(groups * 2, b, cfg.conv_width - 1, w)
    if tail:
        def tail_block(x, lp):
            x, h_last, cv = _rec_block_seq(
                cfg, lp, x, jnp.zeros((b, w), jnp.float32),
                jnp.zeros((b, cfg.conv_width - 1, w), x.dtype))
            return x, (h_last, cv)
        x, (th, tc) = lax.scan(tail_block, x, params["rec_tail"])
        lru_h = jnp.concatenate([lru_h, th], axis=0)
        conv = jnp.concatenate([conv, tc], axis=0)
    x = L.apply_norm(cfg.norm, params["ln_f"], x)
    logits = L.unembed(params["embed"], x[:, -1])
    st = RGState(lru_h, conv, kc, vc, pc, jnp.full((b,), s, jnp.int32))
    return logits, st


def _rec_block_step(cfg, lp, x, h0, conv0):
    """Single-token recurrent block.  x: (B,d)."""
    h = L.apply_norm(cfg.norm, lp["ln"], x)
    gate = jax.nn.gelu(h @ lp["w_in_gate"])
    xx = h @ lp["w_in_x"]                                 # (B,W)
    hist = jnp.concatenate([conv0.astype(xx.dtype), xx[:, None]], axis=1)
    cw = lp["conv_w"].shape[0]
    y = sum(hist[:, i] * lp["conv_w"][i][None, :] for i in range(cw))
    y = y + lp["conv_b"]
    conv = hist[:, 1:]
    hstate, y = _lru_step(lp["lru"], y, h0)
    y = (y.astype(gate.dtype) * gate) @ lp["w_out"]
    x = x + y
    m = L.apply_norm(cfg.norm, lp["ln_mlp"], x)
    return x + L.apply_ffn(lp["mlp"], m, cfg.act), (hstate, conv)


def _attn_block_step(cfg, lp, x, kc, vc, pc, pos, hq, hkv):
    """Single-token local attention with rolling window cache.  x: (B,d)."""
    b = x.shape[0]
    h = L.apply_norm(cfg.norm, lp["ln"], x[:, None])
    q, k, v = L.qkv_project(lp["attn"], h, hq, hkv, cfg.d_head)
    posb = jnp.broadcast_to(pos[:, None], (b, 1))
    q = L.apply_rope(q, posb, cfg.rope_theta)
    k = L.apply_rope(k, posb, cfg.rope_theta)
    # roll the window left by one, append the new entry at the end
    kc = jnp.concatenate([kc[:, 1:], k], axis=1)
    vc = jnp.concatenate([vc[:, 1:], v], axis=1)
    pc = jnp.concatenate([pc[:, 1:], posb], axis=1)
    valid = pc >= 0
    acc, l, _ = L.decode_attention_core(q[:, 0], kc, vc, valid)
    out = (acc / jnp.maximum(l, 1e-20)[..., None]).reshape(b, hq * cfg.d_head)
    x = x + out.astype(x.dtype) @ lp["attn"]["wo"]
    m = L.apply_norm(cfg.norm, lp["ln_mlp"], x)
    return x + L.apply_ffn(lp["mlp"], m, cfg.act), kc, vc, pc


def decode_step(params, cfg: ArchConfig, tokens, state: RGState,
                tp: int = 1):
    hq, hkv = cfg.padded_heads(tp)
    groups, tail = _pattern_counts(cfg)
    x = L.embed(params["embed"], tokens)                  # (B,d)
    pos = state.lengths

    def grp(carry, inp):
        x = carry
        gp, h2, c2, kc, vc, pc = inp
        rec2, attnp = gp
        hs, cs = [], []
        for i in range(2):
            lp = jax.tree.map(lambda a: a[i], rec2)
            x, (hn, cn) = _rec_block_step(cfg, lp, x, h2[i], c2[i])
            hs.append(hn)
            cs.append(cn)
        x, kc, vc, pc = _attn_block_step(cfg, attnp, x, kc, vc, pc, pos,
                                         hq, hkv)
        return x, (jnp.stack(hs), jnp.stack(cs), kc, vc, pc)

    g2 = groups * 2
    h_grp = state.lru_h[:g2].reshape(groups, 2, *state.lru_h.shape[1:])
    c_grp = state.conv[:g2].reshape(groups, 2, *state.conv.shape[1:])
    x, (hs, cs, kc, vc, pc) = lax.scan(
        grp, x, ((params["rec_groups"], params["attn_groups"]),
                 h_grp, c_grp, state.k_cache, state.v_cache,
                 state.pos_cache), unroll=cfg.scan_unroll)
    lru_h = hs.reshape(g2, *state.lru_h.shape[1:])
    conv = cs.reshape(g2, *state.conv.shape[1:])
    if tail:
        def tail_block(x, inp):
            lp, h0, c0 = inp
            x, (hn, cn) = _rec_block_step(cfg, lp, x, h0, c0)
            return x, (hn, cn)
        x, (th, tc) = lax.scan(tail_block, x,
                               (params["rec_tail"], state.lru_h[g2:],
                                state.conv[g2:]))
        lru_h = jnp.concatenate([lru_h, th], axis=0)
        conv = jnp.concatenate([conv, tc], axis=0)
    x = L.apply_norm(cfg.norm, params["ln_f"], x)
    logits = L.unembed(params["embed"], x)
    return logits, RGState(lru_h, conv, kc, vc, pc, state.lengths + 1)
