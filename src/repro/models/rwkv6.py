"""RWKV6 "Finch" — attention-free SSM with data-dependent decay.

Per layer: time-mix (token-shift ddlerp -> r/k/v/g/w projections -> WKV6
linear-attention recurrence with per-channel data-dependent decay + bonus)
and channel-mix (token-shift gated FFN).  Decode state is O(d_model) per
layer, so the 500k-context cell runs with constant memory.

Recurrence (head size hs, per head):
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ArchConfig

Params = Dict[str, Any]


class RWKVState(NamedTuple):
    tm_x: jax.Array     # (L, B, d)   last token for time-mix shift
    cm_x: jax.Array     # (L, B, d)   last token for channel-mix shift
    wkv: jax.Array      # (L, B, H, hs, hs) recurrence state (float32)
    lengths: jax.Array  # (B,)

    @staticmethod
    def zeros(cfg: ArchConfig, batch: int):
        heads = cfg.d_model // cfg.rwkv_head_size
        hs = cfg.rwkv_head_size
        dt = L._dtype(cfg.dtype)
        return RWKVState(
            jnp.zeros((cfg.num_layers, batch, cfg.d_model), dt),
            jnp.zeros((cfg.num_layers, batch, cfg.d_model), dt),
            jnp.zeros((cfg.num_layers, batch, heads, hs, hs), jnp.float32),
            jnp.zeros((batch,), jnp.int32))


def _init_layer(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    lora = max(32, d // 64)
    scale_o = 0.02 / math.sqrt(2 * cfg.num_layers)
    heads = d // cfg.rwkv_head_size
    return {
        "ln1": L.init_norm("layernorm", d),
        "ln2": L.init_norm("layernorm", d),
        "mix": {  # ddlerp mixing coefficients for r,k,v,g,w
            "mu": (jax.random.uniform(ks[0], (5, d)) * 0.5 + 0.25
                   ).astype(dtype),
        },
        "wr": L.dense_init(ks[1], d, d, dtype),
        "wk": L.dense_init(ks[2], d, d, dtype),
        "wv": L.dense_init(ks[3], d, d, dtype),
        "wg": L.dense_init(ks[4], d, d, dtype),
        "wo": L.dense_init(ks[5], d, d, dtype, scale=scale_o),
        "w_decay": {
            "w0": jnp.full((d,), -6.0, jnp.float32),
            "a": L.dense_init(ks[6], d, lora, dtype),
            "b": L.dense_init(ks[7], lora, d, dtype),
        },
        "u_bonus": (jax.random.normal(ks[8], (heads, cfg.rwkv_head_size),
                                      jnp.float32) * 0.1),
        "gn": {"scale": jnp.ones((d,), jnp.float32),
               "bias": jnp.zeros((d,), jnp.float32)},
        "cm": {
            "ln": L.init_norm("layernorm", d),
            "mu": (jax.random.uniform(ks[9], (2, d)) * 0.5 + 0.25
                   ).astype(dtype),
            "wk": L.dense_init(ks[0], d, cfg.d_ff, dtype),
            "wv": L.dense_init(ks[1], cfg.d_ff, d, dtype, scale=scale_o),
            "wr": L.dense_init(ks[2], d, d, dtype),
        },
    }


def init(key, cfg: ArchConfig, tp: int = 1) -> Params:
    dtype = L._dtype(cfg.dtype)
    ke, kl = jax.random.split(key)
    blocks = jax.vmap(lambda k: _init_layer(k, cfg, dtype))(
        jax.random.split(kl, cfg.num_layers))
    return {"embed": L.init_embed(ke, cfg.padded_vocab(tp), cfg.d_model,
                                  dtype, cfg.tie_embeddings),
            "blocks": blocks,
            "ln_f": L.init_norm("layernorm", cfg.d_model)}


def _group_norm(p, x, heads):
    b, d = x.shape
    xg = x.reshape(b, heads, d // heads).astype(jnp.float32)
    mu = xg.mean(-1, keepdims=True)
    var = xg.var(-1, keepdims=True)
    xg = (xg - mu) * lax.rsqrt(var + 1e-5)
    return (xg.reshape(b, d) * p["scale"] + p["bias"])


def _time_mix_step(cfg, lp, x_t, prev_x, state):
    """One token through the time-mix block.  x_t: (B, d)."""
    heads, hs = cfg.d_model // cfg.rwkv_head_size, cfg.rwkv_head_size
    mu = lp["mix"]["mu"]                              # (5, d)
    xs = prev_x + (x_t - prev_x) * mu[:, None, :]     # (5, B, d): r,k,v,g,w
    xr, xk, xv, xg, xw = xs
    r = (xr @ lp["wr"]).reshape(-1, heads, hs).astype(jnp.float32)
    k = (xk @ lp["wk"]).reshape(-1, heads, hs).astype(jnp.float32)
    v = (xv @ lp["wv"]).reshape(-1, heads, hs).astype(jnp.float32)
    g = jax.nn.silu(xg @ lp["wg"])
    wd = lp["w_decay"]
    w = (wd["w0"] + (jnp.tanh(xw @ wd["a"]) @ wd["b"]).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w)).reshape(-1, heads, hs)   # decay in (0,1)
    u = lp["u_bonus"]                                 # (H, hs)
    kv = k[..., :, None] * v[..., None, :]            # (B,H,hs,hs)
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    state = w[..., :, None] * state + kv
    y = _group_norm(lp["gn"], y.reshape(-1, cfg.d_model), heads)
    out = (y.astype(g.dtype) * g) @ lp["wo"]
    return out, state


def _channel_mix_step(cfg, lp, x_t, prev_x):
    mu = lp["mu"]
    xk = prev_x + (x_t - prev_x) * mu[0][None, :]
    xr = prev_x + (x_t - prev_x) * mu[1][None, :]
    k = jnp.square(jax.nn.relu(xk @ lp["wk"])) @ lp["wv"]
    return jax.nn.sigmoid(xr @ lp["wr"]) * k


def _layer_scan_seq(cfg, lp, x, tm_x0, cm_x0, wkv0):
    """Run one layer over a full sequence (scan over time).  x: (B,S,d)."""

    def step(carry, x_t):
        tm_prev, cm_prev, st = carry
        h = L.apply_norm("layernorm", lp["ln1"], x_t)
        tm_h_prev = tm_prev
        out, st = _time_mix_step(cfg, lp, h, tm_h_prev, st)
        x1 = x_t + out
        h2 = L.apply_norm("layernorm", lp["cm"]["ln"], x1)
        out2 = _channel_mix_step(cfg, lp["cm"], h2, cm_prev)
        return (h, h2, st), x1 + out2

    (tm_x, cm_x, wkv), y = lax.scan(step, (tm_x0, cm_x0, wkv0),
                                    jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(y, 0, 1), (tm_x, cm_x, wkv)


def forward_seq(params, cfg: ArchConfig, tokens, state: Optional[RWKVState]
                = None, tp: int = 1, remat: bool = True):
    x = L.embed(params["embed"], tokens)
    b, s, d = x.shape
    if state is None:
        state = RWKVState.zeros(cfg, b)

    def block(x, inp):
        lp, tm0, cm0, st0 = inp
        y, (tm, cm, st) = _layer_scan_seq(cfg, lp, x, tm0, cm0, st0)
        return y, (tm, cm, st)

    if remat:
        block = jax.checkpoint(block)
    x, (tm, cm, wkv) = lax.scan(block, x,
                                (params["blocks"], state.tm_x, state.cm_x,
                                 state.wkv), unroll=cfg.scan_unroll)
    x = L.apply_norm("layernorm", params["ln_f"], x)
    new_state = RWKVState(tm, cm, wkv, state.lengths + s)
    return x, new_state


def loss(params, cfg: ArchConfig, batch, tp: int = 1):
    h, _ = forward_seq(params, cfg, batch["tokens"], tp=tp)
    return L.lm_loss_chunked(params["embed"], h, batch["labels"],
                             batch.get("mask"))


def prefill(params, cfg: ArchConfig, tokens, tp: int = 1, max_seq=None):
    h, state = forward_seq(params, cfg, tokens, tp=tp, remat=False)
    return L.unembed(params["embed"], h[:, -1]), state


def decode_step(params, cfg: ArchConfig, tokens, state: RWKVState,
                tp: int = 1):
    x = L.embed(params["embed"], tokens)                 # (B, d)

    def block(x, inp):
        lp, tm0, cm0, st0 = inp
        h = L.apply_norm("layernorm", lp["ln1"], x)
        out, st = _time_mix_step(cfg, lp, h, tm0, st0)
        x1 = x + out
        h2 = L.apply_norm("layernorm", lp["cm"]["ln"], x1)
        out2 = _channel_mix_step(cfg, lp["cm"], h2, cm0)
        return x1 + out2, (h, h2, st)

    x, (tm, cm, wkv) = lax.scan(block, x,
                                (params["blocks"], state.tm_x, state.cm_x,
                                 state.wkv), unroll=cfg.scan_unroll)
    x = L.apply_norm("layernorm", params["ln_f"], x)
    logits = L.unembed(params["embed"], x)
    return logits, RWKVState(tm, cm, wkv, state.lengths + 1)
