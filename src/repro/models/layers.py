"""Shared model components (pure-functional, pytree params).

Everything is written to be (a) exactly correct on one CPU device for the
smoke tests, (b) GSPMD-shardable at the production mesh for the dry-run, and
(c) memory-sane at 32k-500k contexts (blocked attention, chunked CE loss,
capacity-grouped MoE — no T x E x C one-hot dispatch tensors).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Params = Dict[str, Any]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def init_norm(kind: str, d: int, dtype=jnp.float32) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(kind: str, p: Params, x: jax.Array, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if kind == "layernorm":
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32)
                            / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: Optional[Tuple[int, int, int]] = None):
    """x: (..., S, H, D); positions: (B, S) or (B, S, 3) for M-RoPE."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # (D/2,)
    if positions.ndim == 3:                          # M-RoPE (Qwen2-VL)
        assert mrope_sections is not None
        sec = jnp.asarray(
            sum(([i] * s for i, s in enumerate(mrope_sections)), []))
        sec = sec[: d // 2]                           # (D/2,) section id
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(sec[None, None, :],
                             positions.shape[:2] + (d // 2,)).astype(jnp.int32),
            axis=-1)                                  # (B, S, D/2)
        ang = pos * inv[None, None, :]
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, D/2)
    cos = jnp.cos(ang)[..., None, :]                  # (B, S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / attention initialisation
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 0.02):
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def init_attention(key, cfg, dtype, hq: int, hkv: int) -> Params:
    ks = jax.random.split(key, 5)
    d, dh = cfg.d_model, cfg.d_head
    p = {
        "wq": dense_init(ks[0], d, hq * dh, dtype),
        "wk": dense_init(ks[1], d, hkv * dh, dtype),
        "wv": dense_init(ks[2], d, hkv * dh, dtype),
        "wo": dense_init(ks[3], hq * dh, d, dtype,
                         scale=0.02 / math.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def qkv_project(p: Params, x: jax.Array, hq: int, hkv: int, dh: int):
    b, s, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(b, s, hq, dh), k.reshape(b, s, hkv, dh),
            v.reshape(b, s, hkv, dh))


# ---------------------------------------------------------------------------
# Blocked causal attention (training / prefill)
# ---------------------------------------------------------------------------
def blocked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      q_block: int = 512, kv_block: int = 512,
                      q_offset: int = 0) -> jax.Array:
    """Flash-style online-softmax attention, O(S * block) memory.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq = G * Hkv.
    ``window`` > 0 restricts attention to the last ``window`` positions
    (RecurrentGemma local attention).  ``q_offset`` is the absolute position
    of q[0] relative to k[0] (prefill continuation).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    qb = min(q_block, sq)
    kb = min(kv_block, skv)
    # Ragged tails are padded to a block multiple and masked out (the k-side
    # via the position mask below; the q-side by slicing the output).
    sq_pad = -(-sq // qb) * qb
    skv_pad = -(-skv // kb) * kb
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    if skv_pad != skv:
        kpad = ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0))
        k, v = jnp.pad(k, kpad), jnp.pad(v, kpad)
    nq, nk = sq_pad // qb, skv_pad // kb
    scale = 1.0 / math.sqrt(d)

    qr = q.reshape(b, nq, qb, hkv, g, d)
    kr = k.reshape(b, nk, kb, hkv, d)
    vr = v.reshape(b, nk, kb, hkv, d)
    q_pos_base = jnp.arange(qb)
    k_pos_base = jnp.arange(kb)

    def q_block_fn(qi):
        qblk = qr[:, qi].astype(jnp.float32) * scale      # (B,qb,Hkv,G,D)
        q_pos = q_offset + qi * qb + q_pos_base           # (qb,)

        @jax.checkpoint    # flash-style backward: recompute the (qb, kb)
        def kv_step(carry, ki):   # block scores instead of saving them
            m, l, acc = carry
            kblk = kr[:, ki].astype(jnp.float32)          # (B,kb,Hkv,D)
            vblk = vr[:, ki].astype(jnp.float32)
            s_ = jnp.einsum("bqhgd,bkhd->bqhgk", qblk, kblk)
            k_pos = ki * kb + k_pos_base                  # (kb,)
            mask = jnp.broadcast_to(k_pos[None, :] < skv, (qb, kb))
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            s_ = jnp.where(mask[None, :, None, None, :], s_, -jnp.inf)
            m_new = jnp.maximum(m, s_.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p_ = jnp.exp(s_ - m_safe[..., None])
            p_ = jnp.where(mask[None, :, None, None, :], p_, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p_.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p_, vblk)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, qb, hkv, g), -jnp.inf),
                jnp.zeros((b, qb, hkv, g)),
                jnp.zeros((b, qb, hkv, g, d)))
        (m, l, acc), _ = lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return out                                        # (B,qb,Hkv,G,D)

    # checkpoint per q block: backward holds ONE q block's kv-scan carries
    # at a time instead of the (nq x nk) stack (see EXPERIMENTS.md §Perf)
    q_block_fn = jax.checkpoint(q_block_fn)
    outs = lax.map(q_block_fn, jnp.arange(nq))            # (nq,B,qb,Hkv,G,D)
    outs = jnp.moveaxis(outs, 0, 1)                       # (B,nq,qb,...)
    return outs.reshape(b, sq_pad, hq, d)[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention against a KV cache (single-device logical form; the
# sequence-sharded distributed version wraps `decode_attention_core`)
# ---------------------------------------------------------------------------
def decode_attention_core(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, valid: jax.Array):
    """Partial-softmax attention over one cache shard.

    q: (B, Hq, D); k/v_cache: (B, S, Hkv, D); valid: (B, S) bool.
    Returns (acc, lse, m): un-normalized output + log-sum-exp stats so that
    shards can be combined exactly (paper's IS-S split of the AV operator's
    K = context dimension).
    """
    b, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qr = (q.reshape(b, hkv, g, d).astype(jnp.float32)) * scale
    # keep the cache in bf16 — casting it to f32 doubles the resident KV
    # bytes transiently (§Perf iteration 16); accumulate in f32 instead
    s_ = jnp.einsum("bhgd,bshd->bhgs", qr.astype(k_cache.dtype), k_cache,
                    preferred_element_type=jnp.float32)
    s_ = s_ * jnp.float32(1.0)
    s_ = jnp.where(valid[:, None, None, :], s_, -jnp.inf)
    m = s_.max(axis=-1)                                   # (B,Hkv,G)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(valid[:, None, None, :], jnp.exp(s_ - m_safe[..., None]), 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return acc, l, m_safe


def decode_attention(q, k_cache, v_cache, lengths):
    """q: (B,Hq,D); caches (B,S,Hkv,D); lengths: (B,) valid prefix lengths."""
    b, s = k_cache.shape[0], k_cache.shape[1]
    valid = jnp.arange(s)[None, :] < lengths[:, None]
    acc, l, _ = decode_attention_core(q, k_cache, v_cache, valid)
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    bq, hkv, g, d = out.shape
    return out.reshape(bq, hkv * g, d).astype(q.dtype)


def paged_decode_attention(q, k_pool, v_pool, tables, lengths):
    """Reference decode attention through a block table.

    q: (B, Hq, D); pools: (P+1, page, Hkv, D) with page P a scratch page;
    tables: (B, nblk) page ids (unmapped entries point at the scratch
    page); lengths: (B,).  Gathers the slots' pages into a contiguous view
    and runs the standard masked decode attention — the Pallas paged
    flash-decode kernel replaces this without materializing the gather.
    """
    b = q.shape[0]
    nblk = tables.shape[1]
    ps = k_pool.shape[1]
    k = k_pool[tables].reshape(b, nblk * ps, *k_pool.shape[2:])
    v = v_pool[tables].reshape(b, nblk * ps, *v_pool.shape[2:])
    return decode_attention(q, k, v, lengths)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------
def init_ffn(key, d_model: int, d_ff: int, gated: bool, dtype,
             num_layers: int = 24) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype,
                              scale=0.02 / math.sqrt(2 * num_layers))}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def _act(name: str, x):
    return jax.nn.silu(x) if name == "silu" else jax.nn.gelu(x)


def apply_ffn(p: Params, x: jax.Array, act: str) -> jax.Array:
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = _act(act, x @ p["w_gate"]) * h
    else:
        h = _act(act, h)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE: capacity-grouped dispatch (sort-based, no T x E x C one-hot)
# ---------------------------------------------------------------------------
def init_moe(key, cfg, dtype) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff_expert
    scale_down = 0.02 / math.sqrt(2 * cfg.num_layers)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_up": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                 * 0.02).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                   * 0.02).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   * scale_down).astype(dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_ffn(ks[4], d,
                               cfg.d_ff_expert * cfg.num_shared_experts,
                               cfg.gated_ffn, dtype, cfg.num_layers)
    return p


def moe_capacity(tokens: int, num_experts: int, topk: int,
                 factor: float) -> int:
    c = max(1, int(math.ceil(tokens * topk / num_experts * factor)))
    return -(-c // 16) * 16   # multiple of 16 so C can shard over data axes


def seq_constraint(x: jax.Array) -> jax.Array:
    """Megatron-style sequence parallelism: constrain a (B, S, d) residual
    stream to shard S over "model" (on top of B over the data axes).  Applied
    to the layer-scan carry, it divides the per-layer remat save — the
    dominant train-time memory term — by the TP degree; GSPMD inserts the
    all-gather before attention/FFN and the reduce-scatter after."""
    from repro.distributed import context
    from repro.launch.mesh import data_axes
    mesh = context.current_mesh()
    if mesh is None or "model" not in mesh.axis_names or x.ndim != 3:
        return x
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    b, s, _ = x.shape
    if s % mesh.shape["model"] or (dsize > 1 and b % dsize):
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    return lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(dp, "model", None)))


def _data_chunks(t: int) -> int:
    """Number of data shards to chunk the MoE dispatch over (1 off-mesh)."""
    from repro.distributed import context
    from repro.launch.mesh import data_axes
    mesh = context.current_mesh()
    if mesh is None:
        return 1
    dsize = 1
    for a in data_axes(mesh):
        dsize *= mesh.shape[a]
    return dsize if dsize > 1 and t % dsize == 0 else 1


def _moe_constraint(ge: jax.Array) -> jax.Array:
    """Pin the (X, E, C, d) dispatch tensor to expert-parallel sharding when
    a mesh context is active: chunk axis X over the data axes, E over
    "model".  Chunk-local dispatch means GSPMD never has to move tokens —
    activations are model-replicated going in, so every device builds its
    own chunk x expert slice with zero collectives."""
    from repro.distributed import context
    mesh = context.current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return ge
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import data_axes
    x, e, _, _ = ge.shape
    espec = "model" if e % mesh.shape["model"] == 0 else None
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    xspec = (daxes if len(daxes) > 1 else daxes[0]) \
        if (daxes and x % dsize == 0) else None
    return lax.with_sharding_constraint(
        ge, NamedSharding(mesh, P(xspec, espec, None, None)))


def _local_ranks(flat_e: jax.Array, n: int, e: int) -> jax.Array:
    """Rank of each (token, k) pair within its expert group (stable)."""
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, jnp.arange(e))
    rank_sorted = jnp.arange(n) - first[sorted_e]
    return jnp.zeros((n,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))


def _apply_moe_shardmap(p: Params, x: jax.Array, cfg, mesh) -> jax.Array:
    """Expert-parallel MoE under shard_map: fully local dispatch/combine +
    ONE psum over the expert-sharded "model" axis.

    Each (data, model) device sees its local tokens (replicated over
    "model") and its E/TP expert slice.  Dispatch ranks are computed
    locally; tokens routed to non-local experts or past capacity land in a
    local trash row (exact semantics, no GSPMD scatter across shards).
    The partial expert outputs are summed with lax.psum — the Megatron-EP
    combine, and the paper's Fig. 9 RS/AG stage.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import data_axes
    daxes = data_axes(mesh)
    dp = daxes if len(daxes) > 1 else daxes[0]
    tp = mesh.shape["model"]
    e, k = cfg.num_experts, cfg.topk
    el = e // tp

    def local_moe(xl, router, w_up, w_gate, w_down):
        tl, d = xl.shape
        c = moe_capacity(tl, e, k, cfg.capacity_factor)
        logits = xl.astype(jnp.float32) @ router          # (tl, E)
        probs = jax.nn.softmax(logits, axis=-1)
        weights, experts = lax.top_k(probs, k)
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True),
                                        1e-9)
        rank = _local_ranks(experts.reshape(-1), tl * k, e).reshape(tl, k)
        eloc = experts - lax.axis_index("model") * el     # local expert id
        ok = (eloc >= 0) & (eloc < el) & (rank < c)
        se = jnp.where(ok, eloc, el)    # el is out of bounds -> dropped
        # Per-k scatters keep the update operand at (tl, d) — never the
        # (tl*K, d) expansion — and the (expert, rank) pairs are unique by
        # construction, so XLA skips its sort-based deterministic-scatter
        # lowering (the 6 GiB u32 sort payloads of §Perf iteration 6).
        ge = jnp.zeros((el, c, d), xl.dtype)
        for j in range(k):
            ge = ge.at[se[:, j], rank[:, j]].set(
                xl, mode="drop", unique_indices=True)
        up = jnp.einsum("ecd,edf->ecf", ge, w_up)
        gate = jnp.einsum("ecd,edf->ecf", ge, w_gate)
        h = _act(cfg.act, gate) * up
        out_e = jnp.einsum("ecf,efd->ecd", h, w_down)     # (el, C, d)
        y = jnp.zeros((tl, d), jnp.float32)
        for j in range(k):
            yj = out_e.at[se[:, j], rank[:, j]].get(
                mode="fill", fill_value=0)                # (tl, d)
            wj = jnp.where(ok[:, j], weights[:, j], 0.0)
            y = y + yj.astype(jnp.float32) * wj[:, None]
        return lax.psum(y.astype(xl.dtype), "model")

    wspec = P(None, "model", None, None) if p["w_up"].ndim == 4 \
        else P("model", None, None)
    return shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(dp, None), P(None, None), wspec, wspec,
                  P(None, "model", None, None) if p["w_down"].ndim == 4
                  else P("model", None, None)),
        out_specs=P(dp, None),
        check_rep=False)(x, p["router"], p["w_up"], p["w_gate"],
                         p["w_down"])


def apply_moe(p: Params, x: jax.Array, cfg) -> jax.Array:
    """x: (T, d_model) flattened tokens -> (T, d_model).

    Chunk-local, sort-based capacity dispatch.  Tokens are split into one
    chunk per data shard; each chunk ranks its own (token, k) pairs and
    scatters into its own (E, C_local, d) slice with OOB-drop overflow.
    Ranks never cross chunks, so there is NO global argsort — under GSPMD
    the whole dispatch stays device-local (activations arrive replicated
    over "model"), and the only MoE collective left per layer is the
    (T_local, d) partial-sum combine over the expert-sharded model axis.
    Capacity is enforced per chunk (C_local = ceil(T_local*k/E * factor)),
    the standard per-device capacity semantics of TPU MoE stacks.

    When a mesh context is active and shapes divide, the shard_map
    implementation above is used instead (explicitly local + one psum).
    """
    from repro.distributed import context
    mesh = context.current_mesh()
    if (mesh is not None and "model" in mesh.axis_names
            and cfg.num_experts % mesh.shape["model"] == 0
            and _data_chunks(x.shape[0]) > 1 and "shared" not in p):
        return _apply_moe_shardmap(p, x, cfg, mesh).astype(x.dtype)
    t, d = x.shape
    e, k = cfg.num_experts, cfg.topk
    nx = _data_chunks(t)
    tl = t // nx
    c = moe_capacity(tl, e, k, cfg.capacity_factor)

    logits = (x.astype(jnp.float32) @ p["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = lax.top_k(probs, k)                # (T, K)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    xr = x.reshape(nx, tl, d)
    er = experts.reshape(nx, tl, k)
    wr = weights.reshape(nx, tl, k)

    def _ranks(ec):                                       # (tl, K) -> (tl*K,)
        flat_e = ec.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, jnp.arange(e))
        rank_sorted = jnp.arange(tl * k) - first[sorted_e]
        return jnp.zeros((tl * k,), jnp.int32).at[order].set(
            rank_sorted.astype(jnp.int32))

    rank = jax.vmap(_ranks)(er)                           # (X, tl*K)
    token_idx = jnp.repeat(jnp.arange(tl), k)             # (tl*K,)

    def _dispatch(xc, ec, rk):
        return jnp.zeros((e, c, d), x.dtype).at[
            ec.reshape(-1), rk].set(xc[token_idx], mode="drop")

    ge = _moe_constraint(jax.vmap(_dispatch)(xr, er, rank))  # (X, E, C, d)

    up = jnp.einsum("xecd,edf->xecf", ge, p["w_up"])
    gate = jnp.einsum("xecd,edf->xecf", ge, p["w_gate"])
    h = _act(cfg.act, gate) * up
    out_e = _moe_constraint(
        jnp.einsum("xecf,efd->xecd", h, p["w_down"]))     # (X, E, C, d)

    def _combine(oc, ec, rk, wc):
        y = oc.at[ec.reshape(-1), rk].get(mode="fill", fill_value=0)
        y = y * wc.reshape(-1)[:, None].astype(oc.dtype)
        return y.reshape(tl, k, d).sum(axis=1)            # (tl, d)

    y = jax.vmap(_combine)(out_e, er, rank, wr).reshape(t, d)
    if "shared" in p:
        y = y + apply_ffn(p["shared"], x, cfg.act)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding + chunked cross-entropy
# ---------------------------------------------------------------------------
def init_embed(key, vocab: int, d_model: int, dtype, tie: bool) -> Params:
    ks = jax.random.split(key, 2)
    p = {"table": dense_init(ks[0], vocab, d_model, dtype, scale=0.02)}
    if not tie:
        p["head"] = dense_init(ks[1], d_model, vocab, dtype, scale=0.02)
    return p


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    out = jnp.take(p["table"], tokens, axis=0)
    return _embed_constraint(out)


_EMBED_CONSTRAINT = [True]   # disabled under microbatch scans (XLA SPMD
#                              partitioner rejects the gather+constraint
#                              combination inside a while body)


def _embed_constraint(x: jax.Array) -> jax.Array:
    """Keep the embedding output d-sharded over "model" (matching the
    d-sharded table) so the backward scatter-add produces a (V, d/TP)
    shard instead of a full replicated f32 (V, d) gradient buffer
    (§Perf iteration 10)."""
    from repro.distributed import context
    from repro.launch.mesh import data_axes
    mesh = context.current_mesh()
    if mesh is None or "model" not in mesh.axis_names \
            or not _EMBED_CONSTRAINT[0]:
        return x
    d = x.shape[-1]
    if d % mesh.shape["model"]:
        return x
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    dp = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    lead = [None] * (x.ndim - 1)
    if x.shape[0] % max(dsize, 1) == 0 and dp is not None:
        lead[0] = dp
    from jax.sharding import NamedSharding, PartitionSpec as P
    return lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*lead, "model")))


def unembed(p: Params, h: jax.Array) -> jax.Array:
    w = p.get("head")
    if w is None:
        w = p["table"].T
    return h @ w


def lm_loss_chunked(p_embed: Params, h: jax.Array, labels: jax.Array,
                    mask: Optional[jax.Array] = None,
                    chunk: int = 512) -> jax.Array:
    """Cross-entropy over the (potentially huge, vocab-sharded) head without
    materializing (B, S, V) logits: scan over sequence chunks."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    n = s // chunk
    w = p_embed.get("head")
    if w is None:
        w = p_embed["table"].T
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    hr = jnp.asarray(h).reshape(b, n, chunk, d)
    lr = jnp.asarray(labels).reshape(b, n, chunk)
    mr = jnp.asarray(mask).reshape(b, n, chunk)

    @jax.checkpoint   # recompute the (B, chunk, V) logits in backward —
    def step(carry, i):  # saving them costs chunks x B x chunk x V x 4B
        tot, cnt = carry
        logits = (hr[:, i].astype(jnp.float32) @ w.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lr[:, i][..., None],
                                   axis=-1)[..., 0]
        ce = (lse - gold) * mr[:, i]
        return (tot + ce.sum(), cnt + mr[:, i].sum()), None

    (tot, cnt), _ = lax.scan(step, (jnp.float32(0.0), jnp.float32(0.0)),
                             jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)
