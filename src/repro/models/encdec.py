"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings (B, frames, d_model) straight into the encoder.
Decoder: causal self-attention (cached) + cross-attention to the encoder
output (K/V computed once at prefill) + GELU FFN, pre-LayerNorm throughout.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ArchConfig

Params = Dict[str, Any]


class EncDecCache(NamedTuple):
    self_k: jax.Array     # (Ld, B, S, H, D)
    self_v: jax.Array
    cross_k: jax.Array    # (Ld, B, F, H, D)
    cross_v: jax.Array
    lengths: jax.Array    # (B,)

    @staticmethod
    def zeros(cfg: ArchConfig, batch: int, max_seq: int, tp: int = 1):
        hq, hkv = cfg.padded_heads(tp)
        dt = L._dtype(cfg.dtype)
        return EncDecCache(
            jnp.zeros((cfg.num_layers, batch, max_seq, hkv, cfg.d_head), dt),
            jnp.zeros((cfg.num_layers, batch, max_seq, hkv, cfg.d_head), dt),
            jnp.zeros((cfg.num_layers, batch, cfg.encoder_frames, hkv,
                       cfg.d_head), dt),
            jnp.zeros((cfg.num_layers, batch, cfg.encoder_frames, hkv,
                       cfg.d_head), dt),
            jnp.zeros((batch,), jnp.int32))


def _sinusoid(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_layer(key, cfg, dtype, hq, hkv) -> Params:
    ka, kf = jax.random.split(key)
    return {"ln1": L.init_norm("layernorm", cfg.d_model),
            "attn": L.init_attention(ka, cfg, dtype, hq, hkv),
            "ln2": L.init_norm("layernorm", cfg.d_model),
            "ffn": L.init_ffn(kf, cfg.d_model, cfg.d_ff, False, dtype,
                              cfg.num_layers)}


def _init_dec_layer(key, cfg, dtype, hq, hkv) -> Params:
    ka, kx, kf = jax.random.split(key, 3)
    return {"ln1": L.init_norm("layernorm", cfg.d_model),
            "self_attn": L.init_attention(ka, cfg, dtype, hq, hkv),
            "ln_x": L.init_norm("layernorm", cfg.d_model),
            "cross_attn": L.init_attention(kx, cfg, dtype, hq, hkv),
            "ln2": L.init_norm("layernorm", cfg.d_model),
            "ffn": L.init_ffn(kf, cfg.d_model, cfg.d_ff, False, dtype,
                              cfg.num_layers)}


def init(key, cfg: ArchConfig, tp: int = 1) -> Params:
    dtype = L._dtype(cfg.dtype)
    hq, hkv = cfg.padded_heads(tp)
    ke, kenc, kdec, kp = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype, hq, hkv))(
        jax.random.split(kenc, cfg.encoder_layers))
    dec = jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype, hq, hkv))(
        jax.random.split(kdec, cfg.num_layers))
    return {"embed": L.init_embed(ke, cfg.padded_vocab(tp), cfg.d_model,
                                  dtype, tie=True),
            "pos_dec": (jax.random.normal(kp, (cfg.max_seq, cfg.d_model),
                                          jnp.float32) * 0.01).astype(dtype),
            "enc": enc, "dec": dec,
            "ln_enc": L.init_norm("layernorm", cfg.d_model),
            "ln_f": L.init_norm("layernorm", cfg.d_model)}


def encode(params, cfg: ArchConfig, frames: jax.Array, tp: int = 1,
           remat: bool = True):
    """frames: (B, F, d_model) precomputed embeddings (frontend stub)."""
    hq, hkv = cfg.padded_heads(tp)
    b, f, d = frames.shape
    x = frames + _sinusoid(f, d)[None].astype(frames.dtype)

    def block(x, lp):
        h = L.apply_norm("layernorm", lp["ln1"], x)
        q, k, v = L.qkv_project(lp["attn"], h, hq, hkv, cfg.d_head)
        a = L.blocked_attention(q, k, v, causal=False,
                                q_block=min(512, f), kv_block=min(512, f))
        x = x + a.reshape(b, f, hq * cfg.d_head) @ lp["attn"]["wo"]
        h = L.apply_norm("layernorm", lp["ln2"], x)
        return x + L.apply_ffn(lp["ffn"], h, "gelu"), None

    if remat:
        block = jax.checkpoint(block)
    x, _ = lax.scan(block, x, params["enc"], unroll=cfg.scan_unroll)
    return L.apply_norm("layernorm", params["ln_enc"], x)


def _decoder_seq(params, cfg, tokens, enc_out, tp: int, remat: bool,
                 collect_cache: bool = False):
    hq, hkv = cfg.padded_heads(tp)
    b, s = tokens.shape
    f = enc_out.shape[1]
    x = L.embed(params["embed"], tokens) + \
        params["pos_dec"][None, :s].astype(L._dtype(cfg.dtype))

    def block(x, lp):
        h = L.apply_norm("layernorm", lp["ln1"], x)
        q, k, v = L.qkv_project(lp["self_attn"], h, hq, hkv, cfg.d_head)
        a = L.blocked_attention(q, k, v, causal=True,
                                q_block=min(512, s), kv_block=min(512, s))
        x = x + a.reshape(b, s, hq * cfg.d_head) @ lp["self_attn"]["wo"]
        h = L.apply_norm("layernorm", lp["ln_x"], x)
        qx = (h @ lp["cross_attn"]["wq"]).reshape(b, s, hq, cfg.d_head)
        kx = (enc_out @ lp["cross_attn"]["wk"]).reshape(b, f, hkv, cfg.d_head)
        vx = (enc_out @ lp["cross_attn"]["wv"]).reshape(b, f, hkv, cfg.d_head)
        ax = L.blocked_attention(qx, kx, vx, causal=False,
                                 q_block=min(512, s), kv_block=min(512, f))
        x = x + ax.reshape(b, s, hq * cfg.d_head) @ lp["cross_attn"]["wo"]
        h = L.apply_norm("layernorm", lp["ln2"], x)
        return x + L.apply_ffn(lp["ffn"], h, "gelu"), (k, v, kx, vx)

    if remat and not collect_cache:
        block = jax.checkpoint(block)
    if collect_cache:
        x, caches = lax.scan(block, x, params["dec"],
                             unroll=cfg.scan_unroll)
    else:
        def block_nc(x, lp):
            y, _ = block(x, lp)
            return y, None
        x, caches = lax.scan(block_nc, x, params["dec"],
                             unroll=cfg.scan_unroll)
    return L.apply_norm("layernorm", params["ln_f"], x), caches


def loss(params, cfg: ArchConfig, batch, tp: int = 1):
    enc_out = encode(params, cfg, batch["frames"], tp=tp)
    h, _ = _decoder_seq(params, cfg, batch["tokens"], enc_out, tp, True)
    return L.lm_loss_chunked(params["embed"], h, batch["labels"],
                             batch.get("mask"))


def prefill(params, cfg: ArchConfig, tokens, frames, tp: int = 1,
            max_seq: Optional[int] = None):
    enc_out = encode(params, cfg, frames, tp=tp, remat=False)
    h, (k, v, kx, vx) = _decoder_seq(params, cfg, tokens, enc_out, tp,
                                     remat=False, collect_cache=True)
    b, s = tokens.shape
    if max_seq is not None and max_seq > s:
        pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    cache = EncDecCache(k, v, kx, vx, jnp.full((b,), s, jnp.int32))
    return L.unembed(params["embed"], h[:, -1]), cache


def decode_step(params, cfg: ArchConfig, tokens, cache: EncDecCache,
                tp: int = 1):
    hq, hkv = cfg.padded_heads(tp)
    b = tokens.shape[0]
    x = L.embed(params["embed"], tokens) + jnp.take(
        params["pos_dec"], cache.lengths, axis=0).astype(L._dtype(cfg.dtype))
    f = cache.cross_k.shape[2]
    cross_valid = jnp.ones((b, f), bool)

    def block(x, inp):
        lp, kc, vc, kx, vx = inp
        h = L.apply_norm("layernorm", lp["ln1"], x[:, None])
        q, k, v = L.qkv_project(lp["self_attn"], h, hq, hkv, cfg.d_head)
        idx = cache.lengths
        kc = jax.vmap(lambda c, kn, i: lax.dynamic_update_slice_in_dim(
            c, kn, i, axis=0))(kc, k[:, 0:1], idx)
        vc = jax.vmap(lambda c, vn, i: lax.dynamic_update_slice_in_dim(
            c, vn, i, axis=0))(vc, v[:, 0:1], idx)
        a = L.decode_attention(q[:, 0], kc, vc, cache.lengths + 1)
        x = x + a.reshape(b, hq * cfg.d_head) @ lp["self_attn"]["wo"]
        h = L.apply_norm("layernorm", lp["ln_x"], x[:, None])
        qx = (h @ lp["cross_attn"]["wq"]).reshape(b, 1, hq, cfg.d_head)
        acc, l, _ = L.decode_attention_core(qx[:, 0], kx, vx, cross_valid)
        ax = (acc / jnp.maximum(l, 1e-20)[..., None]).reshape(
            b, hq * cfg.d_head)
        x = x + ax.astype(x.dtype) @ lp["cross_attn"]["wo"]
        h = L.apply_norm("layernorm", lp["ln2"], x)
        return x + L.apply_ffn(lp["ffn"], h, "gelu"), (kc, vc)

    x, (k_new, v_new) = lax.scan(
        block, x, (params["dec"], cache.self_k, cache.self_v,
                   cache.cross_k, cache.cross_v), unroll=cfg.scan_unroll)
    x = L.apply_norm("layernorm", params["ln_f"], x)
    logits = L.unembed(params["embed"], x)
    return logits, EncDecCache(k_new, v_new, cache.cross_k, cache.cross_v,
                               cache.lengths + 1)
