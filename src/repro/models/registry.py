"""Architecture registry: ``--arch <id>`` resolution + per-shape input specs.

Every entry exposes the same pure-function protocol:
    init(key, cfg, tp)                          -> params
    loss(params, cfg, batch, tp)                -> scalar
    prefill(params, cfg, **inputs)              -> (logits, cache/state)
    decode_step(params, cfg, tokens, cache, tp) -> (logits, cache/state)
    cache_zeros(cfg, batch, max_seq, tp)        -> cache/state pytree

``input_specs(cfg, shape, tp)`` returns ShapeDtypeStruct stand-ins for every
model input of that (arch x shape) cell — weak-type-correct, shardable, no
device allocation — which is what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, rglru, rwkv6, transformer
from repro.models import layers as L
from repro.models.config import SHAPES, ArchConfig, ShapeCell, shape_applicable

ARCH_IDS = [
    "dbrx-132b", "kimi-k2-1t-a32b", "rwkv6-7b", "stablelm-3b", "yi-6b",
    "granite-3-8b", "qwen1.5-110b", "recurrentgemma-9b", "qwen2-vl-7b",
    "whisper-small",
]

# The paper's own Table 1 models (non-MLA), selectable via --arch but not
# part of the assigned 40-cell sweep.  DeepSeek-236B (MLA) is modeled in
# the NMP simulator (core/operators.py) only — the JAX model zoo has no
# MLA attention implementation (DESIGN.md §5).
EXTRA_ARCH_IDS = ["opt-66b", "llama3-70b", "mixtral-8x22b", "qwen3-30b-a3b"]

_CONFIG_MODULES = {
    "dbrx-132b": "dbrx_132b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "rwkv6-7b": "rwkv6_7b",
    "stablelm-3b": "stablelm_3b",
    "yi-6b": "yi_6b",
    "granite-3-8b": "granite_3_8b",
    "qwen1.5-110b": "qwen1_5_110b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "whisper-small": "whisper_small",
    "opt-66b": "opt_66b",
    "llama3-70b": "llama3_70b",
    "mixtral-8x22b": "mixtral_8x22b",
    "qwen3-30b-a3b": "qwen3_30b_a3b",
}


@dataclass(frozen=True)
class ArchEntry:
    config: ArchConfig
    module: Any     # model module implementing the protocol

    def cache_zeros(self, batch: int, max_seq: int, tp: int = 1):
        cfg = self.config
        if cfg.family == "ssm":
            return rwkv6.RWKVState.zeros(cfg, batch)
        if cfg.family == "hybrid":
            return rglru.RGState.zeros(cfg, batch)
        if cfg.family == "audio":
            return encdec.EncDecCache.zeros(cfg, batch, max_seq, tp)
        return transformer.KVCache.zeros(cfg, batch, max_seq, tp)


def _module_for(cfg: ArchConfig):
    return {"ssm": rwkv6, "hybrid": rglru, "audio": encdec}.get(
        cfg.family, transformer)


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_CONFIG_MODULES[name]}")
    return mod.CONFIG


def get(name: str, reduced: bool = False, **over) -> ArchEntry:
    cfg = get_config(name)
    if reduced:
        cfg = cfg.reduced(**over)
    elif over:
        cfg = dataclasses.replace(cfg, **over)
    return ArchEntry(config=cfg, module=_module_for(cfg))


def from_config(cfg: ArchConfig) -> ArchEntry:
    return ArchEntry(config=cfg, module=_module_for(cfg))


# ---------------------------------------------------------------------------
# Input specs for the dry-run (ShapeDtypeStruct stand-ins)
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, shape: ShapeCell | str,
                tp: int = 1) -> Dict[str, Any]:
    """Model inputs for one (arch x shape) cell, as ShapeDtypeStructs.

    train  -> {"tokens","labels"} (+"frames" for audio, "embeds" for vlm)
    prefill-> {"tokens"} (+modality inputs)
    decode -> {"tokens": (B,)} — the cache spec comes from cache_specs().
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = L._dtype(cfg.dtype)
    if shape.kind == "train":
        spec = {"tokens": _sds((b, s), i32), "labels": _sds((b, s), i32)}
        if cfg.family == "audio":
            spec["frames"] = _sds((b, cfg.encoder_frames, cfg.d_model), dt)
        if cfg.family == "vlm":
            # frontend stub: precomputed patch embeddings replace tokens
            spec = {"embeds": _sds((b, s, cfg.d_model), dt),
                    "labels": _sds((b, s), i32)}
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": _sds((b, s), i32)}
        if cfg.family == "audio":
            spec["frames"] = _sds((b, cfg.encoder_frames, cfg.d_model), dt)
        if cfg.family == "vlm":
            spec = {"embeds": _sds((b, s, cfg.d_model), dt)}
        return spec
    # decode: one new token against a seq_len-deep cache
    return {"tokens": _sds((b,), i32)}


def cache_specs(entry: ArchEntry, shape: ShapeCell | str, tp: int = 1):
    if isinstance(shape, str):
        shape = SHAPES[shape]
    return jax.eval_shape(
        lambda: entry.cache_zeros(shape.global_batch, shape.seq_len, tp))
