"""Decoder-only transformer LM (dense / MoE / VLM-text families).

Pure-functional: ``init`` builds a stacked-parameter pytree (layer dim
leading, consumed by ``lax.scan``), ``loss`` / ``prefill`` / ``decode_step``
are jit-able pure functions.  The VLM family accepts precomputed patch
embeddings (frontend stub per the assignment) and M-RoPE positions.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.config import ArchConfig

Params = Dict[str, Any]


class KVCache(NamedTuple):
    k: jax.Array          # (L, B, S, Hkv, D)
    v: jax.Array
    lengths: jax.Array    # (B,) valid prefix per request

    @staticmethod
    def zeros(cfg: ArchConfig, batch: int, max_seq: int, tp: int = 1,
              dtype=None):
        _, hkv = cfg.padded_heads(tp)
        dt = dtype or L._dtype(cfg.dtype)
        shape = (cfg.num_layers, batch, max_seq, hkv, cfg.d_head)
        return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt),
                       jnp.zeros((batch,), jnp.int32))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_layer(key, cfg: ArchConfig, dtype, hq, hkv) -> Params:
    ka, kf, kn = jax.random.split(key, 3)
    p = {
        "ln1": L.init_norm(cfg.norm, cfg.d_model),
        "attn": L.init_attention(ka, cfg, dtype, hq, hkv),
        "ln2": L.init_norm(cfg.norm, cfg.d_model),
    }
    if cfg.num_experts:
        p["moe"] = L.init_moe(kf, cfg, dtype)
    else:
        p["ffn"] = L.init_ffn(kf, cfg.d_model, cfg.d_ff, cfg.gated_ffn,
                              dtype, cfg.num_layers)
    return p


def init(key, cfg: ArchConfig, tp: int = 1) -> Params:
    dtype = L._dtype(cfg.dtype)
    hq, hkv = cfg.padded_heads(tp)
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    blocks = jax.vmap(lambda k: _init_layer(k, cfg, dtype, hq, hkv))(
        layer_keys)
    return {
        "embed": L.init_embed(ke, cfg.padded_vocab(tp), cfg.d_model, dtype,
                              cfg.tie_embeddings),
        "blocks": blocks,
        "ln_f": L.init_norm(cfg.norm, cfg.d_model),
    }


# ---------------------------------------------------------------------------
# Forward (training / prefill): scan over stacked layers
# ---------------------------------------------------------------------------
def _block_seq(cfg: ArchConfig, lp: Params, x: jax.Array,
               positions: jax.Array, hq: int, hkv: int,
               window: int = 0) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One decoder block over a full sequence. Returns (x, (k, v))."""
    h = L.apply_norm(cfg.norm, lp["ln1"], x)
    q, k, v = L.qkv_project(lp["attn"], h, hq, hkv, cfg.d_head)
    q = L.apply_rope(q, positions, cfg.rope_theta,
                     cfg.mrope_sections if cfg.mrope else None)
    k = L.apply_rope(k, positions, cfg.rope_theta,
                     cfg.mrope_sections if cfg.mrope else None)
    attn = L.blocked_attention(q, k, v, causal=True, window=window)
    b, s, _, _ = attn.shape
    x = x + attn.reshape(b, s, hq * cfg.d_head) @ lp["attn"]["wo"]
    h = L.apply_norm(cfg.norm, lp["ln2"], x)
    if cfg.num_experts:
        y = L.apply_moe(lp["moe"], h.reshape(b * s, cfg.d_model), cfg)
        y = y.reshape(b, s, cfg.d_model)
    else:
        y = L.apply_ffn(lp["ffn"], h, cfg.act)
    return x + y, (k, v)


def forward_seq(params: Params, cfg: ArchConfig, tokens: Optional[jax.Array],
                positions: Optional[jax.Array] = None,
                embeds: Optional[jax.Array] = None, tp: int = 1,
                collect_cache: bool = False, remat: bool = True):
    """Full-sequence forward. Returns (hidden, (k_stack, v_stack) | None)."""
    hq, hkv = cfg.padded_heads(tp)
    x = embeds if embeds is not None else L.embed(params["embed"], tokens)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[..., None], (b, s, 3))

    def block(x, lp):
        # sequence-parallel carry: the remat save per layer shards S over
        # "model" (no-op off-mesh / non-divisible)
        x = L.seq_constraint(x)
        y, kv = _block_seq(cfg, lp, x, positions, hq, hkv,
                           window=cfg.window)
        return L.seq_constraint(y), kv

    if remat:
        block = jax.checkpoint(block)

    if collect_cache:
        x, kv = lax.scan(block, x, params["blocks"],
                         unroll=cfg.scan_unroll)
    else:
        def block_nocache(x, lp):
            y, _ = block(x, lp)
            return y, None
        x, kv = lax.scan(block_nocache, x, params["blocks"],
                         unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg.norm, params["ln_f"], x)
    return x, kv


def loss(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
         tp: int = 1) -> jax.Array:
    h, _ = forward_seq(params, cfg, batch.get("tokens"),
                       positions=batch.get("positions"),
                       embeds=batch.get("embeds"), tp=tp)
    return L.lm_loss_chunked(params["embed"], h, batch["labels"],
                             batch.get("mask"))


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode
# ---------------------------------------------------------------------------
def prefill(params: Params, cfg: ArchConfig, tokens: jax.Array,
            tp: int = 1, embeds: Optional[jax.Array] = None,
            max_seq: Optional[int] = None, chunk: Optional[int] = None):
    """Process the prompt; returns (last_logits, KVCache).

    ``chunk`` enables Sarathi-style chunked prefill (the paper's ref [1]):
    the prompt is processed ``chunk`` tokens at a time against the growing
    KV cache, bounding peak activation memory to one chunk's working set.
    """
    b, s = (tokens.shape if tokens is not None else embeds.shape[:2])
    if chunk is not None and s > chunk and s % chunk == 0 \
            and embeds is None:
        return _prefill_chunked(params, cfg, tokens, tp, max_seq, chunk)
    h, kv = forward_seq(params, cfg, tokens, embeds=embeds, tp=tp,
                        collect_cache=True, remat=False)
    k, v = kv
    if max_seq is not None and max_seq > s:
        pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    cache = KVCache(k, v, jnp.full((b,), s, jnp.int32))
    logits = L.unembed(params["embed"], h[:, -1])
    return logits, cache


def _prefill_chunked(params: Params, cfg: ArchConfig, tokens: jax.Array,
                     tp: int, max_seq: Optional[int], chunk: int):
    """Chunked prefill: outer fori over chunks, inner fori over layers,
    in-place cache writes (same structure as decode_step, multi-token)."""
    hq, hkv = cfg.padded_heads(tp)
    b, s = tokens.shape
    total = max(max_seq or s, s)
    cache0 = KVCache.zeros(cfg, b, total, tp)
    n_chunks = s // chunk

    def chunk_body(ci, carry):
        kc_all, vc_all, h_last = carry
        toks = lax.dynamic_slice_in_dim(tokens, ci * chunk, chunk, axis=1)
        x = L.embed(params["embed"], toks)                # (B, C, d)
        pos = ci * chunk + jnp.arange(chunk)
        positions = jnp.broadcast_to(pos[None, :], (b, chunk))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[..., None],
                                         (b, chunk, 3))

        def layer_body(li, inner):
            x, kc_all, vc_all = inner
            lp = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, li, 0,
                                                   keepdims=False),
                params["blocks"])
            h = L.apply_norm(cfg.norm, lp["ln1"], x)
            q, k, v = L.qkv_project(lp["attn"], h, hq, hkv, cfg.d_head)
            q = L.apply_rope(q, positions, cfg.rope_theta,
                             cfg.mrope_sections if cfg.mrope else None)
            k = L.apply_rope(k, positions, cfg.rope_theta,
                             cfg.mrope_sections if cfg.mrope else None)
            kc = lax.dynamic_index_in_dim(kc_all, li, 0, keepdims=False)
            vc = lax.dynamic_index_in_dim(vc_all, li, 0, keepdims=False)
            kc = lax.dynamic_update_slice(kc, k, (0, ci * chunk, 0, 0))
            vc = lax.dynamic_update_slice(vc, v, (0, ci * chunk, 0, 0))
            # chunk queries attend over the whole cache buffer; the causal
            # mask (q_offset) blanks everything past the current position,
            # including the still-zero future slots
            attn = L.blocked_attention(q, kc, vc, causal=True,
                                       window=cfg.window,
                                       q_offset=ci * chunk)
            x = x + attn.reshape(b, chunk, hq * cfg.d_head) \
                @ lp["attn"]["wo"]
            h2 = L.apply_norm(cfg.norm, lp["ln2"], x)
            if cfg.num_experts:
                y = L.apply_moe(lp["moe"], h2.reshape(b * chunk,
                                                      cfg.d_model), cfg)
                y = y.reshape(b, chunk, cfg.d_model)
            else:
                y = L.apply_ffn(lp["ffn"], h2, cfg.act)
            kc_all = lax.dynamic_update_index_in_dim(kc_all, kc, li, 0)
            vc_all = lax.dynamic_update_index_in_dim(vc_all, vc, li, 0)
            return (x + y, kc_all, vc_all)

        x, kc_all, vc_all = lax.fori_loop(
            0, cfg.num_layers, layer_body, (x, kc_all, vc_all),
            unroll=cfg.scan_unroll)
        return (kc_all, vc_all, x[:, -1])

    h_last0 = jnp.zeros((b, cfg.d_model), L._dtype(cfg.dtype))
    k_new, v_new, h_last = lax.fori_loop(
        0, n_chunks, chunk_body, (cache0.k, cache0.v, h_last0))
    h_last = L.apply_norm(cfg.norm, params["ln_f"], h_last)
    logits = L.unembed(params["embed"], h_last)
    return logits, KVCache(k_new, v_new, jnp.full((b,), s, jnp.int32))


def extend_step(params: Params, cfg: ArchConfig, tokens: jax.Array,
                cache: KVCache, tp: int = 1) -> Tuple[jax.Array, KVCache]:
    """Process a multi-token chunk against an existing cache.

    This is one Sarathi prefill chunk as a standalone jit-able step: the
    engine's chunk scheduler calls it between decode iterations so a long
    prompt never stalls the hot decode batch for more than one chunk.
    ``cache.lengths`` must be uniform across the batch (the engine prefills
    one request at a time); the chunk is written at that offset and
    ``lengths`` advances by the chunk length.  Returns the logits of the
    chunk's last token (so the final chunk yields the first sampled token).
    """
    hq, hkv = cfg.padded_heads(tp)
    b, c = tokens.shape
    offset = cache.lengths[0]
    x = L.embed(params["embed"], tokens)                  # (B, C, d)
    pos = offset + jnp.arange(c)
    positions = jnp.broadcast_to(pos[None, :], (b, c))
    if cfg.mrope:
        positions = jnp.broadcast_to(positions[..., None], (b, c, 3))

    def body(li, carry):
        x, kc_all, vc_all = carry
        lp = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, li, 0, keepdims=False),
            params["blocks"])
        h = L.apply_norm(cfg.norm, lp["ln1"], x)
        q, k, v = L.qkv_project(lp["attn"], h, hq, hkv, cfg.d_head)
        q = L.apply_rope(q, positions, cfg.rope_theta,
                         cfg.mrope_sections if cfg.mrope else None)
        k = L.apply_rope(k, positions, cfg.rope_theta,
                         cfg.mrope_sections if cfg.mrope else None)
        kc = lax.dynamic_index_in_dim(kc_all, li, 0, keepdims=False)
        vc = lax.dynamic_index_in_dim(vc_all, li, 0, keepdims=False)
        kc = lax.dynamic_update_slice(kc, k, (0, offset, 0, 0))
        vc = lax.dynamic_update_slice(vc, v, (0, offset, 0, 0))
        # the causal mask (q_offset) blanks everything past the current
        # position, including stale/zero future cache slots
        attn = L.blocked_attention(q, kc, vc, causal=True,
                                   window=cfg.window, q_offset=offset)
        x = x + attn.reshape(b, c, hq * cfg.d_head) @ lp["attn"]["wo"]
        h2 = L.apply_norm(cfg.norm, lp["ln2"], x)
        if cfg.num_experts:
            y = L.apply_moe(lp["moe"], h2.reshape(b * c, cfg.d_model), cfg)
            y = y.reshape(b, c, cfg.d_model)
        else:
            y = L.apply_ffn(lp["ffn"], h2, cfg.act)
        kc_all = lax.dynamic_update_index_in_dim(kc_all, kc, li, 0)
        vc_all = lax.dynamic_update_index_in_dim(vc_all, vc, li, 0)
        return (x + y, kc_all, vc_all)

    x, k_new, v_new = lax.fori_loop(0, cfg.num_layers, body,
                                    (x, cache.k, cache.v),
                                    unroll=cfg.scan_unroll)
    h_last = L.apply_norm(cfg.norm, params["ln_f"], x[:, -1])
    logits = L.unembed(params["embed"], h_last)
    return logits, KVCache(k_new, v_new, cache.lengths + c)


def decode_step_paged(params: Params, cfg: ArchConfig, tokens: jax.Array,
                      k_pool: jax.Array, v_pool: jax.Array,
                      tables: jax.Array, lengths: jax.Array, tp: int = 1,
                      attn_fn=None):
    """One decode iteration reading/writing KV through a block table.

    k_pool/v_pool: (L, P+1, page, Hkv, D) page pools (page P is scratch);
    tables: (B, nblk) page ids with unmapped entries pointing at the
    scratch page; lengths: (B,).  The new token's K/V is scattered into
    the page holding position ``lengths[b]`` — no contiguous cache is ever
    materialized, which is the whole point of the paged layout.

    ``attn_fn(q, k_pool_l, v_pool_l, tables, lengths) -> (B, Hq, D)``
    defaults to the reference gather; pass the Pallas paged flash-decode
    wrapper to read pages directly from the pool.
    """
    hq, hkv = cfg.padded_heads(tp)
    attn_fn = attn_fn or L.paged_decode_attention
    ps = k_pool.shape[2]
    nblk = tables.shape[1]
    x = L.embed(params["embed"], tokens)                 # (B, H)
    b = x.shape[0]
    positions = lengths[:, None]                         # (B, 1)
    if cfg.mrope:
        positions = jnp.broadcast_to(positions[..., None], (b, 1, 3))
    blk = lengths // ps
    # a position past the mapped window must write to the scratch page P,
    # not alias (via clipping) onto the window's last live page
    in_window = blk < nblk
    blk = jnp.clip(blk, 0, nblk - 1)
    page = jnp.take_along_axis(tables, blk[:, None], axis=1)[:, 0]
    page = jnp.where(in_window, page, k_pool.shape[1] - 1)
    off = lengths % ps

    def body(li, carry):
        x, kp, vp = carry
        lp = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, li, 0, keepdims=False),
            params["blocks"])
        kc = lax.dynamic_index_in_dim(kp, li, 0, keepdims=False)
        vc = lax.dynamic_index_in_dim(vp, li, 0, keepdims=False)
        h = L.apply_norm(cfg.norm, lp["ln1"], x[:, None, :])
        q, k, v = L.qkv_project(lp["attn"], h, hq, hkv, cfg.d_head)
        q = L.apply_rope(q, positions, cfg.rope_theta,
                         cfg.mrope_sections if cfg.mrope else None)
        k = L.apply_rope(k, positions, cfg.rope_theta,
                         cfg.mrope_sections if cfg.mrope else None)
        kc = kc.at[page, off].set(k[:, 0])               # (B,) pages/offs
        vc = vc.at[page, off].set(v[:, 0])
        attn = attn_fn(q[:, 0], kc, vc, tables, lengths + 1)
        x = x + attn.reshape(b, hq * cfg.d_head) @ lp["attn"]["wo"]
        h2 = L.apply_norm(cfg.norm, lp["ln2"], x)
        if cfg.num_experts:
            y = L.apply_moe(lp["moe"], h2, cfg)
        else:
            y = L.apply_ffn(lp["ffn"], h2, cfg.act)
        kp = lax.dynamic_update_index_in_dim(kp, kc, li, 0)
        vp = lax.dynamic_update_index_in_dim(vp, vc, li, 0)
        return (x + y, kp, vp)

    x, kp, vp = lax.fori_loop(0, cfg.num_layers, body,
                              (x, k_pool, v_pool),
                              unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg.norm, params["ln_f"], x)
    logits = L.unembed(params["embed"], x)
    return logits, (kp, vp, lengths + 1)


def decode_fused_paged(params: Params, cfg: ArchConfig, tokens: jax.Array,
                       k_pool: jax.Array, v_pool: jax.Array,
                       tables: jax.Array, lengths: jax.Array,
                       alive: jax.Array, k_active: jax.Array,
                       n_steps: int, tp: int = 1, attn_fn=None,
                       eos_id: int = -1):
    """Fuse ``n_steps`` paged decode iterations into one ``lax.scan``.

    The whole multi-step loop — greedy argmax sampling, token feedback,
    per-lane length advance, and eos freezing — stays resident on device:
    the host sees one dispatch and one fetch per fusion horizon instead
    of one per token.  ``n_steps`` is static (the engine buckets it to a
    power of two to bound recompiles); ``k_active`` is the traced actual
    horizon — steps at index >= ``k_active`` leave every lane frozen, so
    a bucketed scan emits exactly the same tokens as an exact-length one.

    A frozen lane (inactive, eos'd, or index >= ``k_active``) still runs
    the step — its K/V write lands at its frozen length, one past its
    valid context, on a page it exclusively owns (or the scratch page for
    inactive lanes whose table rows are pre-masked) — but emits nothing:
    ``emitted[j, b]`` masks the steps whose token in ``tokens_out[j, b]``
    is real.

    Returns ``(tokens_out (n_steps, B), emitted (n_steps, B), k_pool,
    v_pool, lengths)``.
    """
    vocab = cfg.vocab

    def step(carry, idx):
        toks, kp, vp, ln, al = carry
        logits, (kp, vp, _) = decode_step_paged(
            params, cfg, toks, kp, vp, tables, ln, tp=tp, attn_fn=attn_fn)
        nxt = jnp.argmax(logits[:, :vocab], axis=-1).astype(toks.dtype)
        run = al & (idx < k_active)
        toks = jnp.where(run, nxt, toks)
        ln = jnp.where(run, ln + 1, ln)
        if eos_id >= 0:
            al = al & ~(run & (nxt == eos_id))
        return (toks, kp, vp, ln, al), (toks, run)

    carry = (tokens, k_pool, v_pool, lengths, alive)
    (_, kp, vp, ln, _), (tok_seq, emit_seq) = lax.scan(
        step, carry, jnp.arange(n_steps))
    return tok_seq, emit_seq, kp, vp, ln


def decode_step(params: Params, cfg: ArchConfig, tokens: jax.Array,
                cache: KVCache, tp: int = 1,
                attn_fn=None) -> Tuple[jax.Array, KVCache]:
    """One decode iteration: tokens (B,) -> logits (B, V), updated cache.

    ``attn_fn(q, k_cache, v_cache, lengths) -> (B, Hq, D)`` may be overridden
    with the sequence-sharded distributed implementation.
    """
    hq, hkv = cfg.padded_heads(tp)
    attn_fn = attn_fn or L.decode_attention
    x = L.embed(params["embed"], tokens)                 # (B, H)
    b = x.shape[0]
    positions = cache.lengths[:, None]                   # (B, 1)
    if cfg.mrope:
        positions = jnp.broadcast_to(positions[..., None], (b, 1, 3))

    def body(li, carry):
        x, kc_all, vc_all = carry
        lp = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, li, 0, keepdims=False),
            params["blocks"])
        kc = lax.dynamic_index_in_dim(kc_all, li, 0, keepdims=False)
        vc = lax.dynamic_index_in_dim(vc_all, li, 0, keepdims=False)
        h = L.apply_norm(cfg.norm, lp["ln1"], x[:, None, :])
        q, k, v = L.qkv_project(lp["attn"], h, hq, hkv, cfg.d_head)
        q = L.apply_rope(q, positions, cfg.rope_theta,
                         cfg.mrope_sections if cfg.mrope else None)
        k = L.apply_rope(k, positions, cfg.rope_theta,
                         cfg.mrope_sections if cfg.mrope else None)
        # write new k/v at each request's current length
        idx = cache.lengths                              # (B,)
        kc = jax.vmap(lambda c, kn, i: lax.dynamic_update_slice_in_dim(
            c, kn, i, axis=0))(kc, k[:, 0:1], idx)
        vc = jax.vmap(lambda c, vn, i: lax.dynamic_update_slice_in_dim(
            c, vn, i, axis=0))(vc, v[:, 0:1], idx)
        attn = attn_fn(q[:, 0], kc, vc, cache.lengths + 1)  # (B, Hq, D)
        x = x + attn.reshape(b, hq * cfg.d_head) @ lp["attn"]["wo"]
        h2 = L.apply_norm(cfg.norm, lp["ln2"], x)
        if cfg.num_experts:
            y = L.apply_moe(lp["moe"], h2, cfg)
        else:
            y = L.apply_ffn(lp["ffn"], h2, cfg.act)
        # in-place cache update: a scan emitting stacked (k, v) outputs
        # would materialize a SECOND full cache in temp (§Perf iter. 17)
        kc_all = lax.dynamic_update_index_in_dim(kc_all, kc, li, 0)
        vc_all = lax.dynamic_update_index_in_dim(vc_all, vc, li, 0)
        return (x + y, kc_all, vc_all)

    x, k_new, v_new = lax.fori_loop(0, cfg.num_layers, body,
                                    (x, cache.k, cache.v),
                                    unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg.norm, params["ln_f"], x)
    logits = L.unembed(params["embed"], x)
    return logits, KVCache(k_new, v_new, cache.lengths + 1)
