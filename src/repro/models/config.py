"""Architecture configuration system.

One ``ArchConfig`` describes any of the supported model families:
dense / MoE decoder LMs, attention-free SSMs (RWKV6), hybrid recurrent
(RecurrentGemma RG-LRU + local attention), VLM text backbones (M-RoPE), and
encoder-decoder audio backbones (Whisper).  Family-specific fields are
ignored by other families.

TP-divisibility: ``padded_heads``/``padded_vocab`` pad the head count and
vocab to multiples required by the tensor-parallel degree; padding is zeroed
and masked so results are exact (see models/layers.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.core.operators import MLASpec, ModelSpec, MoESpec


def _pad_to(x: int, g: int) -> int:
    return -(-x // g) * g


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_q_heads: int
    num_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # ---- MoE ----------------------------------------------------------------
    num_experts: int = 0
    topk: int = 0
    d_ff_expert: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # ---- attention / ffn ----------------------------------------------------
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    gated_ffn: bool = True
    act: str = "silu"            # silu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = False
    mrope: bool = False          # Qwen2-VL multimodal rope
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # ---- ssm (rwkv6) ---------------------------------------------------------
    rwkv_head_size: int = 64
    # ---- hybrid (recurrentgemma) ---------------------------------------------
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    window: int = 0                        # local-attention window
    lru_width: int = 0
    conv_width: int = 4
    # ---- encoder-decoder (whisper) --------------------------------------------
    encoder_layers: int = 0
    encoder_frames: int = 1500             # stub conv frontend output length
    # ---- misc -----------------------------------------------------------------
    max_seq: int = 1 << 19
    dtype: str = "bfloat16"
    # Layer-scan unroll factor.  Functional no-op; used by the dry-run's
    # scan-undercount calibration (cost_analysis counts a while body once,
    # so unroll=2 vs unroll=1 differ by exactly one body copy).
    scan_unroll: int = 1

    # ---- derived ---------------------------------------------------------------
    @property
    def sub_quadratic(self) -> bool:
        """Supports O(1)-state decode (long_500k eligible)."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encdec(self) -> bool:
        return self.family == "audio"

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def group_size(self) -> int:
        return self.num_q_heads // max(1, self.num_kv_heads)

    def padded_heads(self, tp: int) -> Tuple[int, int]:
        """(q_heads, kv_heads) padded for a TP degree.  MHA (kv == q) pads
        both together; GQA kv heads smaller than tp are replicated (not
        padded) — handled by the sharding rules.  Invariant: hq % hkv == 0."""
        hq = _pad_to(self.num_q_heads, tp)
        if self.num_kv_heads == self.num_q_heads:
            return hq, hq
        hkv = self.num_kv_heads if self.num_kv_heads < tp \
            else _pad_to(self.num_kv_heads, tp)
        if hq % hkv:                       # keep the GQA group integral
            hq = _pad_to(hq, hkv)
        return hq, hkv

    def padded_vocab(self, tp: int) -> int:
        return _pad_to(self.vocab, 128 * tp)

    def nmp_spec(self) -> ModelSpec:
        """Project this architecture into the NMP simulator's ModelSpec."""
        moe = None
        if self.num_experts:
            moe = MoESpec(num_experts=self.num_experts, topk=self.topk,
                          d_ff_expert=self.d_ff_expert,
                          num_shared_experts=self.num_shared_experts,
                          d_ff_shared=self.d_ff_expert)
        return ModelSpec(name=self.name, num_layers=self.num_layers,
                         d_model=self.d_model, d_ff=self.d_ff,
                         num_q_heads=self.num_q_heads,
                         num_kv_heads=max(1, self.num_kv_heads),
                         vocab=self.vocab, d_head=self.d_head,
                         gated_ffn=self.gated_ffn, moe=moe)

    def reduced(self, **over) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            # hybrids need one full (rec, rec, attn) group + a 2-layer tail
            num_layers=5 if self.block_pattern else min(self.num_layers, 2),
            d_model=128,
            num_q_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads)),
            d_head=32,
            d_ff=256,
            vocab=512,
            max_seq=256,
            lru_width=128 if self.lru_width else 0,
            window=min(self.window, 64) if self.window else 0,
            num_experts=min(self.num_experts, 8) if self.num_experts else 0,
            topk=min(self.topk, 2) if self.topk else 0,
            d_ff_expert=128 if self.d_ff_expert else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_frames=32 if self.encoder_frames else 0,
            rwkv_head_size=32,
            dtype="float32",
        )
        small.update(over)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Input-shape cells assigned to every LM architecture
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """Cell applicability per the assignment's skip policy."""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attention: 500k decode needs sub-quadratic)"
    return True, ""
