"""Sharding rules: parameter PartitionSpecs, batch/cache specs, ZeRO-1.

Pattern-based: parameter paths map to Megatron-style TP layouts chosen by the
planner's column/row rule (DESIGN.md §4), with divisibility checked against
the actual mesh — any non-divisible dim degrades to replication rather than
failing, and the degradation is visible in the returned spec table.

Sequence-sharded decode caches implement the paper's IS-S on the attention
context dimension: KV caches shard S over "model", batch over ("pod","data").
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return tuple(out)


def _div(shape, dim, mesh, axis) -> bool:
    return shape[dim] % int(np.prod([mesh.shape[a] for a in
                                     (axis if isinstance(axis, tuple)
                                      else (axis,))])) == 0


def _spec(shape, mesh, *dims) -> P:
    """Build a PartitionSpec, dropping non-divisible entries to None."""
    entries = []
    for d, ax in enumerate(dims):
        if ax is None:
            entries.append(None)
        elif _div(shape, d, mesh, ax):
            entries.append(ax)
        else:
            entries.append(None)
    return P(*entries)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------
def param_pspecs(params: Any, mesh) -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""

    def rule(path, leaf) -> P:
        names = _path_names(path)
        last = names[-1]
        shape = leaf.shape
        nd = len(shape)
        pre = (None,) * (nd - 2)  # stacked layer/group leading dims

        def tail2(a, b):
            return _spec(shape, mesh, *pre, a, b)

        # ---- embeddings -----------------------------------------------------
        if last == "table":
            # d-sharded (not vocab-sharded): the token-id gather stays local
            # per shard; vocab sharding makes GSPMD all-gather the full
            # (V, d) table in f32 for every lookup (§Perf iteration 9)
            return _spec(shape, mesh, None, "model")
        if last == "head":
            return _spec(shape, mesh, None, "model")
        if last == "pos_dec":
            return P(*([None] * nd))
        # ---- attention -------------------------------------------------------
        if last in ("wq", "wk", "wv"):
            return tail2(None, "model")       # column-parallel (OS-S)
        if last == "wo":
            return tail2("model", None)       # row-parallel (IS-S)
        if last in ("bq", "bk", "bv"):
            return _spec(shape, mesh, *((None,) * (nd - 1)), "model")
        # ---- MoE -------------------------------------------------------------
        if "moe" in names:
            if last == "router":
                return P(*([None] * nd))
            if last in ("w_up", "w_gate", "w_down") and "shared" not in names:
                # (L, E, d, f): expert-parallel over model
                return _spec(shape, mesh, *([None] * (nd - 3)), "model",
                             None, None)
        # ---- FFN / channel-mix ------------------------------------------------
        if last in ("w_up", "w_gate", "w_in_x", "w_in_gate"):
            return tail2(None, "model")
        if last == "w_down":
            return tail2("model", None)
        if last == "w_out":
            return tail2("model", None)
        # ---- rwkv6 time/channel mix -------------------------------------------
        if "cm" in names and last == "wk":
            return tail2(None, "model")
        if "cm" in names and last == "wv":
            return tail2("model", None)
        if last in ("wr", "wg"):
            return tail2(None, "model") if "cm" not in names \
                else P(*([None] * nd))
        if last == "u_bonus":
            return _spec(shape, mesh, *pre, "model", None)
        # ---- rglru ----------------------------------------------------------
        if last in ("conv_w",):
            return _spec(shape, mesh, *pre, None, "model")
        if last in ("conv_b",):
            return _spec(shape, mesh, *((None,) * (nd - 1)), "model")
        if last in ("w_a", "w_i"):
            return tail2("model", None)
        if last in ("lam", "b_a", "b_i"):
            return _spec(shape, mesh, *((None,) * (nd - 1)), "model")
        # ---- norms, biases, scalars ------------------------------------------
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, params)


def zero1_pspecs(param_specs: Any, params: Any, mesh) -> Any:
    """Optimizer-state specs: the param spec + shard the first
    still-replicated divisible dim over the data axis (ZeRO-1)."""
    daxes = data_axes(mesh)
    if not daxes:
        return param_specs

    def _uses_data(e) -> bool:
        axes = e if isinstance(e, tuple) else (e,)
        return any(a in daxes for a in axes if a)

    def rule(spec: P, leaf) -> P:
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        if any(_uses_data(e) for e in entries):
            return P(*entries)          # already data-sharded (FSDP)
        for d, e in enumerate(entries):
            if e is None and _div(leaf.shape, d, mesh, daxes):
                entries[d] = daxes if len(daxes) > 1 else daxes[0]
                break
        return P(*entries)

    return jax.tree_util.tree_map(rule, param_specs, params)


def fsdp_pspecs(param_specs: Any, params: Any, mesh) -> Any:
    """FSDP / ZeRO-3 parameter sharding: same rule as ZeRO-1 applied to the
    PARAMETERS themselves — the first still-replicated divisible dim shards
    over the data axes.  Under the layer scan, XLA re-gathers exactly one
    layer's weights at a time, so the transient all-gather replaces a
    full-resident copy (TP-only residency exceeds a 16 GB chip for the
    100B+ assigned architectures; see EXPERIMENTS.md §Perf iteration 4)."""
    return zero1_pspecs(param_specs, params, mesh)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------
def batch_pspecs(batch: Dict[str, Any], mesh) -> Dict[str, Any]:
    daxes = data_axes(mesh)
    dp = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def rule(path, leaf):
        shape = leaf.shape
        if not shape:
            return P()
        entries = [None] * len(shape)
        if _div(shape, 0, mesh, daxes):
            entries[0] = dp
        return P(*entries)

    return jax.tree_util.tree_map_with_path(rule, batch)


def cache_pspecs(cache: Any, mesh) -> Any:
    """KV caches: (L, B, S, Hkv, D) -> batch over data axes, SEQUENCE over
    "model" (paper IS-S on the context dim).  Recurrent states: batch over
    data, width/heads over model.  Non-divisible dims degrade to None."""
    daxes = data_axes(mesh)
    dp = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def rule(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        nd = len(shape)
        last = names[-1] if names else ""
        if nd == 1:      # lengths
            return _spec(shape, mesh, dp)
        if last in ("k", "v", "self_k", "self_v", "cross_k", "cross_v",
                    "k_cache", "v_cache"):
            # (L, B, S, H, D)
            return _spec(shape, mesh, None, dp, "model", None, None)
        if last == "pos_cache":
            return _spec(shape, mesh, None, dp, "model")
        if last == "wkv":       # (L, B, H, hs, hs)
            return _spec(shape, mesh, None, dp, "model", None, None)
        if last in ("tm_x", "cm_x", "lru_h"):   # (L, B, d)
            return _spec(shape, mesh, None, dp, "model")
        if last == "conv":      # (L, B, cw-1, W)
            return _spec(shape, mesh, None, dp, None, "model")
        entries = [None] * nd
        if nd >= 2 and _div(shape, 1, mesh, daxes):
            entries[1] = dp
        return P(*entries)

    return jax.tree_util.tree_map_with_path(rule, cache)


def to_named(tree_specs: Any, mesh) -> Any:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  tree_specs,
                                  is_leaf=lambda x: isinstance(x, P))
