"""Distribution context: the active mesh for model-internal sharding hooks.

Model code stays pure; when a mesh context is active, layers route to their
distributed implementations (EP MoE, sequence-sharded decode attention).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

_state = threading.local()


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev
