"""Sequence-sharded decode attention under shard_map (paper IS-S, §5).

The KV cache shards its context dimension S over the "model" mesh axis; each
shard computes partial attention (un-normalized accumulator + log-sum-exp
stats) over its S/P cached tokens with the flash-decode math, then shards
combine EXACTLY via a psum of (acc * exp(m - m_max), l * exp(m - m_max)).
This moves (B, Hq, D)-sized stats over ICI instead of the (B, S, Hkv, D)
cache — the paper's observation that splitting the AV operator's K dimension
(here: the context) is the right spatial partition for decode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.layers import decode_attention_core
from repro.launch.mesh import data_axes


def _local_partial(q, k_shard, v_shard, valid_shard):
    acc, l, m = decode_attention_core(q, k_shard, v_shard, valid_shard)
    return acc, l, m


def make_seq_sharded_attn(mesh, axis: str = "model"):
    """Returns attn_fn(q, k_cache, v_cache, lengths) -> (B, Hq, D) with the
    cache S dim sharded over ``axis`` (layer-level: caches are (B,S,H,D))."""
    daxes = data_axes(mesh)
    dp = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    p_size = mesh.shape[axis]

    def attn(q, k_cache, v_cache, lengths):
        b, s, hkv, d = k_cache.shape

        def shard_fn(q, k, v, lengths):
            # k/v: (B, S/P, Hkv, D) local shard; q replicated over `axis`
            idx = lax.axis_index(axis)
            s_local = k.shape[1]
            start = idx * s_local
            pos = start + jnp.arange(s_local)[None, :]
            valid = pos < lengths[:, None]
            acc, l, m = _local_partial(q, k, v, valid)
            # exact combine: renormalize to the global max
            m_max = lax.pmax(m, axis)
            scale = jnp.exp(m - m_max)
            acc = lax.psum(acc * scale[..., None], axis)
            l = lax.psum(l * scale, axis)
            out = acc / jnp.maximum(l, 1e-20)[..., None]
            bq, hk, g, dd = out.shape
            return out.reshape(bq, hk * g, dd).astype(q.dtype)

        return jax.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(P(dp, None, None), P(dp, axis, None, None),
                      P(dp, axis, None, None), P(dp)),
            out_specs=P(dp, None, None),
            check_vma=False,
        )(q, k_cache, v_cache, lengths)

    return attn
