"""Operator-aware partition planner — the paper's §5 scheduling framework
mapped onto TPU tensor parallelism (DESIGN.md §4).

For each projection GEMM (M = tokens, K = in-features, N = out-features) and
a TP degree P, the two spatial modes translate to:

  IS-S  (split K)  -> row-parallel weight P("model", None):  each shard
        holds K/P rows, produces a full (M, N) partial sum, followed by an
        all-reduce (2*(P-1)/P * M*N*b bytes on ICI);
  OS-S  (split N)  -> column-parallel weight P(None, "model"): each shard
        produces an (M, N/P) output shard, followed by an all-gather where
        the full activation is next consumed ((P-1)/P * M*N*b bytes) — or NO
        collective when the consumer contracts exactly this dimension
        (column -> row chaining, the paper's OS-S -> IS-S layout chain).

The planner picks per-GEMM modes by the same cost model the NMP scheduler
uses: compute is identical across modes (M*N*K/P), so the decision reduces
to collective bytes + utilization corrections — with the paper's first-order
N-vs-K rule recovered when both collectives are exposed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Literal, Optional, Sequence, Tuple

from repro.core.hw import TPU_V5E_ICI_BW

Mode = Literal["column", "row", "replicate"]


@dataclass(frozen=True)
class GemmPlan:
    name: str
    m: int
    n: int
    k: int
    mode: Mode
    comm_bytes: int        # exposed collective payload per step
    chained: bool = False  # column output consumed directly by a row consumer
    note: str = ""

    @property
    def comm_time_s(self) -> float:
        return self.comm_bytes / TPU_V5E_ICI_BW


def _ar_bytes(m: int, n: int, p: int, b: int = 2) -> int:
    return int(2 * (p - 1) / p * m * n * b)


def _ag_bytes(m: int, n: int, p: int, b: int = 2) -> int:
    return int((p - 1) / p * m * n * b)


def plan_projection(name: str, m: int, n: int, k: int, p: int,
                    consumer_contracts_n: bool = False,
                    divisible_n: bool = True,
                    divisible_k: bool = True) -> GemmPlan:
    """Pick column (OS-S) vs row (IS-S) for one weight (K, N)."""
    cands: List[GemmPlan] = []
    if divisible_n:
        if consumer_contracts_n:
            cands.append(GemmPlan(name, m, n, k, "column", 0, chained=True,
                                  note="OS-S -> IS-S chain, gather skipped"))
        else:
            cands.append(GemmPlan(name, m, n, k, "column",
                                  _ag_bytes(m, n, p), note="all-gather"))
    if divisible_k:
        cands.append(GemmPlan(name, m, n, k, "row", _ar_bytes(m, n, p),
                              note="all-reduce of partials"))
    if not cands:
        return GemmPlan(name, m, n, k, "replicate", 0,
                        note="no divisible axis; replicated")
    return min(cands, key=lambda c: c.comm_bytes)


def plan_ffn(name: str, m: int, d_model: int, d_ff: int, p: int
             ) -> Tuple[GemmPlan, GemmPlan]:
    """The canonical pair: up/gate column-parallel chained into down
    row-parallel — one all-reduce for the whole FFN (Megatron = the paper's
    OS-S -> IS-S chain)."""
    up = plan_projection(f"{name}.up", m, d_ff, d_model, p,
                         consumer_contracts_n=True)
    down = plan_projection(f"{name}.down", m, d_model, d_ff, p,
                           divisible_n=False)
    return up, down


def plan_decode_attention(batch: int, ctx: int, heads: int, d_head: int,
                          p: int) -> GemmPlan:
    """Sequence-sharding the KV cache = IS-S on the AV operator (K = ctx):
    each shard computes partial attention over ctx/P cached tokens, combined
    with a log-sum-exp all-reduce of (B, Hq, D) + stats — tiny vs moving the
    cache."""
    payload = _ar_bytes(batch, heads * (d_head + 2), p, 4)
    return GemmPlan("attn.decode", batch * heads, d_head, ctx, "row",
                    payload, note="seq-sharded cache + lse-combine psum")


def describe(plans: Sequence[GemmPlan]) -> str:
    lines = ["name            mode     M       N       K      comm_bytes"]
    for pl in plans:
        lines.append(f"{pl.name:15s} {pl.mode:8s} {pl.m:<7d} {pl.n:<7d} "
                     f"{pl.k:<7d}{pl.comm_bytes:>10d}  {pl.note}")
    return "\n".join(lines)
