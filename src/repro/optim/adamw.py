"""AdamW with global-norm clipping, cosine schedule, and optional int8
error-feedback gradient compression — self-contained (no optax).

States are plain pytrees so the ZeRO-1 sharding rules in
``repro.distributed.sharding`` can shard them over the data axis.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment, same treedef as params
    nu: Any          # second moment
    ef: Any = None   # error-feedback residual (compression only)


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    state_dtype: Any = jnp.float32
    compress_grads: bool = False     # int8 error-feedback compression


def init(params, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    ef = jax.tree.map(zeros, params) if cfg.compress_grads else None
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params), ef)


def schedule(step, cfg: AdamWConfig):
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# int8 error-feedback compression (cross-pod gradient-reduction payload)
# ---------------------------------------------------------------------------
def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads, ef):
    """Error-feedback: residual from the previous step is added before
    quantization; the new residual is what quantization lost."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = compress_int8(gf)
        deq = decompress_int8(q, s)
        return deq.astype(g.dtype), (gf - deq)
    flat = jax.tree.map(one, grads, ef)
    new_g = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_g, new_ef


def update(grads, state: AdamWState, params,
           cfg: AdamWConfig) -> Tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    ef = state.ef
    if cfg.compress_grads:
        grads, ef = ef_compress_grads(grads, ef)
    step = state.step + 1
    lr = schedule(state.step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m.astype(cfg.state_dtype), v.astype(cfg.state_dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree.map(lambda t: t[0], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_p, AdamWState(step, new_m, new_v, ef), \
        {"grad_norm": gnorm, "lr": lr}
