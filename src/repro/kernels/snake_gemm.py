"""SNAKE decode GEMM — shape-adaptive small-M matmul Pallas kernel.

TPU adaptation of the paper's reconfigurable systolic array (§4.2, DESIGN.md
§4).  The physical fabric (MXU) is fixed; what we reconfigure per operator
shape is the *mapping*:

* "logical array shape"  -> VMEM block shape: M is padded only to the sublane
  granularity (8 f32 / 16 bf16 — the analogue of SNAKE's reconfiguration
  granularity of 8) and the freed VMEM budget goes to wide N/K blocks, which
  is exactly the paper's 8x512-style elongation;
* "dataflow"             -> grid order + residency:
    IS (input-stationary):  the whole (M, K) activation stays resident in
        VMEM, B streams one N-block per grid step, one full-K dot each —
        chosen when N > K and A+B blocks fit VMEM (paper's rule);
    OS (output-stationary): an f32 (M, bn) accumulator stays resident in a
        VMEM scratch while K streams in blocks — chosen when K is too large
        to hold (K temporal = paper's OS).

Both mappings share one kernel body structure, mirroring how SNAKE's OS/IS
share the PE fabric and differ only in boundary injection.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
VMEM_BUDGET = 12 * 1024 * 1024   # leave headroom below the 16 MB/core VMEM


def _sublane(dtype) -> int:
    return 16 if dtype in (jnp.bfloat16, jnp.dtype(jnp.bfloat16)) else 8


def _round_up(x: int, g: int) -> int:
    return -(-x // g) * g


@dataclass(frozen=True)
class GemmMapping:
    dataflow: str        # "IS" | "OS"
    block_m: int
    block_n: int
    block_k: int         # == K for IS

    @property
    def grid(self) -> Tuple[int, ...]:
        raise NotImplementedError


def choose_mapping(m: int, n: int, k: int, dtype=jnp.bfloat16) -> GemmMapping:
    """The paper's §3.1 first-order rule, restated in VMEM terms."""
    bm = _round_up(max(1, m), _sublane(dtype))
    esize = jnp.dtype(dtype).itemsize
    # IS feasibility: resident A (bm x K) + streamed B (K x bn) + out
    bn = LANE
    while True:
        nxt = bn * 2
        if (bm * k + k * nxt + bm * nxt) * esize + bm * nxt * 4 > VMEM_BUDGET:
            break
        if nxt > _round_up(n, LANE):
            break
        bn = nxt
    is_feasible = (bm * k + k * bn + bm * bn) * esize <= VMEM_BUDGET
    if is_feasible and n > k:
        return GemmMapping("IS", bm, bn, k)
    # OS: block K; accumulator (bm x bn) f32 resident
    bk = min(_round_up(k, LANE), 2048)
    bn = LANE
    while True:
        nxt = bn * 2
        if ((bm * bk + bk * nxt) * esize + bm * nxt * 4) > VMEM_BUDGET:
            break
        if nxt > _round_up(n, LANE):
            break
        bn = nxt
    return GemmMapping("OS", bm, bn, bk)


# ---------------------------------------------------------------------------
# Kernels
# ---------------------------------------------------------------------------
def _is_kernel(a_ref, b_ref, o_ref):
    """Input-stationary: full-K dot per N block; A resident across grid."""
    o_ref[...] = lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _os_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    """Output-stationary: f32 accumulator resident while K streams."""
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kk == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def snake_decode_gemm(a: jax.Array, b: jax.Array,
                      mapping: Optional[GemmMapping] = None,
                      interpret: bool = False) -> jax.Array:
    """a: (M, K) @ b: (K, N) -> (M, N) with shape-adaptive mapping."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    dtype = a.dtype
    mp = mapping or choose_mapping(m, n, k, dtype)
    bm = mp.block_m
    # pad every dim to its block multiple (M to sublane granularity = the
    # SNAKE reconfiguration granularity; N/K to the lane width)
    mp_pad = _round_up(m, bm)
    np_ = _round_up(n, mp.block_n)
    kp = _round_up(k, mp.block_k if mp.dataflow == "OS" else LANE)
    a_p = jnp.pad(a, ((0, mp_pad - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))

    if mp.dataflow == "IS":
        grid = (np_ // mp.block_n,)
        out = pl.pallas_call(
            _is_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((mp_pad, kp), lambda i: (0, 0)),
                pl.BlockSpec((kp, mp.block_n), lambda i: (0, i)),
            ],
            out_specs=pl.BlockSpec((mp_pad, mp.block_n), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((mp_pad, np_), dtype),
            interpret=interpret,
        )(a_p, b_p)
    else:
        k_steps = kp // mp.block_k
        grid = (np_ // mp.block_n, k_steps)
        out = pl.pallas_call(
            functools.partial(_os_kernel, k_steps=k_steps),
            grid=grid,
            in_specs=[
                pl.BlockSpec((mp_pad, mp.block_k), lambda i, j: (0, j)),
                pl.BlockSpec((mp.block_k, mp.block_n), lambda i, j: (j, i)),
            ],
            out_specs=pl.BlockSpec((mp_pad, mp.block_n), lambda i, j: (0, i)),
            out_shape=jax.ShapeDtypeStruct((mp_pad, np_), dtype),
            scratch_shapes=[pltpu.VMEM((mp_pad, mp.block_n), jnp.float32)],
            interpret=interpret,
        )(a_p, b_p)
    return out[:m, :n]
