"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels run with interpret=True; on real TPU
set ``REPRO_PALLAS_INTERPRET=0`` (or rely on the default platform check).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.flash_decode import paged_flash_decode as _paged_flash
from repro.kernels.snake_gemm import (GemmMapping, choose_mapping,
                                      snake_decode_gemm as _snake_gemm)
from repro.kernels.wkv6 import wkv6 as _wkv6


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_gemm(a: jax.Array, b: jax.Array, interpret: bool = None):
    """Shape-adaptive small-M GEMM: a (M, K) @ b (K, N)."""
    interp = _interpret() if interpret is None else interpret
    return _snake_gemm(a, b, interpret=interp)


def decode_gemm_mapping(m: int, n: int, k: int, dtype=jnp.bfloat16):
    return choose_mapping(m, n, k, dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def attention_decode(q, k, v, lengths, block_s: int = 512,
                     interpret: bool = None):
    """GQA flash-decode: q (B,Hq,D) against (B,S,Hkv,D) caches."""
    interp = _interpret() if interpret is None else interpret
    return _flash_decode(q, k, v, lengths, block_s=block_s, interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def attention_decode_paged(q, k_pool, v_pool, tables, lengths,
                           interpret: bool = None):
    """GQA flash-decode through a block table: q (B,Hq,D) against page
    pools (P+1,page,Hkv,D) mapped by tables (B,nblk)."""
    interp = _interpret() if interpret is None else interpret
    return _paged_flash(q, k_pool, v_pool, tables, lengths,
                        interpret=interp)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6_scan(r, k, v, w, u, state0, interpret: bool = None):
    """RWKV6 recurrence with VMEM-resident state."""
    interp = _interpret() if interpret is None else interpret
    return _wkv6(r, k, v, w, u, state0, interpret=interp)
