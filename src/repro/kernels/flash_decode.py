"""Flash-decode attention Pallas kernel (GQA decode against a KV cache).

This is the paper's attention scheduling made TPU-native (§5b, DESIGN.md §4):
the context dimension S — the K dimension of the AV GEMM — is walked in
blocks (temporal partitioning, the ST axis) with an online-softmax
accumulator resident in VMEM (output-stationary), while the per-(request,
kv-head) grid axes give the head-level parallelism the paper maps across
PUs.  The group dimension G = Hq/Hkv is the small M: it is padded only to
the sublane granularity, exactly like SNAKE's M-granularity of 8.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _sublane(dtype) -> int:
    return 16 if jnp.dtype(dtype) == jnp.bfloat16 else 8


def _round_up(x: int, g: int) -> int:
    return -(-x // g) * g


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            block_s: int, s_steps: int, scale: float):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (bs, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32) * scale  # (G,bs)
    pos = si * block_s + lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < len_ref[0, 0]
    s = jnp.where(valid, s, NEG)

    m_prev = m_ref[:, :1]                          # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)  # (G, bs)
    l_new = l_ref[:, :1] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, :1] = m_new
    l_ref[:, :1] = l_new

    @pl.when(si == s_steps - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[:, :1], 1e-20)).astype(o_ref.dtype)


def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, page: int, n_blocks: int,
                  scale: float):
    """Per-(request, kv-head, table-entry) program.

    The grid's S axis walks the slot's BLOCK TABLE instead of a contiguous
    context: the k/v BlockSpec index_map dereferences the scalar-prefetched
    table, so each step DMAs one page straight out of the pool — the paged
    cache is never materialized as a dense (B, S) view.  Unmapped entries
    point at the scratch page and are masked by ``lengths`` exactly like
    the padded tail in the contiguous kernel.
    """
    bi = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (page, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32) * scale
    pos = si * page + lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < len_ref[bi]
    s = jnp.where(valid, s, NEG)

    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_new = l_ref[:, :1] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[:, :1] = m_new
    l_ref[:, :1] = l_new

    @pl.when(si == n_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[:, :1], 1e-20)).astype(o_ref.dtype)


def paged_flash_decode(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       tables: jax.Array, lengths: jax.Array,
                       interpret: bool = False) -> jax.Array:
    """Flash decode through a block table (vLLM-style paged attention).

    q: (B, Hq, D); k_pool/v_pool: (P+1, page, Hkv, D) page pools whose last
    page is scratch; tables: (B, nblk) int32 page ids (unmapped -> scratch
    page); lengths: (B,) valid context per request.  Returns (B, Hq, D).

    On real TPUs the page size should be a multiple of the sublane count
    (8 fp32 / 16 bf16) so each page DMA is tile-aligned.
    """
    b, hq, d = q.shape
    npages, page, hkv, _ = k_pool.shape
    nblk = tables.shape[1]
    g = hq // hkv
    gp = _round_up(g, _sublane(q.dtype))
    scale = 1.0 / math.sqrt(d)

    qr = q.reshape(b, hkv, g, d)
    qr = jnp.pad(qr, ((0, 0), (0, 0), (0, gp - g), (0, 0)))
    kt = jnp.moveaxis(k_pool, 2, 1)               # (P+1, Hkv, page, D)
    vt = jnp.moveaxis(v_pool, 2, 1)

    grid = (b, hkv, nblk)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                    # tables, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, gp, d),
                         lambda bi, hi, si, tbl, ln: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, page, d),
                         lambda bi, hi, si, tbl, ln: (tbl[bi, si], hi, 0, 0)),
            pl.BlockSpec((1, 1, page, d),
                         lambda bi, hi, si, tbl, ln: (tbl[bi, si], hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, d),
                               lambda bi, hi, si, tbl, ln: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((gp, d), jnp.float32),
            pltpu.VMEM((gp, 128), jnp.float32),
            pltpu.VMEM((gp, 128), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, page=page, n_blocks=nblk,
                          scale=scale),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, gp, d), q.dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), lengths.astype(jnp.int32), qr, kt, vt)
    return out[:, :, :g, :].reshape(b, hq, d)


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 lengths: jax.Array, block_s: int = 512,
                 interpret: bool = False) -> jax.Array:
    """q: (B, Hq, D); k/v: (B, S, Hkv, D); lengths: (B,) -> (B, Hq, D)."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    gp = _round_up(g, _sublane(q.dtype))
    sp = _round_up(s, block_s)
    scale = 1.0 / math.sqrt(d)

    qr = q.reshape(b, hkv, g, d)
    qr = jnp.pad(qr, ((0, 0), (0, 0), (0, gp - g), (0, 0)))
    kt = jnp.moveaxis(k, 2, 1)                    # (B, Hkv, S, D)
    vt = jnp.moveaxis(v, 2, 1)
    kt = jnp.pad(kt, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
    vt = jnp.pad(vt, ((0, 0), (0, 0), (0, sp - s), (0, 0)))
    len2 = lengths.reshape(b, 1).astype(jnp.int32)

    s_steps = sp // block_s
    grid = (b, hkv, s_steps)
    out = pl.pallas_call(
        functools.partial(_kernel, block_s=block_s, s_steps=s_steps,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, hi, si: (bi, 0)),
            pl.BlockSpec((1, 1, gp, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_s, d),
                         lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((1, 1, block_s, d),
                         lambda bi, hi, si: (bi, hi, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, d),
                               lambda bi, hi, si: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, gp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((gp, d), jnp.float32),
            pltpu.VMEM((gp, 128), jnp.float32),
            pltpu.VMEM((gp, 128), jnp.float32),
        ],
        interpret=interpret,
    )(len2, qr, kt, vt)
    return out[:, :, :g, :].reshape(b, hq, d)
