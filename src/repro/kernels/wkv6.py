"""WKV6 recurrence Pallas kernel (RWKV6 time-mix core).

The paper's unified substrate routes auxiliary tensor ops to the vector core
with the SA's output buffer as its working store (§3.3/§4.2.3).  The TPU
analogue for the WKV recurrence is keeping the (hs x hs) per-head state
RESIDENT IN VMEM for the whole sequence sweep — the jnp.scan reference
round-trips the state through HBM every step, so the kernel removes
T * hs^2 * 8 bytes of HBM traffic per head (the memory-roofline term).

Grid: (B, H) — head-level parallelism, exactly the paper's attention/head
mapping across PUs.  Inside: a sequential fori_loop over T (the recurrence
is inherently serial in its dependency; the chunk-parallel reformulation is
a recorded future optimization in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
            s_scr, *, t_len: int):
    s_scr[...] = s0_ref[0, 0].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)             # (1, hs) row

    def step(t, _):
        rt = r_ref[0, 0, pl.ds(t, 1)].astype(jnp.float32)   # (1, hs)
        kt = k_ref[0, 0, pl.ds(t, 1)].astype(jnp.float32)
        vt = v_ref[0, 0, pl.ds(t, 1)].astype(jnp.float32)
        wt = w_ref[0, 0, pl.ds(t, 1)].astype(jnp.float32)
        kv = lax.dot_general(kt, vt, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (hs, hs)
        s = s_scr[...]
        y = lax.dot_general(rt, s + u.T * kv, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (1, hs)
        y_ref[0, 0, pl.ds(t, 1)] = y.astype(y_ref.dtype)
        s_scr[...] = wt.T * s + kv
        return _

    lax.fori_loop(0, t_len, step, None)
    sT_ref[0, 0] = s_scr[...].astype(sT_ref.dtype)


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, state0: jax.Array, interpret: bool = False):
    """r/k/v/w: (B, T, H, hs); u: (H, hs); state0: (B, H, hs, hs).

    Returns (y: (B, T, H, hs), state_T: (B, H, hs, hs)).
    """
    b, t, h, hs = r.shape
    tr = lambda x: jnp.moveaxis(x, 2, 1)           # (B, H, T, hs)
    rt_, kt_, vt_, wt_ = tr(r), tr(k), tr(v), tr(w)

    y, sT = pl.pallas_call(
        functools.partial(_kernel, t_len=t),
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, t, hs), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, t, hs), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, t, hs), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, t, hs), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, hs), lambda bi, hi: (hi, 0)),
            pl.BlockSpec((1, 1, hs, hs), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, t, hs), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, hs, hs), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, t, hs), r.dtype),
            jax.ShapeDtypeStruct((b, h, hs, hs), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(rt_, kt_, vt_, wt_, u, state0)
    return jnp.moveaxis(y, 1, 2), sT
