"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """a: (M, K), b: (K, N) -> (M, N), f32 accumulation."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)
                   ).astype(a.dtype)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     lengths: jax.Array) -> jax.Array:
    """q: (B, Hq, D); k/v: (B, S, Hkv, D); lengths: (B,) -> (B, Hq, D).

    GQA decode attention with per-request valid prefix, f32 softmax.
    """
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qr = q.reshape(b, hkv, g, d).astype(jnp.float32) * scale
    scores = jnp.einsum("bhgd,bshd->bhgs", qr, k.astype(jnp.float32))
    valid = (jnp.arange(s)[None, :] < lengths[:, None])[:, None, None, :]
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, state0: jax.Array):
    """RWKV6 recurrence oracle.

    r/k/v/w: (B, T, H, hs); u: (H, hs); state0: (B, H, hs, hs).
    Returns (y: (B, T, H, hs), state_T).
        y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    def step(s, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(x.astype(jnp.float32), 1, 0)
               for x in (r, k, v, w))
    sT, y = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(y, 0, 1), sT
