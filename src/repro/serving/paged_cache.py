"""Paged (block-table) KV/state cache for the serving engine.

vLLM/L3-style paged residency: instead of reserving a dense
``max_batch x max_seq`` cache, sequence-bearing cache leaves live in a pool
of fixed-size pages and each slot owns a block table mapping its logical
context positions to pages.  KV memory held by a request is then
proportional to its actual context length, which is what lets the engine
admit long-context / skewed-length traffic without reserving for the worst
case.

Prefix sharing (``share=True``): pages carry refcounts and a host-side
prefix trie maps full pages of prompt tokens to the physical page already
holding their KV, so a new request's leading prompt pages are *mapped*
onto existing pages instead of recomputed storage — the shared-system-
prompt workload multiplies admissible batch size per resident page.  A
request with an *identical* prompt additionally shares the ragged tail
page; since both requests will decode-write into that page, any write
targeting a page with refcount > 1 must first ``fork_page`` (copy-on-write:
copy the page on device and remap just that slot's table entry).  The
engine performs that fork in its pre-decode pass, so the jitted scatter
and the Pallas read-through kernel only ever write exclusively-owned
pages.

Generic across all four registry state families via shape probing: we
``eval_shape`` the family's ``cache_zeros`` at two different ``max_seq``
values — leaves whose shape changes are *sequence leaves* and get paged
(KVCache.k/v, EncDecCache.self_k/self_v); everything else (RWKV/RG
recurrent state, cross-attention caches, ``lengths``) is O(1) per request
and stays slot-dense.  For the recurrent families there are no sequence
leaves at all and the paged cache degenerates to the dense layout, which is
already proportional.

Layout: a sequence leaf ``(L, B, S, ...)`` (batch axis 1, seq axis 2 per
the engine's batch-axis rule) becomes a pool ``(L, P+1, page, ...)``; page
index ``P`` is a scratch/trash page so masked scatters and gathers of
unmapped table entries (-1) never touch live data.  Block tables are a host
``(max_batch, max_blocks)`` int32 array mirrored to device on change.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gemm import ceil_div
from repro.core.noc import CollectiveCost, page_ship
from repro.core.placement import (COMMUNAL, PLACEMENT_POLICIES, GatherCost,
                                  PlacementMap, default_system, gather_cost)
from repro.obs.tracer import NULL_TRACER
from repro.serving.replica_api import PlacementReport


# ---------------------------------------------------------------------------
# Host-side block allocator
# ---------------------------------------------------------------------------
class PageAllocator:
    """Refcounted free-list page allocator (host side).

    Pages are plain ints ``0..num_pages-1``.  ``alloc`` returns ``None``
    (allocating nothing) when the request cannot be satisfied — admission
    control, not an error.  Freshly allocated pages start at refcount 1;
    prefix sharing ``incref``s them when a second block table maps the same
    page, and ``decref``/``free`` return a page to the free list only when
    the last reference drops — no page is ever freed while its refcount is
    still positive.

    **Placement** (``placement`` + ``policy``): with a
    :class:`~repro.core.placement.PlacementMap` the allocator places
    pages substrate-aware.  ``free-first`` keeps the legacy LIFO layout
    (wherever the free list points); ``affinity`` prefers the caller's
    ``home`` region (or the communal region for ``communal=True`` shared
    prefix pages), spilling to the emptiest other region only when the
    preferred one runs dry; ``interleave`` stripes pages round-robin
    across slot regions.  Placement only changes WHICH free pages are
    picked — success/failure depends solely on the global free count, so
    admission control (and therefore scheduling) is identical across
    policies.
    """

    def __init__(self, num_pages: int,
                 placement: Optional[PlacementMap] = None,
                 policy: str = "free-first"):
        if num_pages <= 0:
            raise ValueError("num_pages must be positive")
        if policy not in PLACEMENT_POLICIES:
            raise ValueError(f"unknown placement policy {policy!r}; "
                             f"choose from {PLACEMENT_POLICIES}")
        if placement is not None and placement.num_pages != num_pages:
            raise ValueError(
                f"placement map covers {placement.num_pages} pages, "
                f"allocator has {num_pages}")
        self.num_pages = num_pages
        self.placement = placement
        self.policy = policy
        self._rr = 0                    # interleave striping cursor
        self._refs: Dict[int, int] = {}
        self._init_free()

    def _init_free(self) -> None:
        if self.placed:
            # persistent per-region free lists (placed mode): descending
            # so pop() hands out each region's lowest index first — the
            # same LIFO invariant as the global list, at O(1) per page
            self._region_lists: Dict[int, List[int]] = {
                r: sorted(self.placement.region_pages(r), reverse=True)
                for r in self.placement.regions()}
            self._free: List[int] = []      # unused in placed mode
        else:
            self._free = list(range(self.num_pages - 1, -1, -1))

    @property
    def free_pages(self) -> int:
        if self.placed:
            return sum(len(v) for v in self._region_lists.values())
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._refs)

    @property
    def shared_pages(self) -> int:
        """Pages currently mapped by more than one block-table entry."""
        return sum(1 for rc in self._refs.values() if rc > 1)

    def live_pages(self) -> List[int]:
        return sorted(self._refs)

    def highest_used(self) -> int:
        """Highest allocated page index (-1 when empty)."""
        return max(self._refs, default=-1)

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    # -- placement geometry ------------------------------------------------
    @property
    def placed(self) -> bool:
        """True when allocation actively steers placement (a map under a
        non-legacy policy)."""
        return self.placement is not None and self.policy != "free-first"

    def region_free(self) -> Dict[int, int]:
        """Free pages per region (requires a placement map)."""
        assert self.placement is not None
        if self.placed:
            return {r: len(v) for r, v in self._region_lists.items()}
        out = {r: 0 for r in self.placement.regions()}
        for p in self._free:
            out[self.placement.region_of(p)] += 1
        return out

    def region_used(self) -> Dict[int, int]:
        """Allocated pages per region (requires a placement map)."""
        assert self.placement is not None
        out = {r: 0 for r in self.placement.regions()}
        for p in self._refs:
            out[self.placement.region_of(p)] += 1
        return out

    def _select(self, n: int, home: Optional[int],
                n_communal: int) -> List[int]:
        """Pop ``n`` free pages off the per-region lists under the
        placement policy (caller has checked the global free count).
        The first ``n_communal`` picks prefer the communal region; the
        rest follow the policy.  O(1) per page."""
        pmap = self.placement
        lists = self._region_lists
        picks: List[int] = []

        def take_from(region: int, k: int) -> int:
            pool = lists.get(region, [])
            got = min(k, len(pool))
            for _ in range(got):
                picks.append(pool.pop())
            return got

        # shared (publishable) pages go communal under every placement
        # policy: all slots read them, so no slot channel is favored —
        # overflow falls through to the private-page policy below
        want = n - take_from(COMMUNAL, min(n_communal, n)) \
            if pmap.communal_pages else n
        if self.policy == "interleave":
            ring = list(range(pmap.n_regions))
            while want > 0:
                if not any(lists[r] for r in ring):
                    want -= take_from(COMMUNAL, want)   # only communal left
                    break
                r = ring[self._rr % len(ring)]
                self._rr += 1
                want -= take_from(r, min(1, want)) if lists[r] else 0
            return picks
        # affinity: home region first, then spill to the emptiest-used
        # (most-free) other regions, deterministic ties by region id
        order = [home] if home is not None else []
        order.extend(sorted(
            (r for r in pmap.regions() if r not in order),
            key=lambda r: (r == COMMUNAL, -len(lists[r]), r)))
        for r in order:
            want -= take_from(r, want)
            if want == 0:
                break
        return picks

    def alloc(self, n: int, *, home: Optional[int] = None,
              communal: int = 0) -> Optional[List[int]]:
        """Allocate ``n`` pages; ``home`` steers private pages and the
        first ``communal`` of them prefer the communal region (both
        ignored under the legacy free-first policy).  Atomic: returns
        ``None`` without mutating when fewer than ``n`` pages are free."""
        if n < 0:
            raise ValueError("alloc size must be >= 0")
        if n > self.free_pages:
            return None
        if not self.placed:
            pages = [self._free.pop() for _ in range(n)]
        else:
            pages = self._select(n, home, communal)
            assert len(pages) == n
        for p in pages:
            self._refs[p] = 1
        return pages

    def alloc_in(self, region: int, n: int = 1) -> Optional[List[int]]:
        """Allocate ``n`` pages strictly from ``region`` (placed mode
        only) — the page-migration primitive: unlike :meth:`alloc` it
        never spills, returning ``None`` when the region cannot satisfy
        the request in full.  Atomic."""
        assert self.placed, "alloc_in needs active placement"
        pool = self._region_lists.get(region, [])
        if n < 0:
            raise ValueError("alloc size must be >= 0")
        if len(pool) < n:
            return None
        pages = [pool.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def incref(self, page: int) -> None:
        if page not in self._refs:
            raise ValueError(f"incref of unallocated page {page}")
        self._refs[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one reference; True iff the page was returned to the free
        list (refcount reached zero)."""
        rc = self._refs.get(page)
        if rc is None:
            raise ValueError(f"double free / foreign page {page}")
        if rc == 1:
            del self._refs[page]
            if self.placed:
                self._region_lists[self.placement.region_of(page)] \
                    .append(page)
            else:
                self._free.append(page)
            return True
        self._refs[page] = rc - 1
        return False

    def free(self, pages: Sequence[int]) -> None:
        """Drop one reference per page (frees those reaching zero)."""
        for p in pages:
            self.decref(p)

    def rebuild(self, refcounts: Dict[int, int]) -> None:
        """Reset the allocator to an explicit live set (the public defrag
        API).

        ``refcounts`` maps live page id -> its refcount.  The free list is
        rebuilt in descending index order, so subsequent allocations hand
        out the lowest free indices first — the same LIFO invariant a
        freshly constructed allocator starts with.
        """
        for p, rc in refcounts.items():
            if not 0 <= p < self.num_pages:
                raise ValueError(f"page {p} out of range")
            if rc <= 0:
                raise ValueError(f"page {p} has non-positive refcount {rc}")
        self._refs = dict(refcounts)
        if self.placed:
            self._region_lists = {
                r: [p for p in sorted(self.placement.region_pages(r),
                                      reverse=True)
                    if p not in self._refs]
                for r in self.placement.regions()}
        else:
            self._free = [p for p in range(self.num_pages - 1, -1, -1)
                          if p not in self._refs]

    def reset(self) -> None:
        self._refs.clear()
        self._rr = 0
        self._init_free()


# ---------------------------------------------------------------------------
# Host-side prompt-prefix trie (page-granular)
# ---------------------------------------------------------------------------
class _TrieNode:
    __slots__ = ("children", "partial")

    def __init__(self):
        # full-page token chunk -> (page id, subtree)
        self.children: Dict[bytes, Tuple[int, "_TrieNode"]] = {}
        # trailing sub-page token chunk -> page id
        self.partial: Dict[bytes, int] = {}


def _chunk_key(tokens: np.ndarray) -> bytes:
    # canonical dtype so int32 prompts and int64 literals key identically
    return np.ascontiguousarray(tokens, dtype=np.int64).tobytes()


class PrefixIndex:
    """Page-granular prompt-prefix trie (host side).

    Each edge keys one full page of prompt tokens (raw token bytes — exact
    matching, no hash collisions) and carries the physical page holding
    that chunk's KV.  A node's ``partial`` table maps a trailing sub-page
    chunk to its page, which is what lets two requests with *identical*
    prompts share the ragged tail page — the case that exercises
    copy-on-write, since both holders decode-write into that page.

    Entries are registered only after the page contents have actually been
    written (``PagedCache`` commits at insert time, not at admission) and
    are dropped when the page's last reference is released, so a hit always
    points at live, fully materialized prompt KV.  CoW forks and decode
    growth pages are never registered: their contents diverge from the
    prompt.
    """

    def __init__(self):
        self.root = _TrieNode()
        # page -> (owning node, edge key, is_partial) for O(1) removal and
        # defrag renumbering
        self._by_page: Dict[int, Tuple[_TrieNode, bytes, bool]] = {}

    def __len__(self) -> int:
        return len(self._by_page)

    def match(self, tokens: np.ndarray, page_size: int) -> List[int]:
        """Longest shared prefix of ``tokens`` in whole pages, plus the
        ragged tail page when the remainder matches exactly."""
        node, pages = self.root, []
        k = len(tokens) // page_size
        for i in range(k):
            hit = node.children.get(
                _chunk_key(tokens[i * page_size:(i + 1) * page_size]))
            if hit is None:
                return pages
            pages.append(hit[0])
            node = hit[1]
        tail = tokens[k * page_size:]
        if len(tail):
            page = node.partial.get(_chunk_key(tail))
            if page is not None:
                pages.append(page)
        return pages

    def register(self, tokens: np.ndarray, pages: Sequence[int],
                 page_size: int) -> None:
        """Publish ``pages`` (page-chunked KV of ``tokens``) for reuse.

        First-writer-wins: chunks already present keep their existing page
        (the caller's duplicate copy simply stays private); chunks missing
        from the walk are inserted with the caller's page.
        """
        node = self.root
        k = len(tokens) // page_size
        for i in range(k):
            key = _chunk_key(tokens[i * page_size:(i + 1) * page_size])
            hit = node.children.get(key)
            if hit is None:
                child = _TrieNode()
                node.children[key] = (pages[i], child)
                self._by_page[pages[i]] = (node, key, False)
                node = child
            else:
                node = hit[1]
        tail = tokens[k * page_size:]
        if len(tail) and k < len(pages):
            key = _chunk_key(tail)
            if key not in node.partial:
                node.partial[key] = pages[k]
                self._by_page[pages[k]] = (node, key, True)

    def remove(self, page: int) -> None:
        """Forget a freed page.  Children of a removed full-page edge are
        unreachable afterwards, which is safe: any request mapping a child
        chunk also held a reference on this page, so the whole chain dies
        together."""
        info = self._by_page.pop(page, None)
        if info is None:
            return
        node, key, is_partial = info
        if is_partial:
            node.partial.pop(key, None)
        else:
            node.children.pop(key, None)

    def remap(self, mapping: Dict[int, int]) -> None:
        """Apply a defrag old->new page renumbering in place."""
        by_page = {}
        for old, (node, key, is_partial) in self._by_page.items():
            new = mapping.get(old, old)
            if is_partial:
                node.partial[key] = new
            else:
                node.children[key] = (new, node.children[key][1])
            by_page[new] = (node, key, is_partial)
        self._by_page = by_page


# ---------------------------------------------------------------------------
# Shape probing: which leaves page, and where
# ---------------------------------------------------------------------------
SEQ_AXIS = 2    # engine batch-axis rule: (L, B, S, ...) for seq leaves
BATCH_AXIS = 1


def probe_seq_leaves(entry, max_batch: int, tp: int = 1) -> List[bool]:
    """True per flattened cache leaf iff its shape depends on ``max_seq``."""
    sa = jax.eval_shape(lambda: entry.cache_zeros(max_batch, 16, tp))
    sb = jax.eval_shape(lambda: entry.cache_zeros(max_batch, 32, tp))
    la, _ = jax.tree.flatten(sa)
    lb, _ = jax.tree.flatten(sb)
    out = []
    for a, b in zip(la, lb):
        if a.shape == b.shape:
            out.append(False)
        else:
            diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                    if x != y]
            if diff != [SEQ_AXIS]:
                raise ValueError(
                    f"seq leaf with unsupported layout {a.shape} vs "
                    f"{b.shape}: expected the seq axis at {SEQ_AXIS}")
            out.append(True)
    return out


def num_blocks(n_tokens: int, page_size: int) -> int:
    return ceil_div(n_tokens, page_size)


# ---------------------------------------------------------------------------
# Cross-pool page shipment (prefill -> decode tier handoff, PR 10)
# ---------------------------------------------------------------------------
@dataclass
class PageShipment:
    """A slot's KV pages packaged for transfer between two pools.

    Built by :meth:`PagedCache.export_slot_pages` (which also releases
    the slot at the source) and consumed by
    :meth:`PagedCache.import_slot_pages`.  Carries everything the
    destination needs to reconstruct the slot bit-identically:

    * ``seq_payload`` — per cache leaf, the gathered page block
      ``(L, n_pages, page, ...)`` for sequence leaves (``None`` for
      dense leaves);
    * ``slot_payload`` — per cache leaf, the slot column for dense
      leaves (recurrent state, lengths; ``None`` for sequence leaves);
    * ``tokens`` — the prompt, so the destination can consult *its own*
      prefix trie (mapping already-resident pages instead of writing
      duplicates) and re-register the coverage after import;
    * ``cost_s`` / ``bytes_on_wire`` — the priced cross-stack movement
      (:func:`~repro.core.noc.page_ship`), charged once at export.

    The engine layer annotates ``req`` (the live request object),
    ``next_tok`` (the first decoded token, produced on the prefill
    tier) and ``src``/``dst`` replica ids for the ``ship`` trace event.
    """

    n_tokens: int
    page_size: int
    n_pages: int
    tokens: Optional[np.ndarray]
    seq_payload: List[Optional[jax.Array]]
    slot_payload: List[Optional[jax.Array]]
    bytes_on_wire: int = 0
    cost_s: float = 0.0
    # engine-layer annotations (router handoff)
    req: Any = None
    next_tok: int = -1
    src: int = -1
    dst: int = -1


#: legal ``kind`` values for :meth:`PagedCache.transfer_pages` — every
#: priced page movement in the repo is one of these.
TRANSFER_KINDS = ("migrate", "defrag", "ship")


# ---------------------------------------------------------------------------
# Device-side paged cache
# ---------------------------------------------------------------------------
@dataclass
class PagedCache:
    """Page pools + block tables for one engine instance.

    ``store`` is the cache pytree where every sequence leaf has been
    replaced by its pool ``(L, P+1, page, ...)``; non-sequence leaves keep
    their dense slot layout.  ``tables`` is host-resident; ``tables_dev``
    is refreshed lazily before any gather/scatter.

    ``max_seq`` is rounded up to a whole number of pages so the block
    tables tile the logical window exactly; callers that size buffers or
    occupancy math off ``max_seq`` must read it back after construction
    (the engine adopts the rounded value and asserts agreement in
    ``kv_report``).
    """
    entry: Any
    max_batch: int
    max_seq: int
    page_size: int
    num_pages: int
    tp: int = 1
    share: bool = False
    placement: Optional[PlacementMap] = None
    placement_policy: str = "free-first"

    def __post_init__(self):
        if self.page_size <= 0:
            raise ValueError(f"page_size must be positive, "
                             f"got {self.page_size}")
        if self.max_seq % self.page_size:
            # round the logical window up so tables tile it exactly
            self.max_seq = num_blocks(self.max_seq,
                                      self.page_size) * self.page_size
        self.max_blocks = self.max_seq // self.page_size
        self.alloc = PageAllocator(self.num_pages,
                                   placement=self.placement,
                                   policy=self.placement_policy)
        # per-slot home region (affinity placement); -1 = unassigned
        self.home_region: Dict[int, int] = {}
        self.tables = np.full((self.max_batch, self.max_blocks), -1,
                              np.int32)
        self._tables_dev = None
        dense = self.entry.cache_zeros(self.max_batch, self.page_size,
                                       self.tp)
        leaves, self.treedef = jax.tree.flatten(dense)
        self.is_seq = probe_seq_leaves(self.entry, self.max_batch, self.tp)
        store = []
        for leaf, seq in zip(leaves, self.is_seq):
            if seq:
                # (L, B, page, ...) -> (L, P+1, page, ...): drop the batch
                # axis, add the page axis (+1 scratch page at index P)
                shape = (leaf.shape[0], self.num_pages + 1,
                         self.page_size) + leaf.shape[3:]
                store.append(jnp.zeros(shape, leaf.dtype))
            else:
                store.append(leaf)   # dense slot layout, as allocated
        # non-seq leaves don't depend on max_seq, so the probe-sized
        # cache_zeros call above produced them at exactly the right shape
        self.store = store
        # recurrent families have no sequence leaves: their per-request
        # state is O(1) and lives slot-dense, so they consume no pages
        self.has_seq = any(self.is_seq)
        self.prefix = PrefixIndex() if self.share else None
        # leading table entries mapped onto shared pages at admission —
        # write_slot skips re-writing them (their KV is already resident)
        self.shared_count = np.zeros((self.max_batch,), np.int64)
        self._pending_prompt: Dict[int, np.ndarray] = {}
        self.cow_forks = 0
        # cross-region home migration (defrag's spilled-page repair pass)
        self.migrated_pages = 0
        self.migration_cost_s = 0.0
        # in-pool compaction moves (defrag) and cross-pool shipments
        # (tier handoff), both priced through transfer_pages
        self.defrag_move_cost_s = 0.0
        self.shipped_pages = 0
        self.ship_cost_s = 0.0
        self._bytes_per_page: Optional[int] = None
        # lifecycle-event sink; the engine rebinds this to its own
        # (replica-bound) tracer when one is attached
        self.tracer = NULL_TRACER

    # -- block-table bookkeeping -------------------------------------------
    def _invalidate(self):
        """Drop the device mirror entirely — only the bulk rewrites
        (defrag / migrate / reset) pay a full rebuild; the per-slot hot
        ops maintain the mirror incrementally via ``_mirror_row`` /
        ``_mirror_set``."""
        self._tables_dev = None

    def tables_device(self) -> jax.Array:
        if self._tables_dev is None:
            # unmapped entries -> scratch page P (safe for gather/scatter)
            t = np.where(self.tables < 0, self.num_pages, self.tables)
            self._tables_dev = jnp.asarray(t, jnp.int32)
        return self._tables_dev

    def _mirror_row(self, slot: int) -> None:
        """Refresh one slot's row of the device table mirror in place
        (alloc/extend/free touch a single row — rebuilding the whole
        ``(B, nblk)`` table per tick was the serving loop's biggest
        host->device transfer)."""
        if self._tables_dev is None:
            return
        row = np.where(self.tables[slot] < 0, self.num_pages,
                       self.tables[slot]).astype(np.int32)
        self._tables_dev = self._tables_dev.at[slot].set(jnp.asarray(row))

    def _mirror_set(self, slot: int, blk: int, page: int) -> None:
        """Point one mirror entry at a new physical page (CoW fork)."""
        if self._tables_dev is None:
            return
        self._tables_dev = self._tables_dev.at[slot, blk].set(page)

    def mirror_consistent(self) -> bool:
        """True iff the incrementally maintained device mirror equals a
        fresh rebuild of the host tables.  An unbuilt mirror (None) is
        trivially consistent.  The allocator-model checker drives a
        scripted op sequence through this after every mutation."""
        if self._tables_dev is None:
            return True
        ref = np.where(self.tables < 0, self.num_pages, self.tables)
        return bool(np.array_equal(np.asarray(self._tables_dev), ref))

    def blocks_of(self, slot: int) -> List[int]:
        return [int(p) for p in self.tables[slot] if p >= 0]

    def pages_in_use(self) -> int:
        return self.alloc.used_pages

    def kv_tokens_resident(self) -> int:
        """Capacity (in tokens) of all allocated pages."""
        return self.alloc.used_pages * self.page_size

    def logical_pages(self) -> int:
        """Block-table entries mapped across all slots (>= physical pages
        whenever prefix sharing deduplicates)."""
        return int((self.tables >= 0).sum())

    def fragmentation(self) -> float:
        """Fraction of holes below the high-water page index (0 = the live
        set is compact at the lowest indices).  With a placement map the
        high-water mark is per region — affinity deliberately spreads
        slots across regions, which is placement, not fragmentation."""
        used = self.alloc.used_pages
        if used == 0:
            return 0.0
        if self.placement is None:
            return 1.0 - used / (self.alloc.highest_used() + 1)
        pmap = self.placement
        high: Dict[int, int] = {}       # region -> high-water page
        for p in self.alloc.live_pages():
            r = pmap.region_of(p)
            high[r] = max(high.get(r, p), p)
        span = sum(hw - pmap.region_pages(r).start + 1
                   for r, hw in high.items())
        return 1.0 - used / span if span else 0.0

    def sharing_report(self) -> Dict[str, Any]:
        logical = self.logical_pages()
        physical = self.alloc.used_pages
        return {"logical_pages": logical,
                "physical_pages": physical,
                "shared_pages": self.alloc.shared_pages,
                "dedup_ratio": logical / physical if physical else 1.0,
                "cow_forks": self.cow_forks}

    def prefix_residency(self, tokens: Optional[np.ndarray]) -> int:
        """Leading prompt pages of ``tokens`` already resident in the
        prefix trie — the front-end router's prefix-affinity probe.
        Counts only what admission would actually map (full pages plus an
        exact-match ragged tail); 0 when sharing is off."""
        if (not self.share or not self.has_seq or tokens is None
                or not len(tokens)):
            return 0
        return len(self.prefix.match(np.asarray(tokens), self.page_size))

    def alloc_slot(self, slot: int, n_tokens: int,
                   tokens: Optional[np.ndarray] = None) -> bool:
        """Allocate pages to cover ``n_tokens`` for an empty slot.

        With ``share=True`` and the prompt ``tokens`` given, the leading
        pages whose token chunks are already resident are *mapped* onto
        the existing shared pages (incref) and only the unshared tail is
        allocated.  Publication of the new pages into the trie is deferred
        to ``write_slot``, so a prefix can never be matched before its KV
        has actually been written.  Atomic: on failure nothing is mapped,
        incref'd, or allocated.
        """
        if not self.has_seq:
            return True
        assert not self.blocks_of(slot), "slot already mapped"
        need = num_blocks(n_tokens, self.page_size)
        shared: List[int] = []
        if self.share and tokens is not None and len(tokens):
            shared = self.prefix.match(np.asarray(tokens), self.page_size)
        home = self._assign_home(slot)
        # full prompt pages are publishable as trie edges, so they go
        # communal (any future holder reads them — no slot channel is
        # favored); the ragged tail + decode pages are private -> home
        n_communal = 0
        if self.share and tokens is not None:
            n_communal = max(0, len(tokens) // self.page_size
                             - len(shared))
        fresh = self.alloc.alloc(need - len(shared), home=home,
                                 communal=n_communal)
        if fresh is None:
            return False
        for p in shared:
            self.alloc.incref(p)
        pages = shared + fresh
        self.tables[slot, : len(pages)] = pages
        self.shared_count[slot] = len(shared)
        if self.share and tokens is not None:
            self._pending_prompt[slot] = np.asarray(tokens).copy()
        self._mirror_row(slot)
        return True

    def _assign_home(self, slot: int) -> Optional[int]:
        """Pick (and remember) the slot's home region: the slot region
        with the most free pages at admission, deterministic ties to the
        lowest id.  None without active placement."""
        if not self.alloc.placed:
            return None
        free = self.alloc.region_free()
        home = min((r for r in free if r != COMMUNAL),
                   key=lambda r: (-free[r], r))
        self.home_region[slot] = home
        return home

    def extend_slot(self, slot: int, n_tokens: int) -> bool:
        """Grow a slot's mapping to cover ``n_tokens`` total (on-demand
        decode growth).  Growth pages are private to the slot, so they
        prefer its home region.  No-op if already covered."""
        if not self.has_seq:
            return True
        have = len(self.blocks_of(slot))
        need = num_blocks(n_tokens, self.page_size)
        if need <= have:
            return True
        if need > self.max_blocks:
            return False
        pages = self.alloc.alloc(need - have,
                                 home=self.home_region.get(slot))
        if pages is None:
            return False
        self.tables[slot, have:need] = pages
        self._mirror_row(slot)
        return True

    def free_slot(self, slot: int) -> None:
        for p in self.blocks_of(slot):
            if self.alloc.decref(p) and self.prefix is not None:
                self.prefix.remove(p)
        self.tables[slot, :] = -1
        self.shared_count[slot] = 0
        self._pending_prompt.pop(slot, None)
        self.home_region.pop(slot, None)
        self._mirror_row(slot)

    def reset(self) -> None:
        self.alloc.reset()
        self.tables[:, :] = -1
        self.shared_count[:] = 0
        self._pending_prompt.clear()
        self.home_region.clear()
        if self.share:
            self.prefix = PrefixIndex()
        self.cow_forks = 0
        self.migrated_pages = 0
        self.migration_cost_s = 0.0
        self.defrag_move_cost_s = 0.0
        self.shipped_pages = 0
        self.ship_cost_s = 0.0
        self._invalidate()

    # -- copy-on-write -----------------------------------------------------
    def cow_for_write(self, slot: int, pos: int) -> bool:
        """Ensure the page a write at ``pos`` will hit is exclusively owned.

        Called by the engine before every decode scatter.  Forks (copies)
        the page when its refcount is > 1; returns False only when the fork
        could not allocate a page — the caller preempts a victim and
        retries.  No-op for unmapped / out-of-window targets (those land in
        the scratch page) and for already-exclusive pages.
        """
        if not self.has_seq:
            return True
        blk = pos // self.page_size
        if blk >= self.max_blocks:
            return True
        page = int(self.tables[slot, blk])
        if page < 0 or self.alloc.refcount(page) <= 1:
            return True
        return self.fork_page(slot, blk)

    def fork_page(self, slot: int, blk: int) -> bool:
        """Copy-on-write fork: give ``slot`` a private copy of the page at
        table entry ``blk``.  The original page (and its trie entry) stays
        in place for the remaining holders."""
        old = int(self.tables[slot, blk])
        assert old >= 0, "fork of unmapped table entry"
        # the fork is a private copy: it belongs in the slot's home region
        got = self.alloc.alloc(1, home=self.home_region.get(slot))
        if got is None:
            return False
        new = got[0]
        self.store = [
            _copy_page(pool, old, new) if seq else pool
            for pool, seq in zip(self.store, self.is_seq)]
        self.tables[slot, blk] = new
        if blk < self.shared_count[slot]:
            self.shared_count[slot] = blk
        if self.alloc.decref(old) and self.prefix is not None:
            # last holder raced away (defensive: cow_for_write only forks
            # at refcount > 1, so this should not trigger)
            self.prefix.remove(old)
        self.cow_forks += 1
        if self.tracer.enabled:
            self.tracer.emit("cow_fork", slot=slot,
                             blk=blk, old_page=old, new_page=new)
        self._mirror_set(slot, blk, new)
        return True

    # -- device ops --------------------------------------------------------
    def gather(self) -> Any:
        """Assemble the dense ``(L, B, max_seq, ...)`` cache view.

        The reference decode path runs the ordinary ``decode_step`` on this
        view (token-exact vs. the dense engine); the Pallas paged path
        skips this and reads pages through the block table instead.
        """
        tables = self.tables_device()
        out = []
        for leaf, seq in zip(self.store, self.is_seq):
            if seq:
                g = _gather_pool(leaf, tables)
                out.append(g)
            else:
                out.append(leaf)
        return jax.tree.unflatten(self.treedef, out)

    def scatter_token(self, cache: Any, positions: np.ndarray,
                      active: np.ndarray) -> None:
        """Write back one decode step.

        ``cache`` is the updated dense view returned by ``decode_step``;
        the single new token per slot was written at ``positions[b]``
        (the pre-step length).  Sequence leaves scatter just that token
        into their pools; non-sequence leaves (recurrent state, lengths)
        are replaced wholesale.  ``active`` masks slots whose write should
        land in the scratch page, as do writes past a slot's mapped
        window.  With sharing enabled the caller must have run
        ``cow_for_write`` for every active slot first, so no write here
        ever lands on a page with refcount > 1.
        """
        tables = self.tables_device()
        pos = jnp.asarray(np.where(active, positions, 0), jnp.int32)
        act = jnp.asarray(active)
        leaves, _ = jax.tree.flatten(cache)
        new_store = []
        for pool, leaf, seq in zip(self.store, leaves, self.is_seq):
            if seq:
                new_store.append(
                    _scatter_token_jit(pool, leaf, tables, pos, act,
                                       self.page_size))
            else:
                new_store.append(leaf)
        self.store = new_store

    def write_slot(self, slot: int, cache1: Any, n_tokens: int) -> None:
        """Insert a freshly prefilled request (batch-1 cache) into ``slot``.

        Sequence leaves are chopped into pages and scattered to the slot's
        block table — pages mapped from the shared-prefix trie are skipped
        (their KV is already resident and other holders may be reading
        them); non-sequence leaves use the dense ``_insert_slot`` rule
        (rank-1 -> axis 0, else axis 1).  The slot's freshly written pages
        are then published to the trie.
        """
        pages = self.blocks_of(slot)
        need = num_blocks(n_tokens, self.page_size)
        skip = int(self.shared_count[slot])
        if self.has_seq:
            assert len(pages) >= need, \
                "write_slot without enough pages mapped"
        idx = jnp.asarray(pages[skip:need], jnp.int32)
        leaves, _ = jax.tree.flatten(cache1)
        new_store = []
        for pool, leaf, seq in zip(self.store, leaves, self.is_seq):
            if seq:
                if skip < need:
                    pool = _write_pages(pool, leaf, idx, skip, need,
                                        self.page_size)
                new_store.append(pool)
            else:
                if leaf.ndim == 1:
                    new_store.append(pool.at[slot].set(leaf[0]))
                else:
                    new_store.append(pool.at[:, slot].set(leaf[:, 0]))
        self.store = new_store
        self.commit_prefix(slot)

    # -- direct chunked prefill (no dense staging buffer) ------------------
    def gather_slot(self, slot: int, pos: int) -> Any:
        """Assemble a batch-1 dense cache view of one slot's block-table
        window for an ``extend_step`` chunk at offset ``pos``.

        Sequence leaves gather the slot's full page window (unmapped
        entries read the scratch page — the causal mask blanks everything
        past ``pos`` + chunk anyway); non-sequence leaves slice the slot
        column, except the rank-1 lengths leaf which is pinned to ``pos``
        (the slot-dense copy is stale until the first chunk commits).
        """
        row = np.where(self.tables[slot] < 0, self.num_pages,
                       self.tables[slot])
        t_dev = jnp.asarray(row[None, :], jnp.int32)
        out = []
        for leaf, seq in zip(self.store, self.is_seq):
            if seq:
                out.append(_gather_pool(leaf, t_dev))
            elif leaf.ndim == 1:
                out.append(jnp.full((1,), pos, leaf.dtype))
            else:
                out.append(leaf[:, slot: slot + 1])
        return jax.tree.unflatten(self.treedef, out)

    def scatter_chunk(self, slot: int, cache1: Any, pos: int,
                      take: int) -> None:
        """Write one prefill chunk (``take`` tokens at offset ``pos``)
        from the batch-1 view returned by ``extend_step`` straight into
        the slot's block-table pages.

        This is what lets the paged engine's chunk scheduler skip the
        dense per-request staging buffer (and the admission-time
        ``write_slot`` copy) entirely.  Positions inside shared-prefix
        pages are routed to the scratch page: their KV is already
        resident and other holders may be reading it — re-writing would
        perturb it with this request's (numerically different) recompute.
        Non-sequence leaves (lengths, recurrent state) are written to the
        slot column wholesale each chunk.
        """
        ps = self.page_size
        idx = np.arange(pos, pos + take)
        blk = idx // ps
        row = self.tables[slot]
        assert (row[blk] >= 0).all(), "scatter_chunk into unmapped pages"
        pages = np.where(blk < int(self.shared_count[slot]),
                         self.num_pages, row[blk])
        pages_dev = jnp.asarray(pages, jnp.int32)
        offs_dev = jnp.asarray(idx % ps, jnp.int32)
        leaves, _ = jax.tree.flatten(cache1)
        new_store = []
        for pool, leaf, seq in zip(self.store, leaves, self.is_seq):
            if seq:
                new_store.append(_scatter_chunk_jit(pool, leaf, pages_dev,
                                                    offs_dev, pos))
            elif leaf.ndim == 1:
                new_store.append(pool.at[slot].set(leaf[0]))
            else:
                new_store.append(pool.at[:, slot].set(leaf[:, 0]))
        self.store = new_store

    def commit_prefix(self, slot: int) -> None:
        """Publish the slot's prompt pages now that their KV is written."""
        tokens = self._pending_prompt.pop(slot, None)
        if tokens is None or self.prefix is None:
            return
        covered = num_blocks(len(tokens), self.page_size)
        self.prefix.register(tokens, self.blocks_of(slot)[:covered],
                             self.page_size)

    # -- priced page movement (the one code path) --------------------------
    def transfer_pages(self, n_pages: int, *, sys=None, hops: int = 0,
                       kind: str = "migrate") -> CollectiveCost:
        """Price (and account) the movement of ``n_pages`` physical pages.

        Every page movement in the cache goes through here, costed by
        :func:`~repro.core.noc.page_ship`: spilled-page re-homing
        (``kind="migrate"``, ``hops=0`` — intra-stack, exactly the
        legacy ``page_gather`` number), defrag compaction moves
        (``kind="defrag"``, ``hops=0``), and cross-stack tier shipments
        (``kind="ship"``, ``hops>=1`` — adds the inter-stack link and
        destination-scatter terms).  Accumulates the matching counters
        (``migrated_pages``/``migration_cost_s``,
        ``defrag_move_cost_s``, ``shipped_pages``/``ship_cost_s``) and
        emits the ``migrate`` lifecycle event; ``defrag``/``ship``
        events are emitted by their callers, which own the span
        context (moved counts, src/dst replicas)."""
        if kind not in TRANSFER_KINDS:
            raise ValueError(f"unknown transfer kind {kind!r}; "
                             f"choose from {TRANSFER_KINDS}")
        if n_pages <= 0:
            return CollectiveCost(0, 0.0)
        cost = page_ship(sys if sys is not None else default_system(),
                         n_pages * self.bytes_per_page(), n_pages,
                         hops=hops)
        if kind == "migrate":
            self.migrated_pages += n_pages
            self.migration_cost_s += cost.time_s
            if self.tracer.enabled:
                self.tracer.emit("migrate", pages=n_pages,
                                 cost_s=cost.time_s)
        elif kind == "defrag":
            self.defrag_move_cost_s += cost.time_s
        else:
            self.shipped_pages += n_pages
            self.ship_cost_s += cost.time_s
        return cost

    def migrate_spilled(self, sys=None) -> int:
        """Move exclusively-owned pages that spilled out of their slot's
        home region back home (placed mode only).

        Under pressure ``alloc`` deliberately spills to a foreign region
        rather than fail admission — but once the pool relaxes the slot
        keeps paying the cross-region gather tax on every decode step,
        forever.  This pass repairs that: each spilled page whose home
        region has free capacity again is physically copied home through
        the NoC, priced through :meth:`transfer_pages` (``hops=0`` —
        the intra-stack :func:`~repro.core.noc.page_ship` degradation)
        and accumulated into ``migrated_pages`` / ``migration_cost_s``.

        Shared pages stay put — refcount > 1 means holders with
        different homes read them — and trie-registered pages are
        communal by design.  Returns the number of pages moved.
        """
        if not (self.has_seq and self.alloc.placed):
            return 0
        moved = 0
        for slot, home in sorted(self.home_region.items()):
            for blk in range(self.max_blocks):
                page = int(self.tables[slot, blk])
                if (page < 0 or self.alloc.refcount(page) != 1
                        or self.placement.region_of(page) == home):
                    continue
                if self.prefix is not None \
                        and page in self.prefix._by_page:
                    continue
                got = self.alloc.alloc_in(home, 1)
                if got is None:
                    break                    # home is full again
                new = got[0]
                self.store = [
                    _copy_page(pool, page, new) if seq else pool
                    for pool, seq in zip(self.store, self.is_seq)]
                self.tables[slot, blk] = new
                self.alloc.decref(page)
                moved += 1
        if moved:
            self.transfer_pages(moved, sys=sys, hops=0, kind="migrate")
            self._invalidate()
        return moved

    def defrag(self, sys=None) -> Dict[int, int]:
        """Compact live pages to the lowest indices.

        Returns the old->new mapping applied.  Pool data is permuted on
        device; block tables, the prefix trie, and the allocator (via its
        public ``rebuild``, refcounts preserved) are renumbered so the
        logical contents (``gather()``) are unchanged.

        With a placement map, compaction is **region-preserving**: each
        region's live pages compact to that region's lowest indices and
        never migrate across regions (a cross-region move would be a
        physical DMA copy through the NoC — exactly the traffic placement
        exists to avoid).  The one exception is deliberate and priced:
        under an active placement policy a :meth:`migrate_spilled` repair
        pass runs first, copying exclusively-owned spilled pages back to
        their slot's home region through the NoC (charged via
        ``page_gather``) so a slot squeezed during a pressure spike is
        not fragmented across regions forever.  The prefix trie is
        renumbered through the same constrained mapping, so a trie hit
        after defrag still points at a live page in the original channel
        region; both invariants are asserted below.
        """
        if self.alloc.placed:
            self.migrate_spilled(sys)
        live = self.alloc.live_pages()
        if self.placement is None:
            mapping = {old: new for new, old in enumerate(live)}
        else:
            mapping = {}
            for r in self.placement.regions():
                live_r = [p for p in live
                          if self.placement.region_of(p) == r]
                for p, tgt in zip(live_r, self.placement.region_pages(r)):
                    mapping[p] = tgt
            assert all(self.placement.region_of(o)
                       == self.placement.region_of(n)
                       for o, n in mapping.items()), \
                "defrag target crossed a placement region"
        if all(o == n for o, n in mapping.items()):
            return mapping
        perm = np.arange(self.num_pages + 1)
        for old, new in mapping.items():
            perm[new] = old
        perm_dev = jnp.asarray(perm, jnp.int32)
        self.store = [
            _permute_pool(pool, perm_dev) if seq else pool
            for pool, seq in zip(self.store, self.is_seq)]
        lut = np.full(self.num_pages + 1, -1, np.int32)
        for old, new in mapping.items():
            lut[old] = new
        self.tables = np.where(self.tables < 0, -1,
                               lut[np.maximum(self.tables, 0)]
                               ).astype(np.int32)
        self.alloc.rebuild({mapping[p]: self.alloc.refcount(p)
                            for p in live})
        moved_n = sum(1 for o, n in mapping.items() if o != n)
        cost = self.transfer_pages(moved_n, sys=sys, hops=0,
                                   kind="defrag")
        if self.tracer.enabled:
            self.tracer.emit("defrag", live_pages=len(live),
                             moved=moved_n, cost_s=cost.time_s)
        if self.prefix is not None:
            self.prefix.remap(mapping)
            # region-constrained targets must keep the trie consistent:
            # every registered page is still allocated after renumbering
            assert all(self.alloc.refcount(p) > 0
                       for p in self.prefix._by_page), \
                "defrag left the prefix trie pointing at a dead page"
        self._invalidate()
        return mapping

    # -- cross-pool shipment (prefill -> decode tier, PR 10) ---------------
    def export_slot_pages(self, slot: int, n_tokens: int,
                          tokens: Optional[np.ndarray] = None, *,
                          sys=None, hops: int = 1) -> PageShipment:
        """Package ``slot``'s resident state for another pool and
        release the slot here.

        Sequence leaves gather the slot's first ``ceil(n_tokens /
        page)`` pages into a contiguous ``(L, n, page, ...)`` block
        (shared-prefix pages included — the destination decides what it
        can dedup against its own trie); dense leaves copy the slot
        column.  The movement is priced once, here, through
        :meth:`transfer_pages` (``kind="ship"``): the source pays the
        gather + ``hops`` inter-stack link crossings + the destination
        scatter.  The slot is then freed exactly as a finished request
        would be — shared pages survive under their remaining holders'
        references, and trie entries drop only with their last holder.
        """
        pages = self.blocks_of(slot)
        if self.has_seq:
            need = num_blocks(n_tokens, self.page_size)
            assert len(pages) >= need, \
                "export_slot_pages of an under-mapped slot"
            pages = pages[:need]
        seq_payload: List[Optional[jax.Array]] = []
        slot_payload: List[Optional[jax.Array]] = []
        idx = jnp.asarray(pages, jnp.int32)
        for pool, seq in zip(self.store, self.is_seq):
            if seq:
                seq_payload.append(pool[:, idx] if pages else None)
                slot_payload.append(None)
            else:
                seq_payload.append(None)
                slot_payload.append(pool[slot] if pool.ndim == 1
                                    else pool[:, slot])
        cost = self.transfer_pages(len(pages), sys=sys, hops=hops,
                                   kind="ship")
        shipment = PageShipment(
            n_tokens=n_tokens, page_size=self.page_size,
            n_pages=len(pages),
            tokens=(np.asarray(tokens).copy()
                    if tokens is not None else None),
            seq_payload=seq_payload, slot_payload=slot_payload,
            bytes_on_wire=cost.bytes_on_wire, cost_s=cost.time_s)
        self.free_slot(slot)
        return shipment

    def import_slot_pages(self, slot: int,
                          shipment: PageShipment) -> bool:
        """Splice a :class:`PageShipment` into an empty ``slot`` here.

        Refcount/region reconciliation: the destination's *own* prefix
        trie is consulted first — leading prompt pages already resident
        are mapped (incref) instead of re-written, exactly as a local
        admission would dedup; only the unshared tail pages allocate
        (home-region assignment + communal steering for publishable
        full prompt pages) and receive the shipped payload.  The
        imported coverage is then registered in the destination trie so
        later arrivals dedup against it.  Atomic: returns ``False``
        with nothing mapped, incref'd, or written when the pool cannot
        hold the unshared pages — the caller retries or re-targets.
        """
        if shipment.page_size != self.page_size:
            raise ValueError(
                f"shipment page_size {shipment.page_size} != pool "
                f"page_size {self.page_size} (tiers must agree)")
        if not self.has_seq:
            self._import_dense(slot, shipment)
            return True
        assert not self.blocks_of(slot), "import into a mapped slot"
        need = shipment.n_pages
        tokens = shipment.tokens
        shared: List[int] = []
        if self.share and tokens is not None and len(tokens):
            shared = self.prefix.match(np.asarray(tokens),
                                       self.page_size)[:need]
        home = self._assign_home(slot)
        n_communal = 0
        if self.share and tokens is not None:
            n_communal = max(0, len(tokens) // self.page_size
                             - len(shared))
        fresh = self.alloc.alloc(need - len(shared), home=home,
                                 communal=n_communal)
        if fresh is None:
            self.home_region.pop(slot, None)
            return False
        for p in shared:
            self.alloc.incref(p)
        pages = shared + fresh
        self.tables[slot, :need] = pages
        self.shared_count[slot] = len(shared)
        dst_idx = jnp.asarray(fresh, jnp.int32)
        src_idx = jnp.asarray(np.arange(len(shared), need), jnp.int32)
        new_store = []
        for pool, seq, payload, col in zip(self.store, self.is_seq,
                                           shipment.seq_payload,
                                           shipment.slot_payload):
            if seq:
                if fresh:
                    pool = pool.at[:, dst_idx].set(payload[:, src_idx])
                new_store.append(pool)
            elif pool.ndim == 1:
                new_store.append(pool.at[slot].set(col))
            else:
                new_store.append(pool.at[:, slot].set(col))
        self.store = new_store
        if self.share and tokens is not None:
            self._pending_prompt[slot] = np.asarray(tokens).copy()
            self.commit_prefix(slot)
        self._mirror_row(slot)
        return True

    def _import_dense(self, slot: int, shipment: PageShipment) -> None:
        """Recurrent families: no pages — just restore the slot column."""
        new_store = []
        for pool, col in zip(self.store, shipment.slot_payload):
            if pool.ndim == 1:
                new_store.append(pool.at[slot].set(col))
            else:
                new_store.append(pool.at[:, slot].set(col))
        self.store = new_store

    # -- placement scoring -------------------------------------------------
    def bytes_per_page(self) -> int:
        """Bytes one physical page holds across all paged leaves/layers
        (the per-page gather payload).  Pool shapes are fixed at
        construction, so the first computation is cached."""
        if self._bytes_per_page is None:
            self._bytes_per_page = sum(
                int(np.prod([d for i, d in enumerate(pool.shape)
                             if i != 1])) * pool.dtype.itemsize
                for pool, seq in zip(self.store, self.is_seq) if seq)
        return self._bytes_per_page

    def slot_region_counts(self, slot: int) -> Dict[int, int]:
        """Region histogram of the slot's mapped pages (requires a
        placement map)."""
        assert self.placement is not None
        counts: Dict[int, int] = {}
        for p in self.blocks_of(slot):
            r = self.placement.region_of(p)
            counts[r] = counts.get(r, 0) + 1
        return counts

    def gather_cost_slot(self, sys, slot: int) -> Optional[GatherCost]:
        """DMA/NoC cost of this slot's block-table gather on ``sys``
        (None when the slot has no pages mapped).  Scored from the
        majority region for every policy — the scheduling half of the
        co-design issues the gather from the PU already holding most of
        the table."""
        if self.placement is None or not self.blocks_of(slot):
            return None
        counts = self.slot_region_counts(slot)
        return gather_cost(sys, counts, self.bytes_per_page())

    def gather_cost_mean(self, sys, slots: Optional[Sequence[int]] = None
                         ) -> Tuple[float, float]:
        """Mean (gather time, home-channel concentration) over the given
        slots (default: every slot with pages mapped)."""
        if slots is None:
            slots = [s for s in range(self.max_batch) if self.blocks_of(s)]
        costs = [c for c in (self.gather_cost_slot(sys, s) for s in slots)
                 if c is not None]
        if not costs:
            return 0.0, 1.0
        return (float(np.mean([c.time_s for c in costs])),
                float(np.mean([c.concentration for c in costs])))

    def placement_report(self) -> PlacementReport:
        """Per-region pressure snapshot, typed (PR 10).

        Returns an *empty* :class:`~repro.serving.replica_api.
        PlacementReport` without a placement map; ``to_dict()`` at the
        JSON/metrics boundary reproduces the legacy dict (``{}`` when
        empty) key-for-key."""
        if self.placement is None:
            return PlacementReport()
        used = self.alloc.region_used()
        free = self.alloc.region_free()
        return PlacementReport(
            placement_policy=self.placement_policy,
            n_regions=self.placement.n_regions,
            communal_pages=self.placement.communal_pages,
            region_used={str(r): used[r] for r in used},
            region_free={str(r): free[r] for r in free})


# ---------------------------------------------------------------------------
# jitted pool primitives (shapes static per engine instance)
# ---------------------------------------------------------------------------
@jax.jit
def _gather_pool(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """pool (L, P+1, ps, ...) + tables (B, nblk) -> (L, B, nblk*ps, ...)."""
    g = pool[:, tables]                      # (L, B, nblk, ps, ...)
    l, b, nblk, ps = g.shape[:4]
    return g.reshape((l, b, nblk * ps) + g.shape[4:])


@jax.jit
def _permute_pool(pool: jax.Array, perm: jax.Array) -> jax.Array:
    return pool[:, perm]


@jax.jit
def _copy_page(pool: jax.Array, src, dst) -> jax.Array:
    return pool.at[:, dst].set(pool[:, src])


@functools.partial(jax.jit, static_argnums=(3, 4))
def _write_pages_impl(pool, leaf, idx, skip, page_size):
    # leaf (L, 1, S, ...) with S >= (skip+n)*ps; chop the unshared span
    # into (L, n, ps, ...) and scatter it at idx
    l = leaf.shape[0]
    n = idx.shape[0]
    chunk = leaf[:, 0, skip * page_size:(skip + n) * page_size]
    chunk = chunk.reshape((l, n, page_size) + leaf.shape[3:])
    return pool.at[:, idx].set(chunk)


def _write_pages(pool, leaf, idx, skip, need, page_size):
    s = leaf.shape[SEQ_AXIS]
    if s < need * page_size:                 # pad ragged tail to page edge
        pad = [(0, 0)] * leaf.ndim
        pad[SEQ_AXIS] = (0, need * page_size - s)
        leaf = jnp.pad(leaf, pad)
    return _write_pages_impl(pool, leaf, idx, skip, page_size)


@jax.jit
def _scatter_chunk_jit(pool, leaf, pages, offs, start):
    """Scatter ``take`` consecutive tokens (``leaf[:, 0, start:start+take]``)
    into ``(pages[j], offs[j])`` pool positions.  ``pages`` already routes
    shared-prefix positions to the scratch page."""
    take = pages.shape[0]
    vals = jax.lax.dynamic_slice_in_dim(leaf[:, 0], start, take, axis=1)
    return pool.at[:, pages, offs].set(vals)


@jax.jit
def _scatter_token_jit(pool, leaf, tables, pos, active, page_size):
    """Scatter leaf[:, b, pos[b]] into pool at the page holding pos[b].

    A write whose position falls outside the slot's mapped window (block
    index past the table) is routed to the scratch page together with
    inactive slots — clipping ``blk`` alone used to alias such writes onto
    the window's *last live page*, corrupting resident KV.
    """
    b = leaf.shape[BATCH_AXIS]
    blk = pos // page_size                   # (B,)
    off = pos % page_size
    nblk = tables.shape[1]
    in_window = blk < nblk
    blk = jnp.clip(blk, 0, nblk - 1)
    page = jnp.take_along_axis(tables, blk[:, None], axis=1)[:, 0]
    trash = pool.shape[1] - 1                # scratch page index P
    page = jnp.where(active & in_window, page, trash)
    pos = jnp.clip(pos, 0, leaf.shape[SEQ_AXIS] - 1)
    val = jnp.take_along_axis(
        leaf, pos.reshape((1, b) + (1,) * (leaf.ndim - 2)),
        axis=SEQ_AXIS)                       # (L, B, 1, ...)
    val = jnp.squeeze(val, axis=SEQ_AXIS)    # (L, B, ...)
    return pool.at[:, page, off].set(val)
