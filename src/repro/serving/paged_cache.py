"""Paged (block-table) KV/state cache for the serving engine.

vLLM/L3-style paged residency: instead of reserving a dense
``max_batch x max_seq`` cache, sequence-bearing cache leaves live in a pool
of fixed-size pages and each slot owns a block table mapping its logical
context positions to pages.  KV memory held by a request is then
proportional to its actual context length, which is what lets the engine
admit long-context / skewed-length traffic without reserving for the worst
case.

Generic across all four registry state families via shape probing: we
``eval_shape`` the family's ``cache_zeros`` at two different ``max_seq``
values — leaves whose shape changes are *sequence leaves* and get paged
(KVCache.k/v, EncDecCache.self_k/self_v); everything else (RWKV/RG
recurrent state, cross-attention caches, ``lengths``) is O(1) per request
and stays slot-dense.  For the recurrent families there are no sequence
leaves at all and the paged cache degenerates to the dense layout, which is
already proportional.

Layout: a sequence leaf ``(L, B, S, ...)`` (batch axis 1, seq axis 2 per
the engine's batch-axis rule) becomes a pool ``(L, P+1, page, ...)``; page
index ``P`` is a scratch/trash page so masked scatters and gathers of
unmapped table entries (-1) never touch live data.  Block tables are a host
``(max_batch, max_blocks)`` int32 array mirrored to device on change.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gemm import ceil_div


# ---------------------------------------------------------------------------
# Host-side block allocator
# ---------------------------------------------------------------------------
class PageAllocator:
    """Free-list page allocator (host side, O(1) alloc/free).

    Pages are plain ints ``0..num_pages-1``.  ``alloc`` returns ``None``
    (allocating nothing) when the request cannot be satisfied — admission
    control, not an error.
    """

    def __init__(self, num_pages: int):
        if num_pages <= 0:
            raise ValueError("num_pages must be positive")
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._used: set = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._used)

    def alloc(self, n: int) -> Optional[List[int]]:
        if n < 0:
            raise ValueError("alloc size must be >= 0")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._used.update(pages)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p not in self._used:
                raise ValueError(f"double free / foreign page {p}")
            self._used.remove(p)
            self._free.append(p)

    def reset(self) -> None:
        self._free = list(range(self.num_pages - 1, -1, -1))
        self._used.clear()


# ---------------------------------------------------------------------------
# Shape probing: which leaves page, and where
# ---------------------------------------------------------------------------
SEQ_AXIS = 2    # engine batch-axis rule: (L, B, S, ...) for seq leaves
BATCH_AXIS = 1


def probe_seq_leaves(entry, max_batch: int, tp: int = 1) -> List[bool]:
    """True per flattened cache leaf iff its shape depends on ``max_seq``."""
    sa = jax.eval_shape(lambda: entry.cache_zeros(max_batch, 16, tp))
    sb = jax.eval_shape(lambda: entry.cache_zeros(max_batch, 32, tp))
    la, _ = jax.tree.flatten(sa)
    lb, _ = jax.tree.flatten(sb)
    out = []
    for a, b in zip(la, lb):
        if a.shape == b.shape:
            out.append(False)
        else:
            diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                    if x != y]
            if diff != [SEQ_AXIS]:
                raise ValueError(
                    f"seq leaf with unsupported layout {a.shape} vs "
                    f"{b.shape}: expected the seq axis at {SEQ_AXIS}")
            out.append(True)
    return out


def num_blocks(n_tokens: int, page_size: int) -> int:
    return ceil_div(n_tokens, page_size)


# ---------------------------------------------------------------------------
# Device-side paged cache
# ---------------------------------------------------------------------------
@dataclass
class PagedCache:
    """Page pools + block tables for one engine instance.

    ``store`` is the cache pytree where every sequence leaf has been
    replaced by its pool ``(L, P+1, page, ...)``; non-sequence leaves keep
    their dense slot layout.  ``tables`` is host-resident; ``tables_dev``
    is refreshed lazily before any gather/scatter.
    """
    entry: Any
    max_batch: int
    max_seq: int
    page_size: int
    num_pages: int
    tp: int = 1

    def __post_init__(self):
        if self.page_size <= 0:
            raise ValueError(f"page_size must be positive, "
                             f"got {self.page_size}")
        if self.max_seq % self.page_size:
            # round the logical window up so tables tile it exactly
            self.max_seq = num_blocks(self.max_seq,
                                      self.page_size) * self.page_size
        self.max_blocks = self.max_seq // self.page_size
        self.alloc = PageAllocator(self.num_pages)
        self.tables = np.full((self.max_batch, self.max_blocks), -1,
                              np.int32)
        self._tables_dev = None
        dense = self.entry.cache_zeros(self.max_batch, self.page_size,
                                       self.tp)
        leaves, self.treedef = jax.tree.flatten(dense)
        self.is_seq = probe_seq_leaves(self.entry, self.max_batch, self.tp)
        store = []
        for leaf, seq in zip(leaves, self.is_seq):
            if seq:
                # (L, B, page, ...) -> (L, P+1, page, ...): drop the batch
                # axis, add the page axis (+1 scratch page at index P)
                shape = (leaf.shape[0], self.num_pages + 1,
                         self.page_size) + leaf.shape[3:]
                store.append(jnp.zeros(shape, leaf.dtype))
            else:
                store.append(leaf)   # dense slot layout, as allocated
        # non-seq leaves don't depend on max_seq, so the probe-sized
        # cache_zeros call above produced them at exactly the right shape
        self.store = store
        # recurrent families have no sequence leaves: their per-request
        # state is O(1) and lives slot-dense, so they consume no pages
        self.has_seq = any(self.is_seq)

    # -- block-table bookkeeping -------------------------------------------
    def _invalidate(self):
        self._tables_dev = None

    def tables_device(self) -> jax.Array:
        if self._tables_dev is None:
            # unmapped entries -> scratch page P (safe for gather/scatter)
            t = np.where(self.tables < 0, self.num_pages, self.tables)
            self._tables_dev = jnp.asarray(t, jnp.int32)
        return self._tables_dev

    def blocks_of(self, slot: int) -> List[int]:
        return [int(p) for p in self.tables[slot] if p >= 0]

    def pages_in_use(self) -> int:
        return self.alloc.used_pages

    def kv_tokens_resident(self) -> int:
        """Capacity (in tokens) of all allocated pages."""
        return self.alloc.used_pages * self.page_size

    def alloc_slot(self, slot: int, n_tokens: int) -> bool:
        """Allocate pages to cover ``n_tokens`` for an empty slot."""
        if not self.has_seq:
            return True
        assert not self.blocks_of(slot), "slot already mapped"
        pages = self.alloc.alloc(num_blocks(n_tokens, self.page_size))
        if pages is None:
            return False
        self.tables[slot, : len(pages)] = pages
        self._invalidate()
        return True

    def extend_slot(self, slot: int, n_tokens: int) -> bool:
        """Grow a slot's mapping to cover ``n_tokens`` total (on-demand
        decode growth).  No-op if already covered."""
        if not self.has_seq:
            return True
        have = len(self.blocks_of(slot))
        need = num_blocks(n_tokens, self.page_size)
        if need <= have:
            return True
        if need > self.max_blocks:
            return False
        pages = self.alloc.alloc(need - have)
        if pages is None:
            return False
        self.tables[slot, have:need] = pages
        self._invalidate()
        return True

    def free_slot(self, slot: int) -> None:
        pages = self.blocks_of(slot)
        if pages:
            self.alloc.free(pages)
        self.tables[slot, :] = -1
        self._invalidate()

    def reset(self) -> None:
        self.alloc.reset()
        self.tables[:, :] = -1
        self._invalidate()

    # -- device ops --------------------------------------------------------
    def gather(self) -> Any:
        """Assemble the dense ``(L, B, max_seq, ...)`` cache view.

        The reference decode path runs the ordinary ``decode_step`` on this
        view (token-exact vs. the dense engine); the Pallas paged path
        skips this and reads pages through the block table instead.
        """
        tables = self.tables_device()
        out = []
        for leaf, seq in zip(self.store, self.is_seq):
            if seq:
                g = _gather_pool(leaf, tables)
                out.append(g)
            else:
                out.append(leaf)
        return jax.tree.unflatten(self.treedef, out)

    def scatter_token(self, cache: Any, positions: np.ndarray,
                      active: np.ndarray) -> None:
        """Write back one decode step.

        ``cache`` is the updated dense view returned by ``decode_step``;
        the single new token per slot was written at ``positions[b]``
        (the pre-step length).  Sequence leaves scatter just that token
        into their pools; non-sequence leaves (recurrent state, lengths)
        are replaced wholesale.  ``active`` masks slots whose write should
        land in the scratch page.
        """
        tables = self.tables_device()
        pos = jnp.asarray(np.where(active, positions, 0), jnp.int32)
        act = jnp.asarray(active)
        leaves, _ = jax.tree.flatten(cache)
        new_store = []
        for pool, leaf, seq in zip(self.store, leaves, self.is_seq):
            if seq:
                new_store.append(
                    _scatter_token_jit(pool, leaf, tables, pos, act,
                                       self.page_size))
            else:
                new_store.append(leaf)
        self.store = new_store

    def write_slot(self, slot: int, cache1: Any, n_tokens: int) -> None:
        """Insert a freshly prefilled request (batch-1 cache) into ``slot``.

        Sequence leaves are chopped into pages and scattered to the slot's
        block table; non-sequence leaves use the dense ``_insert_slot``
        rule (rank-1 -> axis 0, else axis 1).
        """
        pages = self.blocks_of(slot)
        need = num_blocks(n_tokens, self.page_size)
        if self.has_seq:
            assert len(pages) >= need, \
                "write_slot without enough pages mapped"
        idx = jnp.asarray(pages[:need], jnp.int32)
        leaves, _ = jax.tree.flatten(cache1)
        new_store = []
        for pool, leaf, seq in zip(self.store, leaves, self.is_seq):
            if seq:
                new_store.append(
                    _write_pages(pool, leaf, idx, need, self.page_size))
            else:
                if leaf.ndim == 1:
                    new_store.append(pool.at[slot].set(leaf[0]))
                else:
                    new_store.append(pool.at[:, slot].set(leaf[:, 0]))
        self.store = new_store

    def defrag(self) -> Dict[int, int]:
        """Compact live pages to the lowest indices.

        Returns the old->new mapping applied.  Pool data is permuted on
        device; block tables and the allocator free list are rebuilt so the
        logical contents (``gather()``) are unchanged.
        """
        live = sorted(self.alloc._used)
        mapping = {old: new for new, old in enumerate(live)}
        if all(o == n for o, n in mapping.items()):
            return mapping
        perm = np.arange(self.num_pages + 1)
        for old, new in mapping.items():
            perm[new] = old
        perm_dev = jnp.asarray(perm, jnp.int32)
        self.store = [
            _permute_pool(pool, perm_dev) if seq else pool
            for pool, seq in zip(self.store, self.is_seq)]
        lut = np.full(self.num_pages + 1, -1, np.int32)
        for old, new in mapping.items():
            lut[old] = new
        self.tables = np.where(self.tables < 0, -1,
                               lut[np.maximum(self.tables, 0)]
                               ).astype(np.int32)
        self.alloc._used = set(range(len(live)))
        self.alloc._free = list(range(self.num_pages - 1, len(live) - 1, -1))
        self._invalidate()
        return mapping


# ---------------------------------------------------------------------------
# jitted pool primitives (shapes static per engine instance)
# ---------------------------------------------------------------------------
@jax.jit
def _gather_pool(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """pool (L, P+1, ps, ...) + tables (B, nblk) -> (L, B, nblk*ps, ...)."""
    g = pool[:, tables]                      # (L, B, nblk, ps, ...)
    l, b, nblk, ps = g.shape[:4]
    return g.reshape((l, b, nblk * ps) + g.shape[4:])


@jax.jit
def _permute_pool(pool: jax.Array, perm: jax.Array) -> jax.Array:
    return pool[:, perm]


@functools.partial(jax.jit, static_argnums=(3,))
def _write_pages_impl(pool, leaf, idx, page_size):
    # leaf (L, 1, S, ...) with S >= need*ps; chop into (L, need, ps, ...)
    l = leaf.shape[0]
    need = idx.shape[0]
    chunk = leaf[:, 0, : need * page_size]
    chunk = chunk.reshape((l, need, page_size) + leaf.shape[3:])
    return pool.at[:, idx].set(chunk)


def _write_pages(pool, leaf, idx, need, page_size):
    s = leaf.shape[SEQ_AXIS]
    if s < need * page_size:                 # pad ragged tail to page edge
        pad = [(0, 0)] * leaf.ndim
        pad[SEQ_AXIS] = (0, need * page_size - s)
        leaf = jnp.pad(leaf, pad)
    return _write_pages_impl(pool, leaf, idx, page_size)


@jax.jit
def _scatter_token_jit(pool, leaf, tables, pos, active, page_size):
    """Scatter leaf[:, b, pos[b]] into pool at the page holding pos[b]."""
    b = leaf.shape[BATCH_AXIS]
    blk = pos // page_size                   # (B,)
    off = pos % page_size
    nblk = tables.shape[1]
    blk = jnp.clip(blk, 0, nblk - 1)
    page = jnp.take_along_axis(tables, blk[:, None], axis=1)[:, 0]
    trash = pool.shape[1] - 1                # scratch page index P
    page = jnp.where(active, page, trash)
    val = jnp.take_along_axis(
        leaf, pos.reshape((1, b) + (1,) * (leaf.ndim - 2)),
        axis=SEQ_AXIS)                       # (L, B, 1, ...)
    val = jnp.squeeze(val, axis=SEQ_AXIS)    # (L, B, ...)
    return pool.at[:, page, off].set(val)
