"""The replica contract (PR 10): one explicit interface for every
"replica" the router can drive.

Three implementations must stay interchangeable behind the
:class:`Replica` protocol:

* :class:`repro.serving.engine.ServingEngine` /
  :class:`~repro.serving.engine.PagedServingEngine` — the real JAX
  engines;
* ``repro.core.serving_sim._Replica`` — the analytical cluster mirror
  (modeled clock, no arrays);
* the stub replicas the router's policy unit tests drive.

Before this module the interface was duck-typed across all three and
could drift silently; now the protocol is written down here, each
implementation declares conformance in its docstring, and the
mirror-drift checker (``analysis/checks/mirror_drift.py::
check_replica_protocol``) fails CI when an implementation stops
defining a protocol method.

Contract
--------
``admit(req) -> bool``
    Try to start ``req`` (prefill immediately or begin its chunked
    prefill).  ``False`` means "no capacity right now" — the caller
    retries later; the replica must not have mutated ``req``.
``tick() -> None``
    Advance one scheduling quantum: at most one prefill chunk plus one
    decode iteration (or one fused horizon).
``busy() -> bool``
    Whether any request is resident (active or mid-prefill).
``load_report() -> LoadReport``
    Dispatch-time load signals, typed (see :class:`LoadReport`).
``requeue``
    List attribute of preempted requests awaiting re-admission; the
    scheduler drains it ahead of fresh arrivals.
``export_slot_pages(rid) -> PageShipment | None``
    Disaggregation (prefill tier): package a finished request's KV
    pages, block-table row, and prefix-trie coverage for shipment.
    ``None`` means the request is not shippable *yet* (still mid
    chunked-prefill) — the caller defers and retries.
``import_slot_pages(shipment) -> bool``
    Disaggregation (decode tier): splice a shipment into the local
    paged pool, reconciling refcounts/regions and re-registering the
    trie coverage.  ``False`` means no capacity — the caller retries
    or picks another target.

Typed reports
-------------
:class:`LoadReport` and :class:`PlacementReport` replace the
dict-shaped payloads.  They are frozen dataclasses shared by the
engine and the sims; ``asdict()``/``to_dict()`` at the JSON/metrics
boundary keeps every reported number and key name unchanged (the field
lists are pinned in ``analysis/checks/mirror_spec.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

try:                            # Protocol: py3.8+; fall back quietly
    from typing import Protocol, runtime_checkable
except ImportError:             # pragma: no cover - py3.7 safety net
    Protocol = object           # type: ignore

    def runtime_checkable(cls):  # type: ignore
        return cls


@dataclass(frozen=True)
class LoadReport:
    """Dispatch-time load signals a router reads off a replica.

    Field names mirror the legacy dict keys exactly; ``to_dict()`` is
    the JSON/metrics boundary.  ``region_free`` is only populated under
    stack-aware placement (empty tuple otherwise), and
    ``min_region_free`` falls back to ``free_pages`` so unplaced pools
    still expose a scalar pressure signal.
    """

    active: int                 # decoding slots
    prefilling: int             # 0/1: a chunked prefill is resident
    queue_depth: int            # active + prefilling + engine requeue
    free_slots: int
    free_pages: int             # page pool headroom (== free_slots dense)
    min_region_free: int        # tightest slot region (free_pages unplaced)
    region_free: Tuple[int, ...] = ()   # per-slot-region free pages

    def to_dict(self) -> Dict[str, Any]:
        d = {"active": self.active, "prefilling": self.prefilling,
             "queue_depth": self.queue_depth,
             "free_slots": self.free_slots,
             "free_pages": self.free_pages,
             "min_region_free": self.min_region_free}
        if self.region_free:
            d["region_free"] = list(self.region_free)
        return d


@dataclass(frozen=True)
class PlacementReport:
    """Stack-aware placement occupancy (``PagedCache.placement_report``).

    ``region_used`` / ``region_free`` map region id (as a string, the
    legacy JSON key shape) to page counts; ``empty`` mirrors the legacy
    "no placement configured -> {}" contract at the dict boundary.
    """

    placement_policy: str = ""
    n_regions: int = 0
    communal_pages: int = 0
    region_used: Dict[str, int] = field(default_factory=dict)
    region_free: Dict[str, int] = field(default_factory=dict)

    @property
    def empty(self) -> bool:
        return not self.placement_policy

    def to_dict(self) -> Dict[str, Any]:
        if self.empty:
            return {}
        return {"placement_policy": self.placement_policy,
                "n_regions": self.n_regions,
                "communal_pages": self.communal_pages,
                "region_used": dict(self.region_used),
                "region_free": dict(self.region_free)}


#: methods every replica implementation must define — pinned in
#: ``mirror_spec.REPLICA_PROTOCOL_METHODS`` and enforced by the
#: mirror-drift checker across engine / sim / test stubs.
REPLICA_METHODS = ("admit", "tick", "busy", "load_report",
                   "export_slot_pages", "import_slot_pages")


@runtime_checkable
class Replica(Protocol):
    """Structural type for a routable replica (see module docstring)."""

    requeue: List[Any]

    def admit(self, req: Any) -> bool: ...

    def tick(self) -> None: ...

    def busy(self) -> bool: ...

    def load_report(self) -> LoadReport: ...

    def export_slot_pages(self, rid: int) -> Optional[Any]: ...

    def import_slot_pages(self, shipment: Any) -> bool: ...
