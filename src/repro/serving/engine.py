"""Continuous-batching serving engine (the real-JAX counterpart of the
paper's Duplex-style serving simulator in ``repro.core.serving_sim``).

Slot-based KV/state cache: the engine owns a ``max_batch``-deep cache pytree;
finished requests free their slot and newly prefilled requests are inserted
with a donated dynamic-update — the decode step always runs at the full slot
batch (inactive slots are masked by their ``lengths``), which keeps one
compiled executable hot.

Works for every registry family (KVCache / RWKVState / RGState /
EncDecCache) via a generic batch-axis rule: rank-1 state leaves batch on
axis 0, higher-rank leaves on axis 1 (layer dim leads).

On CPU this drives reduced configs end-to-end (see examples/serve_decode.py
and launch/serve.py); under a production mesh the same engine runs with the
shardings from ``launch.steps.assemble_shardings``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry


@dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 512
    max_new_tokens: int = 32
    eos_id: int = -1            # <0: never stops early (synthetic load)
    use_pallas_decode: bool = False   # flash-decode kernel for attention
    prefill_chunk: Optional[int] = None   # Sarathi-style chunked prefill


@dataclass
class RequestState:
    rid: int
    prompt: np.ndarray
    arrival_s: float = 0.0
    slot: int = -1
    prefill_done_s: float = 0.0
    tokens_out: List[int] = field(default_factory=list)
    token_times: List[float] = field(default_factory=list)
    finish_s: float = 0.0

    @property
    def done(self) -> bool:
        return self.finish_s > 0.0


def _insert_slot(cache, new, slot: int):
    """Write request-0 of ``new`` (batch=1 prefill output) into ``slot``."""
    def one(c, n):
        if c.ndim == 1:                       # lengths-like, batch axis 0
            return c.at[slot].set(n[0])
        return c.at[:, slot].set(n[:, 0])     # (L, B, ...) batch axis 1
    return jax.tree.map(one, cache, new)


class ServingEngine:
    def __init__(self, entry: registry.ArchEntry, ecfg: EngineConfig,
                 tp: int = 1, mesh=None):
        self.entry = entry
        self.cfg = entry.config
        self.ecfg = ecfg
        self.tp = tp
        self.mesh = mesh
        key = jax.random.PRNGKey(0)
        self.params = entry.module.init(key, self.cfg, tp)
        self.cache = entry.cache_zeros(ecfg.max_batch, ecfg.max_seq, tp)
        self.free_slots = list(range(ecfg.max_batch))
        self.active: Dict[int, RequestState] = {}
        self.completed: List[RequestState] = []
        self._clock = 0.0

        attn_fn = None
        if ecfg.use_pallas_decode and self.cfg.family in ("dense", "moe",
                                                          "vlm"):
            from repro.kernels import ops as kops
            attn_fn = (lambda q, k, v, lengths:
                       kops.attention_decode(q, k, v, lengths))

        mod, cfg = entry.module, self.cfg

        def _prefill(params, tokens):
            if cfg.family == "audio":
                return mod.prefill(params, cfg, tokens,
                                   frames=jnp.zeros((tokens.shape[0],
                                                     cfg.encoder_frames,
                                                     cfg.d_model),
                                                    jnp.float32),
                                   tp=tp, max_seq=ecfg.max_seq)
            if cfg.family in ("dense", "moe", "vlm"):
                return mod.prefill(params, cfg, tokens, tp=tp,
                                   max_seq=ecfg.max_seq,
                                   chunk=ecfg.prefill_chunk)
            return mod.prefill(params, cfg, tokens, tp=tp,
                               max_seq=ecfg.max_seq)

        def _decode(params, cache, tokens):
            if attn_fn is not None:
                return mod.decode_step(params, cfg, tokens, cache, tp=tp,
                                       attn_fn=attn_fn)
            return mod.decode_step(params, cfg, tokens, cache, tp=tp)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(1,))
        self._next_tok = np.zeros((ecfg.max_batch,), np.int32)

    # ------------------------------------------------------------------
    def submit(self, req: RequestState) -> bool:
        """Prefill the request into a free slot; False if engine is full."""
        if not self.free_slots:
            return False
        slot = self.free_slots.pop()
        t0 = time.perf_counter()
        tokens = jnp.asarray(req.prompt[None, :])
        logits, new_cache = self._prefill(self.params, tokens)
        logits.block_until_ready()
        self.cache = _insert_slot(self.cache, new_cache, slot)
        first = int(jnp.argmax(logits[0, : self.cfg.vocab]))
        self._next_tok[slot] = first
        req.slot = slot
        req.prefill_done_s = time.perf_counter() - t0
        req.tokens_out.append(first)
        self.active[slot] = req
        return True

    def step(self) -> int:
        """One decode iteration for all active slots; returns #finished."""
        if not self.active:
            return 0
        toks = jnp.asarray(self._next_tok)
        logits, self.cache = self._decode(self.params, self.cache, toks)
        logits.block_until_ready()
        now = time.perf_counter()
        nxt = np.asarray(jnp.argmax(logits[:, : self.cfg.vocab], axis=-1),
                         np.int32)
        finished = 0
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.tokens_out.append(tok)
            req.token_times.append(now)
            hit_eos = self.ecfg.eos_id >= 0 and tok == self.ecfg.eos_id
            if hit_eos or len(req.tokens_out) >= self.ecfg.max_new_tokens:
                req.finish_s = now
                self.completed.append(req)
                del self.active[slot]
                self.free_slots.append(slot)
                finished += 1
            else:
                self._next_tok[slot] = tok
        return finished

    # ------------------------------------------------------------------
    def run_workload(self, *, rate_req_s: float, n_requests: int,
                     prompt_len: int, seed: int = 0) -> dict:
        """Poisson arrivals, wall-clock continuous batching; returns metrics."""
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / rate_req_s, size=n_requests)
        arrivals = np.cumsum(gaps)
        prompts = rng.integers(0, self.cfg.vocab,
                               size=(n_requests, prompt_len)).astype(np.int32)
        reqs = [RequestState(i, prompts[i], arrival_s=float(arrivals[i]))
                for i in range(n_requests)]
        t0 = time.perf_counter()
        pending = list(reqs)
        while len(self.completed) < n_requests:
            now = time.perf_counter() - t0
            while pending and pending[0].arrival_s <= now and self.free_slots:
                self.submit(pending.pop(0))
            if not self.active:
                if pending:
                    time.sleep(max(0.0, pending[0].arrival_s - now))
                continue
            self.step()
        wall = time.perf_counter() - t0
        tbts = []
        for r in self.completed:
            if len(r.token_times) > 1:
                tbts.extend(np.diff(r.token_times))
        toks = sum(len(r.tokens_out) for r in self.completed)
        return {"wall_s": wall, "requests": len(self.completed),
                "decoded_tokens": toks,
                "tokens_per_s": toks / wall,
                "tbt_mean_s": float(np.mean(tbts)) if tbts else 0.0,
                "tbt_p99_s": float(np.percentile(tbts, 99)) if tbts else 0.0}
