"""Continuous-batching serving engine (the real-JAX counterpart of the
paper's Duplex-style serving simulator in ``repro.core.serving_sim``).

Two KV/state residency modes:

* **Dense (seed layout)** — the engine owns a ``max_batch``-deep cache
  pytree reserved at ``max_batch x max_seq``; finished requests free their
  slot and newly prefilled requests are inserted with a donated
  dynamic-update.
* **Paged (block-table layout, ``EngineConfig.paged``)** — sequence-bearing
  cache leaves live in a page pool (``serving/paged_cache.py``) and each
  slot maps its context through a block table, so resident KV is
  proportional to the *actual* context lengths.  Prompt pages are reserved
  at admission; decode growth allocates on demand, and when the pool is
  oversubscribed the youngest active request is preempted and re-queued.
  The decode step either gathers the slot pages into the dense view
  (reference path, token-exact vs. the dense engine) or — with
  ``use_pallas_decode`` on attention families — reads pages directly
  through the block table with the paged flash-decode kernel, never
  materializing a contiguous cache.

Admission is arrival-driven and prefill can be **chunk-interleaved**
(Sarathi, the paper's ref [1]): with ``prefill_chunk`` set, the driver
advances at most one prompt chunk via ``transformer.extend_step`` between
decode iterations, so a long prompt never stalls the hot decode batch for
more than one chunk of work.  On the paged engine those chunks are
written **directly into block-table pages** (gather the slot window,
extend, scatter the chunk) — no dense per-request staging buffer, no
admission-time copy.

The trace-driving loop itself lives in ``serving/scheduler.py`` (PR 3):
an engine is one *replica* exposing ``admit`` / ``tick`` /
``load_report``, and ``serving/router.py`` dispatches traffic across N
replicas.  ``run_trace`` / ``run_workload`` here are thin wrappers that
drive a single-replica :class:`~repro.serving.scheduler.Scheduler`.

Works for every registry family (KVCache / RWKVState / RGState /
EncDecCache) via a generic batch-axis rule: rank-1 state leaves batch on
axis 0, higher-rank leaves on axis 1 (layer dim leads).  Recurrent
families have no sequence leaves, so their paged cache degenerates to the
(already proportional) slot-dense layout.

On CPU this drives reduced configs end-to-end (see examples/serve_decode.py
and launch/serve.py); under a production mesh the same engine runs with the
shardings from ``launch.steps.assemble_shardings``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import registry
from repro.obs.tracer import NULL_TRACER
from repro.serving.paged_cache import PagedCache, PageShipment, num_blocks
from repro.serving.replica_api import LoadReport
# re-exported for back-compat: these lived here before the scheduling
# loop was extracted into serving/scheduler.py
from repro.serving.scheduler import (RequestState, Scheduler, load_trace,
                                     make_grouped_prefix_trace,
                                     make_shared_prefix_trace, make_trace,
                                     save_trace)

__all__ = ["EngineConfig", "RequestState", "ServingEngine",
           "PagedServingEngine", "make_engine", "make_trace",
           "make_shared_prefix_trace", "make_grouped_prefix_trace",
           "load_trace", "save_trace", "Scheduler"]

# transformer-module families: chunkable prefill (extend_step) and the
# flash-decode attention paths all key off this one set
_ATTN_FAMILIES = ("dense", "moe", "vlm")


@dataclass
class EngineConfig:
    max_batch: int = 8
    max_seq: int = 512
    max_new_tokens: int = 32
    eos_id: int = -1            # <0: never stops early (synthetic load)
    use_pallas_decode: bool = False   # flash-decode kernel for attention
    prefill_chunk: Optional[int] = None   # Sarathi-style chunked prefill
    paged: bool = False               # block-table KV residency
    page_size: int = 16
    num_pages: Optional[int] = None   # default: dense-equivalent capacity
    prefix_sharing: bool = False      # refcounted prompt-prefix pages + CoW
    # run PagedCache.defrag() when the fraction of holes below the
    # high-water page index exceeds this (None disables the trigger)
    defrag_threshold: Optional[float] = 0.5
    # stack-aware page placement (core/placement.py): None keeps the
    # legacy layout with no region accounting; "free-first" keeps the
    # legacy layout but scores it; "affinity" co-locates a slot's pages
    # in one per-channel region; "interleave" stripes them
    placement: Optional[str] = None
    placement_regions: Optional[int] = None   # default: one per PU, capped
    # fraction of the pool carved off for shared prefix pages (placement
    # + prefix_sharing only)
    communal_frac: float = 0.25
    # live microarchitecture-scheduling co-design (core/serving_sim.py
    # TickLatencyModel): price every tick's actual operator mix on an NMP
    # substrate model and report the chosen array shapes, reconfiguration
    # count, and utilization alongside the wall-clock metrics.  The
    # modeled clock is an accounting channel — scheduling stays
    # wall-clock-driven, so decoded tokens are identical with it on/off.
    codesign: bool = False
    # price a fixed-shape substrate (rows x PEs/rows @ the same PE count
    # as the reconfigurable default) instead — the benchmark's baselines
    codesign_rows: Optional[int] = None
    # price this ModelSpec instead of the engine's own (reduced test
    # configs run tiny weights; pricing the full-size registry spec keeps
    # the substrate comparison at deployment scale), and at this tensor-
    # parallel width (the paper's stacks are tp=8 even when the reduced
    # engine itself runs tp=1)
    codesign_spec: Optional[object] = None
    codesign_tp: Optional[int] = None
    # seconds charged to the modeled clock per substrate reconfiguration
    # (shape-profile change); None derives the pipeline fill/drain cost
    # from the substrate geometry
    codesign_reconfig_cost_s: Optional[float] = None
    # fuse up to K decode steps into one jitted lax.scan with tokens,
    # lengths, and eos/finish masks resident on device (paged engine
    # only; 1 keeps the per-tick host loop).  The actual horizon each
    # tick is min(fuse_steps, steps-until-any-slot-needs-a-new-page,
    # min-remaining-decode-budget), so allocation and token streams stay
    # exactly identical to the per-tick engine
    fuse_steps: int = 1


def _insert_slot(cache, new, slot: int):
    """Write request-0 of ``new`` (batch=1 prefill output) into ``slot``."""
    def one(c, n):
        if c.ndim == 1:                       # lengths-like, batch axis 0
            return c.at[slot].set(n[0])
        return c.at[:, slot].set(n[:, 0])     # (L, B, ...) batch axis 1
    return jax.tree.map(one, cache, new)


class ServingEngine:
    """Fixed-slot dense-cache engine (the seed layout)."""

    def __init__(self, entry: registry.ArchEntry, ecfg: EngineConfig,
                 tp: int = 1, mesh=None):
        self.entry = entry
        self.cfg = entry.config
        self.ecfg = ecfg
        self.tp = tp
        self.mesh = mesh
        key = jax.random.PRNGKey(0)
        self.params = entry.module.init(key, self.cfg, tp)
        self.free_slots = list(range(ecfg.max_batch))
        self.active: Dict[int, RequestState] = {}
        self.completed: List[RequestState] = []
        self.preemption_count = 0
        self.requeue: List[RequestState] = []   # preempted, awaiting re-admit
        self._prefilling: Optional[dict] = None   # chunk-scheduler state
        # disaggregation tier (PR 10): "mixed" engines prefill AND decode
        # (the colocated default); a "prefill"-tier engine runs prompts
        # but never decodes — its finished slots are harvested by the
        # router and shipped to a "decode"-tier replica's page pool
        self.role = "mixed"
        # lifecycle tracing (repro.obs): NULL_TRACER keeps the hot path
        # branch-cheap; set_tracer swaps in a recording tracer.  Tracing
        # only reads state, so tokens are bit-identical either way.
        self.tracer = NULL_TRACER
        self._init_cache()
        self._init_codesign()

        attn_fn = None
        if ecfg.use_pallas_decode and self.cfg.family in _ATTN_FAMILIES:
            from repro.kernels import ops as kops
            attn_fn = (lambda q, k, v, lengths:
                       kops.attention_decode(q, k, v, lengths))

        mod, cfg = entry.module, self.cfg

        def _prefill(params, tokens):
            if cfg.family == "audio":
                return mod.prefill(params, cfg, tokens,
                                   frames=jnp.zeros((tokens.shape[0],
                                                     cfg.encoder_frames,
                                                     cfg.d_model),
                                                    jnp.float32),
                                   tp=tp, max_seq=ecfg.max_seq)
            if cfg.family in _ATTN_FAMILIES:
                return mod.prefill(params, cfg, tokens, tp=tp,
                                   max_seq=ecfg.max_seq,
                                   chunk=ecfg.prefill_chunk)
            return mod.prefill(params, cfg, tokens, tp=tp,
                               max_seq=ecfg.max_seq)

        def _decode(params, cache, tokens):
            if attn_fn is not None:
                return mod.decode_step(params, cfg, tokens, cache, tp=tp,
                                       attn_fn=attn_fn)
            return mod.decode_step(params, cfg, tokens, cache, tp=tp)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode, donate_argnums=(1,))
        if cfg.family in _ATTN_FAMILIES:
            self._extend = jax.jit(
                lambda params, tokens, cache:
                mod.extend_step(params, cfg, tokens, cache, tp=tp))
        else:
            self._extend = None
        self._next_tok = np.zeros((ecfg.max_batch,), np.int32)

    def set_tracer(self, tracer, replica: int = 0) -> None:
        """Attach an ``obs.tracer`` Tracer; this replica's events carry
        ``replica`` as their Perfetto process id."""
        self.tracer = tracer.for_replica(replica)

    # -- cache backend hooks (overridden by PagedServingEngine) ------------
    def _init_cache(self):
        self.cache = self.entry.cache_zeros(self.ecfg.max_batch,
                                            self.ecfg.max_seq, self.tp)

    def _claim(self, req: RequestState) -> Optional[int]:
        """Reserve a slot (and, when paged, the prompt's pages; with
        prefix sharing, leading pages already resident are mapped instead
        of allocated)."""
        if not self.free_slots:
            return None
        return self.free_slots.pop()

    def _insert(self, slot: int, new_cache, n_tokens: int) -> None:
        self.cache = _insert_slot(self.cache, new_cache, slot)

    def _release(self, slot: int) -> None:
        self.free_slots.append(slot)

    def _decode_batch(self, toks: jax.Array) -> jax.Array:
        """One decode iteration over all slots; returns logits."""
        logits, self.cache = self._decode(self.params, self.cache, toks)
        return logits

    def _pre_decode_grow(self) -> None:
        """Hook: ensure capacity for the token the step is about to write."""

    def kv_report(self) -> dict:
        """Resident-KV accounting (tokens of cache reserved vs. in use)."""
        used = sum(len(r.prompt) + len(r.tokens_out)
                   for r in self.active.values())
        cap = self.ecfg.max_batch * self.ecfg.max_seq
        return {"mode": "dense", "reserved_tokens": cap,
                "peak_tokens": cap, "used_tokens": used}

    def _budget(self, req: RequestState) -> int:
        """Decode budget: the engine ceiling, tightened by the request's
        trace-sampled early stop (eos-aware traces)."""
        if req.decode_len is not None:
            return min(self.ecfg.max_new_tokens, max(1, req.decode_len))
        return self.ecfg.max_new_tokens

    def _activate(self, slot: int, req: RequestState) -> None:
        """Prefill done, first token emitted: either the request is
        already finished (budget of one, or the first token IS eos) or it
        joins the decode batch."""
        hit_eos = (self.ecfg.eos_id >= 0
                   and req.tokens_out[-1] == self.ecfg.eos_id)
        budget = self._budget(req)
        if hit_eos or len(req.tokens_out) >= budget:
            req.finish_s = time.perf_counter()
            req.finish_reason = (
                "eos" if (hit_eos or budget < self.ecfg.max_new_tokens)
                else "budget")
            self.completed.append(req)
            if self.tracer.enabled:
                self.tracer.emit("finish", ts=req.finish_s, slot=slot,
                                 rid=req.rid, reason=req.finish_reason,
                                 tokens=len(req.tokens_out))
            self._release(slot)
            return
        self.active[slot] = req

    # ------------------------------------------------------------------
    def submit(self, req: RequestState) -> bool:
        """Prefill the request into a free slot; False if engine is full."""
        slot = self._claim(req)
        if slot is None:
            return False
        t0 = time.perf_counter()
        tokens = jnp.asarray(req.prompt[None, :])
        logits, new_cache = self._prefill(self.params, tokens)
        self._insert(slot, new_cache, len(req.prompt))
        # argmax on device; the int() fetch is the only synchronization
        first = int(jnp.argmax(logits[0, : self.cfg.vocab]))
        self._next_tok[slot] = first
        req.slot = slot
        req.prefill_done_s = time.perf_counter() - t0
        req.first_token_s = time.perf_counter()
        req.tokens_out.append(first)
        if self.tracer.enabled:
            # whole-prompt prefill is one maximal "chunk"
            self.tracer.emit("prefill_chunk", ts=t0,
                             dur=req.first_token_s - t0, slot=slot,
                             rid=req.rid, tokens=len(req.prompt),
                             pos=len(req.prompt), last=True)
        self._activate(slot, req)
        return True

    def step(self) -> int:
        """One decode iteration for all active slots; returns #finished."""
        if not self.active or self.role == "prefill":
            # prefill-tier engines hold finished prompts for harvest
            # (export_slot_pages) instead of decoding them
            return 0
        t_step0 = time.perf_counter() if self.tracer.enabled else 0.0
        batch0 = len(self.active)
        self._pre_decode_grow()
        toks = jnp.asarray(self._next_tok)
        logits = self._decode_batch(toks)
        # argmax on device, one host fetch per step — dispatch stays
        # async until the sampled ids are actually needed
        nxt = np.asarray(jnp.argmax(logits[:, : self.cfg.vocab], axis=-1),
                         np.int32)
        now = time.perf_counter()
        finished = 0
        for slot, req in list(self.active.items()):
            tok = int(nxt[slot])
            req.tokens_out.append(tok)
            req.token_times.append(now)
            hit_eos = self.ecfg.eos_id >= 0 and tok == self.ecfg.eos_id
            # per-request decode budget: a trace-sampled early stop below
            # the engine ceiling models an eos emission (eos-aware traces)
            budget = self._budget(req)
            if hit_eos or len(req.tokens_out) >= budget:
                req.finish_s = now
                req.finish_reason = (
                    "eos" if (hit_eos
                              or budget < self.ecfg.max_new_tokens)
                    else "budget")
                self.completed.append(req)
                if self.tracer.enabled:
                    self.tracer.emit("finish", ts=now, slot=slot,
                                     rid=req.rid,
                                     reason=req.finish_reason,
                                     tokens=len(req.tokens_out))
                del self.active[slot]
                self._release(slot)
                finished += 1
            else:
                self._next_tok[slot] = tok
        if self.tracer.enabled:
            self.tracer.emit("decode_step", ts=t_step0, dur=now - t_step0,
                             batch=batch0, finished=finished)
        return finished

    # -- Sarathi chunk scheduler ---------------------------------------
    def _chunkable(self) -> bool:
        return (self._extend is not None
                and self.ecfg.prefill_chunk is not None)

    def _start_chunked(self, req: RequestState) -> bool:
        """Claim a slot and set up incremental prefill state for ``req``."""
        if self._prefilling is not None:
            return False
        slot = self._claim(req)
        if slot is None:
            return False
        buf = self.entry.cache_zeros(1, self.ecfg.max_seq, self.tp)
        self._prefilling = {"req": req, "slot": slot, "buf": buf,
                            "pos": 0, "t0": time.perf_counter(),
                            "logits": None}
        return True

    def _prefill_chunk_tick(self) -> bool:
        """Advance the in-flight prefill by ONE chunk.  True when the
        request became active (prefill complete)."""
        st = self._prefilling
        if st is None:
            return False
        tr = self.tracer
        t_ck0 = time.perf_counter() if tr.enabled else 0.0
        req, chunk = st["req"], self.ecfg.prefill_chunk
        n = len(req.prompt)
        take = min(chunk, n - st["pos"])
        toks = jnp.asarray(req.prompt[None, st["pos"]: st["pos"] + take])
        logits, st["buf"] = self._extend(self.params, toks, st["buf"])
        # no sync: chunks chain on device; only the final chunk's argmax
        # (below) fetches a value at the prefill boundary
        st["pos"] += take
        st["logits"] = logits
        if st["pos"] < n:
            if tr.enabled:
                tr.emit("prefill_chunk", ts=t_ck0,
                        dur=time.perf_counter() - t_ck0, slot=st["slot"],
                        rid=req.rid, tokens=take, pos=st["pos"],
                        last=False)
            return False
        # prompt fully consumed: move the buffer into the slot
        slot = st["slot"]
        self._insert(slot, st["buf"], n)
        first = int(jnp.argmax(st["logits"][0, : self.cfg.vocab]))
        self._next_tok[slot] = first
        req.slot = slot
        req.prefill_done_s = time.perf_counter() - st["t0"]
        req.first_token_s = time.perf_counter()
        req.tokens_out.append(first)
        if tr.enabled:
            tr.emit("prefill_chunk", ts=t_ck0,
                    dur=req.first_token_s - t_ck0, slot=slot, rid=req.rid,
                    tokens=take, pos=n, last=True)
        self._activate(slot, req)
        self._prefilling = None
        return True

    # -- narrow replica interface (driven by Scheduler / Router) -------
    def admit(self, req: RequestState) -> bool:
        """Claim a slot for ``req`` — chunked prefill start when chunking
        is configured, full prefill otherwise.  False when saturated."""
        return (self._start_chunked(req) if self._chunkable()
                else self.submit(req))

    # -- live co-design (TickLatencyModel accounting channel) ----------
    def _init_codesign(self) -> None:
        self._tick_model = None
        self.modeled_time_s = 0.0
        self._tick_util_sum = 0.0
        self._tick_steps = 0
        if not self.ecfg.codesign:
            return
        from repro.core.hw import fixed_sa_system
        from repro.core.placement import default_system
        from repro.core.serving_sim import nmp_tick_model
        hw = default_system()
        if self.ecfg.codesign_rows:
            sa = hw.substrate
            pes = sa.phys_rows * sa.phys_cols
            hw = fixed_sa_system(self.ecfg.codesign_rows,
                                 pes // self.ecfg.codesign_rows)
        self._codesign_hw = hw
        spec = self.ecfg.codesign_spec or self.entry.config.nmp_spec()
        self._tick_model = nmp_tick_model(
            hw, spec, tp=self.ecfg.codesign_tp or self.tp,
            reconfig_cost_s=self.ecfg.codesign_reconfig_cost_s)

    def _note_tick(self, batch: int, ctxs: List[int], pf_tokens: int,
                   pf_ctx: int) -> None:
        """Price this tick's actual composition on the modeled substrate."""
        if self._tick_model is None or not (batch or pf_tokens):
            return
        prev = (self._tick_model._last_shapes.get(0)
                if self.tracer.enabled else None)
        d = self._tick_model.step(batch, ctxs, prefill_tokens=pf_tokens,
                                  prefill_ctx=pf_ctx)
        self.modeled_time_s += d.time_s + d.reconfig_s
        self._tick_util_sum += d.util
        self._tick_steps += 1
        if prev is not None and prev != d.shapes:
            # instantaneous on the wall clock; the modeled charge rides
            # in args (the sims charge dur on their own clock instead)
            self.tracer.emit("reconfigure", old=str(prev),
                             new=str(d.shapes),
                             modeled_reconfig_s=d.reconfig_s)

    def codesign_report(self) -> dict:
        """Substrate decisions accumulated over the run ({} when off)."""
        if self._tick_model is None:
            return {}
        tm = self._tick_model
        return {"substrate": self._codesign_hw.name,
                "modeled_time_s": self.modeled_time_s,
                "reconfigurations": tm.reconfigurations,
                "substrate_configs": len(tm.configs_seen),
                "array_util_mean": (self._tick_util_sum / self._tick_steps
                                    if self._tick_steps else 0.0)}

    def tick(self) -> int:
        """Advance one iteration: at most one prefill chunk co-scheduled
        with one decode step.  Returns #finished requests."""
        pf_tokens = pf_ctx = 0
        if self._chunkable():
            st = self._prefilling
            if st is not None and self._tick_model is not None:
                pf_tokens = min(self.ecfg.prefill_chunk,
                                len(st["req"].prompt) - st["pos"])
                pf_ctx = st["pos"] + pf_tokens
            self._prefill_chunk_tick()
        if self._tick_model is not None:
            # composition of the decode step about to run (the chunk just
            # ticked may have activated its request into this batch; a
            # prefill-tier engine never decodes, so its batch is empty)
            ctxs = ([len(r.prompt) + len(r.tokens_out)
                     for r in self.active.values()]
                    if self.role != "prefill" else [])
            self._note_tick(len(ctxs), ctxs, pf_tokens, pf_ctx)
        n_fin = self.step()
        if self.tracer.enabled:
            self._trace_gauges()
        return n_fin

    def _trace_gauges(self) -> None:
        """One ``gauge`` event per tick (tracing only): each args key
        becomes a Perfetto counter track."""
        args = {"active": len(self.active),
                "free_slots": len(self.free_slots)}
        if self._tick_model is not None and self.modeled_time_s > 0:
            toks = (sum(len(r.tokens_out) for r in self.completed)
                    + sum(len(r.tokens_out) for r in self.active.values()))
            args["modeled_tokens_per_s"] = toks / self.modeled_time_s
        self.tracer.emit("gauge", **args)

    def busy(self) -> bool:
        return bool(self.active) or self._prefilling is not None

    def load_report(self) -> LoadReport:
        """Load snapshot for front-end routing decisions: resident work
        (``queue_depth``) and headroom (``free_slots`` / ``free_pages``),
        typed per the :mod:`~repro.serving.replica_api` contract."""
        prefilling = int(self._prefilling is not None)
        free = len(self.free_slots)
        # dense engines have no page pool; slots are the capacity
        return LoadReport(
            active=len(self.active), prefilling=prefilling,
            queue_depth=(len(self.active) + prefilling
                         + len(self.requeue)),
            free_slots=free, free_pages=free, min_region_free=free)

    def prefix_residency(self, prompt: np.ndarray) -> int:
        """Prompt pages already resident on this replica (0: none — the
        dense engine shares nothing)."""
        return 0

    # -- disaggregation hooks (PR 10; paged engine overrides) ----------
    def export_slot_pages(self, rid: int) -> Optional[PageShipment]:
        raise RuntimeError("page shipping requires the paged engine "
                           "(EngineConfig.paged)")

    def import_slot_pages(self, shipment: PageShipment) -> bool:
        raise RuntimeError("page shipping requires the paged engine "
                           "(EngineConfig.paged)")

    # -- single-replica driver wrappers --------------------------------
    def run_trace(self, reqs: List[RequestState]) -> dict:
        """Drive an explicit request trace through a single-replica
        :class:`~repro.serving.scheduler.Scheduler` (the loop extracted
        from this class in PR 3)."""
        return Scheduler(self).run_trace(reqs)

    def run_workload(self, *, rate_req_s: float, n_requests: int,
                     prompt_len: int, seed: int = 0,
                     prompt_lens: Optional[np.ndarray] = None,
                     **trace_kwargs) -> dict:
        """Poisson arrivals, wall-clock continuous batching; returns metrics.

        ``prompt_lens`` overrides the constant ``prompt_len`` per request
        (skewed-length traces); remaining ``trace_kwargs`` (``eos_rate``,
        ``sessions``) are threaded through to :func:`make_trace` instead
        of being silently dropped."""
        reqs = make_trace(self.cfg.vocab, rate_req_s=rate_req_s,
                          n_requests=n_requests, prompt_len=prompt_len,
                          seed=seed, prompt_lens=prompt_lens,
                          **trace_kwargs)
        return self.run_trace(reqs)


# ---------------------------------------------------------------------------
# Paged engine
# ---------------------------------------------------------------------------
class PagedServingEngine(ServingEngine):
    """Block-table KV residency + on-demand page growth + preemption."""

    def _init_cache(self):
        ecfg = self.ecfg
        if ecfg.page_size <= 0:
            raise ValueError(f"page_size must be positive, "
                             f"got {ecfg.page_size}")
        max_blocks = num_blocks(ecfg.max_seq, ecfg.page_size)
        n_pages = ecfg.num_pages or ecfg.max_batch * max_blocks
        if n_pages < max_blocks:
            raise ValueError(
                f"num_pages={n_pages} cannot hold even one max-length "
                f"context ({max_blocks} pages)")
        pmap = None
        self._hw = None
        if ecfg.placement is not None:
            from repro.core.placement import PlacementMap, default_system
            self._hw = default_system()
            pmap = PlacementMap.from_system(
                self._hw, n_pages,
                communal_frac=(ecfg.communal_frac
                               if ecfg.prefix_sharing else 0.0),
                n_regions=ecfg.placement_regions)
        self.paged = PagedCache(self.entry, max_batch=ecfg.max_batch,
                                max_seq=ecfg.max_seq,
                                page_size=ecfg.page_size,
                                num_pages=n_pages, tp=self.tp,
                                share=ecfg.prefix_sharing,
                                placement=pmap,
                                placement_policy=(ecfg.placement
                                                  or "free-first"))
        # PagedCache rounds max_seq up to a whole number of pages; adopt
        # the rounded value so prefill buffers, gather views and occupancy
        # math all agree with the table capacity (kv_report asserts this)
        ecfg.max_seq = self.paged.max_seq
        self._lengths_host = np.zeros((ecfg.max_batch,), np.int64)
        self.pages_peak = 0
        self.pages_logical_peak = 0
        self.dedup_ratio_peak = 1.0
        self.defrag_runs = 0
        # prompt tokens whose extend_step compute was skipped because the
        # shared-prefix trie already held their KV (chunked prefill)
        self.prefill_tokens_skipped = 0
        self._gather_cost_sum = 0.0
        self._gather_conc_sum = 0.0
        self._gather_cost_steps = 0
        # per-iteration gather-cost samples (obs histogram source; one
        # float per decode iteration under a placement map)
        self.gather_cost_samples: List[float] = []
        self._region_peak: Dict[int, int] = {}
        self._paged_decode = None   # built lazily (pallas path)
        # fused multi-step decode (lax.scan engine core): one jitted
        # callable per bucketed horizon length, plus host/device wall
        # split for the host-overhead metric
        self._fused_jits: Dict[int, Any] = {}
        self._fused_ticks = 0
        self._fused_steps_sum = 0
        self._fused_host_s = 0.0
        self._fused_device_s = 0.0
        # realized horizons (obs histogram source) and the constraint
        # that clamped the most recent one (fused_tick trace events)
        self.fused_horizons: List[int] = []
        self._last_horizon_clamp = "fuse_steps"

    def set_tracer(self, tracer, replica: int = 0) -> None:
        super().set_tracer(tracer, replica)
        self.paged.tracer = self.tracer   # CoW / defrag / migrate events

    # -- capacity ------------------------------------------------------
    def _claim(self, req: RequestState) -> Optional[int]:
        if not self.free_slots:
            return None
        slot = self.free_slots.pop()
        tokens = req.prompt if self.paged.share else None
        if not self.paged.alloc_slot(slot, len(req.prompt) + 1,
                                     tokens=tokens):
            self.free_slots.append(slot)
            return None
        self._note_pages()
        return slot

    def _insert(self, slot: int, new_cache, n_tokens: int) -> None:
        self.paged.write_slot(slot, new_cache, n_tokens)
        self._lengths_host[slot] = n_tokens

    def _release(self, slot: int) -> None:
        self.paged.free_slot(slot)
        self._lengths_host[slot] = 0
        self._maybe_defrag()
        super()._release(slot)

    def _maybe_defrag(self) -> None:
        """Fragmentation-threshold defrag trigger: compact the page pool
        when the live set has drifted too far from the lowest indices (the
        gather's DMA pattern is densest on a compact pool)."""
        thr = self.ecfg.defrag_threshold
        if thr is None or not self.paged.has_seq:
            return
        if self.paged.fragmentation() > thr:
            # defrag also runs the spilled-page home-migration repair
            # pass (placed mode), priced on the engine's hardware model
            self.paged.defrag(self._hw)
            self.defrag_runs += 1

    def _note_pages(self) -> None:
        physical = self.paged.pages_in_use()
        self.pages_peak = max(self.pages_peak, physical)
        logical = self.paged.logical_pages()
        self.pages_logical_peak = max(self.pages_logical_peak, logical)
        if physical:
            self.dedup_ratio_peak = max(self.dedup_ratio_peak,
                                        logical / physical)
        if self.paged.placement is not None:
            for r, u in self.paged.alloc.region_used().items():
                self._region_peak[r] = max(self._region_peak.get(r, 0), u)

    def _note_gather_cost(self) -> None:
        """Score the active slots' block tables against the substrate
        (one sample per decode iteration)."""
        if self.paged.placement is None or not self.active:
            return
        cost, conc = self.paged.gather_cost_mean(
            self._hw, slots=sorted(self.active))
        self._gather_cost_sum += cost
        self._gather_conc_sum += conc
        self._gather_cost_steps += 1
        self.gather_cost_samples.append(cost)

    def load_report(self) -> LoadReport:
        base = super().load_report()
        if not self.paged.has_seq:
            return base
        free_pages = self.paged.alloc.free_pages
        region_free: tuple = ()
        min_region_free = free_pages
        if self.paged.placement is not None:
            # per-region pressure: the scarcest slot region is what
            # gates an affinity admission staying fully co-located
            free = self.paged.alloc.region_free()
            slot_free = tuple(free[r] for r in free if r >= 0)
            region_free = slot_free
            min_region_free = min(slot_free)
        return LoadReport(
            active=base.active, prefilling=base.prefilling,
            queue_depth=base.queue_depth, free_slots=base.free_slots,
            free_pages=free_pages, min_region_free=min_region_free,
            region_free=region_free)

    def prefix_residency(self, prompt: np.ndarray) -> int:
        return self.paged.prefix_residency(prompt)

    # -- prefill/decode disaggregation (PR 10) -------------------------
    def export_slot_pages(self, rid: int) -> Optional[PageShipment]:
        """Package request ``rid``'s finished-prefill slot for shipment
        to a decode-tier replica, and release the slot here.

        Returns ``None`` while the request is still mid chunked-prefill
        (handoff is deferred — the harvester retries next tick).  The
        shipment carries the request object, its first decoded token
        (produced at the prefill boundary on THIS replica, so the
        decode tier continues the exact greedy stream), and the priced
        cross-stack movement cost.
        """
        st = self._prefilling
        if st is not None and st["req"].rid == rid:
            return None                 # mid-prefill: defer the handoff
        slot = next((s for s, r in self.active.items() if r.rid == rid),
                    None)
        if slot is None:
            raise KeyError(f"request {rid} is not resident")
        req = self.active.pop(slot)
        shipment = self.paged.export_slot_pages(
            slot, int(self._lengths_host[slot]), tokens=req.prompt,
            sys=self._hw, hops=1)
        shipment.req = req
        shipment.next_tok = int(self._next_tok[slot])
        self._lengths_host[slot] = 0
        self._maybe_defrag()
        self.free_slots.append(slot)
        return shipment

    def import_slot_pages(self, shipment: PageShipment) -> bool:
        """Splice a prefill-tier shipment into a free slot and join the
        request to this replica's decode batch.  ``False`` when no slot
        or insufficient pages are available (caller re-targets/retries);
        atomic either way."""
        if not self.free_slots:
            return False
        slot = self.free_slots.pop()
        if not self.paged.import_slot_pages(slot, shipment):
            self.free_slots.append(slot)
            return False
        req = shipment.req
        self._lengths_host[slot] = shipment.n_tokens
        self._next_tok[slot] = shipment.next_tok
        req.slot = slot
        self.active[slot] = req
        self._note_pages()
        return True

    # -- chunked prefill straight into block-table pages ---------------
    def _start_chunked(self, req: RequestState) -> bool:
        if not self.paged.has_seq:
            # recurrent families: state is slot-dense, keep the buffer path
            return super()._start_chunked(req)
        if self._prefilling is not None:
            return False
        slot = self._claim(req)     # reserves prompt pages, maps shared ones
        if slot is None:
            return False
        # Shared-prefix compute skip: pages mapped from the trie already
        # hold this prompt's leading KV, so extension starts at the
        # shared-page boundary instead of recomputing resident chunks
        # (their writes were being routed to the scratch page anyway —
        # pure wasted compute).  At least the final prompt token is kept
        # so the last chunk's logits still yield the first output token,
        # which also covers the exact-tail case where the *whole* prompt
        # is resident.
        n = len(req.prompt)
        resident = min(int(self.paged.shared_count[slot])
                       * self.ecfg.page_size, n)
        start = min(resident, n - 1)
        self.prefill_tokens_skipped += start
        self._prefilling = {"req": req, "slot": slot, "pos": start,
                            "t0": time.perf_counter(), "logits": None,
                            "direct": True}
        return True

    def _prefill_chunk_tick(self) -> bool:
        """Advance the in-flight prefill by ONE chunk, writing it directly
        into the slot's pages (gather window -> extend_step -> scatter
        chunk) — no dense staging buffer, no admission-time copy."""
        st = self._prefilling
        if st is None or not st.get("direct"):
            return super()._prefill_chunk_tick()
        tr = self.tracer
        t_ck0 = time.perf_counter() if tr.enabled else 0.0
        req, chunk, slot = st["req"], self.ecfg.prefill_chunk, st["slot"]
        n = len(req.prompt)
        take = min(chunk, n - st["pos"])
        toks = jnp.asarray(req.prompt[None, st["pos"]: st["pos"] + take])
        view = self.paged.gather_slot(slot, st["pos"])
        logits, view = self._extend(self.params, toks, view)
        # no sync: the scatter chains on the extend on device; only the
        # final chunk's argmax (below) fetches a value
        self.paged.scatter_chunk(slot, view, st["pos"], take)
        st["pos"] += take
        st["logits"] = logits
        if st["pos"] < n:
            if tr.enabled:
                tr.emit("prefill_chunk", ts=t_ck0,
                        dur=time.perf_counter() - t_ck0, slot=slot,
                        rid=req.rid, tokens=take, pos=st["pos"],
                        last=False)
            return False
        # prompt fully consumed: publish prefix pages, activate the slot
        self.paged.commit_prefix(slot)
        self._lengths_host[slot] = n
        self._note_pages()
        first = int(jnp.argmax(st["logits"][0, : self.cfg.vocab]))
        self._next_tok[slot] = first
        req.slot = slot
        req.prefill_done_s = time.perf_counter() - st["t0"]
        req.first_token_s = time.perf_counter()
        req.tokens_out.append(first)
        if tr.enabled:
            tr.emit("prefill_chunk", ts=t_ck0,
                    dur=req.first_token_s - t_ck0, slot=slot, rid=req.rid,
                    tokens=take, pos=n, last=True)
        self._activate(slot, req)
        self._prefilling = None
        return True

    def kv_report(self) -> dict:
        # _init_cache reconciled the engine's max_seq with the paged
        # cache's page-rounded window; occupancy math is wrong if the two
        # (or the table capacity) ever drift apart again
        assert (self.paged.max_seq == self.ecfg.max_seq
                == self.paged.max_blocks * self.ecfg.page_size), \
            "engine max_seq out of sync with page-table capacity"
        used = sum(len(r.prompt) + len(r.tokens_out)
                   for r in self.active.values())
        rep = {"mode": "paged",
               "reserved_tokens": self.paged.kv_tokens_resident(),
               "peak_tokens": self.pages_peak * self.ecfg.page_size,
               "used_tokens": used,
               "logical_peak_pages": self.pages_logical_peak,
               "dedup_ratio_peak": self.dedup_ratio_peak,
               "defrag_runs": self.defrag_runs,
               "prefill_skipped_tokens": self.prefill_tokens_skipped,
               "migrated_pages": self.paged.migrated_pages,
               "migration_cost_s": self.paged.migration_cost_s,
               "shipped_pages": self.paged.shipped_pages,
               "ship_cost_s": self.paged.ship_cost_s}
        rep.update(self.paged.sharing_report())
        if self.paged.placement is not None:
            steps = max(1, self._gather_cost_steps)
            rep.update(self.paged.placement_report().to_dict())
            rep["region_peak"] = {str(r): u
                                  for r, u in self._region_peak.items()}
            rep["gather_cost_mean_s"] = self._gather_cost_sum / steps
            rep["gather_concentration_mean"] = (
                self._gather_conc_sum / steps
                if self._gather_cost_steps else 1.0)
        return rep

    # -- decode --------------------------------------------------------
    def _pre_decode_grow(self) -> None:
        """Grow every active slot to cover the token this step writes;
        preempt the youngest request when the pool runs dry."""
        tr = self.tracer
        pages0 = (self.paged.pages_in_use()
                  if tr.enabled and self.paged.has_seq else 0)
        for slot in sorted(self.active):
            if slot not in self.active:      # preempted mid-loop
                continue
            need = num_blocks(int(self._lengths_host[slot]) + 1,
                              self.ecfg.page_size)
            if need > self.paged.max_blocks:
                # preemption can never fix a max_seq overflow — don't
                # evict innocents on the way to an inevitable failure
                raise RuntimeError(
                    f"slot {slot} context {self._lengths_host[slot] + 1} "
                    f"exceeds max_seq={self.paged.max_seq}")
            while not self.paged.extend_slot(
                    slot, int(self._lengths_host[slot]) + 1):
                victim = self._pick_victim(exclude=slot)
                if victim is None:
                    raise RuntimeError(
                        "page pool exhausted with no preemptible request")
                self._preempt(victim)
            if self.paged.share:
                # the write may target a shared page (identical-prompt
                # tail): fork it now so the jitted scatter / Pallas kernel
                # only ever writes exclusively-owned pages
                while not self.paged.cow_for_write(
                        slot, int(self._lengths_host[slot])):
                    victim = self._pick_victim(exclude=slot)
                    if victim is None:
                        raise RuntimeError(
                            "page pool exhausted with no preemptible "
                            "request (copy-on-write fork)")
                    self._preempt(victim)
        self._note_pages()
        if tr.enabled and self.paged.has_seq:
            grown = self.paged.pages_in_use() - pages0
            if grown > 0:
                tr.emit("grow", pages=grown)

    def _pick_victim(self, exclude: int) -> Optional[int]:
        cands = [s for s in self.active if s != exclude]
        if not cands:
            return None
        # youngest request (latest arrival) loses its pages
        return max(cands, key=lambda s: (self.active[s].arrival_s,
                                         self.active[s].rid))

    def _preempt(self, slot: int) -> None:
        req = self.active.pop(slot)
        if self.tracer.enabled:
            self.tracer.emit("preempt", slot=slot, rid=req.rid,
                             preemptions=req.preemptions + 1)
        self._release(slot)
        req.reset_generation()
        req.preemptions += 1
        self.preemption_count += 1
        self.requeue.append(req)

    def _decode_batch(self, toks: jax.Array) -> jax.Array:
        ecfg = self.ecfg
        self._note_gather_cost()
        lengths_pre = self._lengths_host.copy()
        active = np.zeros((ecfg.max_batch,), bool)
        for s in self.active:
            active[s] = True
        if (ecfg.use_pallas_decode and self.paged.has_seq
                and self.cfg.family in _ATTN_FAMILIES):
            logits = self._decode_paged_pallas(toks, active)
        else:
            cache = self.paged.gather()
            logits, new_cache = self._decode(self.params, cache, toks)
            self.paged.scatter_token(new_cache, lengths_pre, active)
        self._lengths_host[active] += 1
        return logits

    def _decode_paged_pallas(self, toks: jax.Array,
                             active: np.ndarray) -> jax.Array:
        """Block-table read-through decode: no dense gather materialized."""
        from repro.kernels import ops as kops
        mod, cfg, tp = self.entry.module, self.cfg, self.tp
        if self._paged_decode is None:
            attn_fn = (lambda q, kc, vc, t, ln:
                       kops.attention_decode_paged(q, kc, vc, t, ln))
            self._paged_decode = jax.jit(
                lambda params, tokens, kp, vp, tables, lengths:
                mod.decode_step_paged(params, cfg, tokens, kp, vp,
                                      tables, lengths, tp=tp,
                                      attn_fn=attn_fn),
                donate_argnums=(2, 3))
        seq_idx = [i for i, s in enumerate(self.paged.is_seq) if s]
        assert len(seq_idx) == 2, "pallas paged decode expects k/v pools"
        ki, vi = seq_idx
        store = list(self.paged.store)
        lengths = jnp.asarray(
            np.where(active, self._lengths_host, 0), jnp.int32)
        # a lane outside the decode batch can still have pages mapped (a
        # slot mid chunked-prefill — with sharing, possibly live *shared*
        # prefix pages): the kernel writes each lane's K/V unconditionally,
        # so route every inactive lane's window to the scratch page.  The
        # table itself comes from the incrementally maintained device
        # mirror — no per-tick numpy rebuild/upload — and the masking runs
        # on device over that mirror
        t = jnp.where(jnp.asarray(active)[:, None],
                      self.paged.tables_device(), self.paged.num_pages)
        logits, (kp, vp, new_len) = self._paged_decode(
            self.params, toks, store[ki], store[vi], t, lengths)
        store[ki], store[vi] = kp, vp
        # the lengths leaf is the only rank-1 non-seq leaf the step advances
        li = [i for i, s in enumerate(self.paged.is_seq)
              if not s and store[i].ndim == 1]
        assert len(li) == 1
        store[li[0]] = jnp.where(jnp.asarray(active), new_len,
                                 store[li[0]])
        self.paged.store = store
        return logits

    # -- fused multi-step decode (device-resident lax.scan core) -------
    def tick(self) -> int:
        if (self.ecfg.fuse_steps <= 1 or not self.paged.has_seq
                or self.cfg.family not in _ATTN_FAMILIES
                or self.role == "prefill"
                or not hasattr(self.entry.module, "decode_fused_paged")):
            return super().tick()
        return self._fused_tick()

    def _fused_horizon(self) -> int:
        """K = min(fuse_steps, steps until any active slot crosses its
        mapped page window, min remaining decode budget) — computed from
        ``_lengths_host`` so nothing inside the scan ever needs a page
        allocation, and budget finishes land exactly on the final step.
        Token-level eos cannot be predicted from host state; those lanes
        freeze on device instead (``emitted`` masks their tail steps)."""
        ps = self.ecfg.page_size
        k = self.ecfg.fuse_steps
        clamp = "fuse_steps"            # which constraint set the horizon
        for slot, req in self.active.items():
            cap = (len(self.paged.blocks_of(slot)) * ps
                   - int(self._lengths_host[slot]))
            if cap < k:
                k, clamp = cap, "page_edge"
            bud = self._budget(req) - len(req.tokens_out)
            if bud < k:
                k, clamp = bud, "budget"
        self._last_horizon_clamp = clamp
        return max(1, k)

    def _cow_horizon(self, k: int) -> None:
        """Fork every shared page the next ``k`` writes can touch.
        Shared pages are immutable while their refcount is > 1, so
        forking at the horizon boundary is content-identical to forking
        at the write step — only the fork's *timing* moves, never the
        copied bytes.  Preempts on fork-allocation failure, exactly like
        the per-step CoW pass."""
        if not self.paged.share:
            return
        ps = self.ecfg.page_size
        for slot in sorted(self.active):
            if slot not in self.active:      # preempted mid-loop
                continue
            ln = int(self._lengths_host[slot])
            for blk in range(ln // ps, (ln + k - 1) // ps + 1):
                while not self.paged.cow_for_write(slot, blk * ps):
                    victim = self._pick_victim(exclude=slot)
                    if victim is None:
                        raise RuntimeError(
                            "page pool exhausted with no preemptible "
                            "request (fused copy-on-write fork)")
                    self._preempt(victim)
        self._note_pages()

    def _fused_fn(self, n_steps: int):
        """Jitted K-step scan, cached per bucketed horizon length."""
        fn = self._fused_jits.get(n_steps)
        if fn is None:
            mod, cfg, tp = self.entry.module, self.cfg, self.tp
            attn_fn = None
            if self.ecfg.use_pallas_decode:
                from repro.kernels import ops as kops
                attn_fn = (lambda q, kc, vc, t, ln:
                           kops.attention_decode_paged(q, kc, vc, t, ln))
            eos = self.ecfg.eos_id
            fn = jax.jit(
                lambda params, toks, kp, vp, tables, lengths, alive, ka:
                mod.decode_fused_paged(params, cfg, toks, kp, vp, tables,
                                       lengths, alive, ka, n_steps,
                                       tp=tp, attn_fn=attn_fn,
                                       eos_id=eos),
                donate_argnums=(2, 3))
            self._fused_jits[n_steps] = fn
        return fn

    def _fused_tick(self) -> int:
        """One scheduler tick = one prefill chunk + a K-step fused scan.

        The host surfaces only here, at the fusion-horizon boundary:
        admission/chunk advance, page growth + preemption, CoW forks,
        then ONE device dispatch and ONE fetch for all K tokens, then
        finish bookkeeping.  Falls back to the per-step path when the
        horizon degenerates to a single step."""
        t_tick0 = time.perf_counter()
        ecfg = self.ecfg
        pf_tokens = pf_ctx = 0
        if self._chunkable():
            st = self._prefilling
            if st is not None and self._tick_model is not None:
                pf_tokens = min(ecfg.prefill_chunk,
                                len(st["req"].prompt) - st["pos"])
                pf_ctx = st["pos"] + pf_tokens
            self._prefill_chunk_tick()
        t_dec0 = time.perf_counter() if self.tracer.enabled else 0.0
        if not self.active:
            self._note_tick(0, [], pf_tokens, pf_ctx)
            if self.tracer.enabled:
                self._trace_gauges()
            return 0
        self._pre_decode_grow()
        k = self._fused_horizon()
        if k <= 1:
            if self._tick_model is not None:
                ctxs = [len(r.prompt) + len(r.tokens_out)
                        for r in self.active.values()]
                self._note_tick(len(ctxs), ctxs, pf_tokens, pf_ctx)
            n_fin = self.step()
            if self.tracer.enabled:
                self._trace_gauges()
            return n_fin
        self._cow_horizon(k)
        self._note_gather_cost()     # one placement sample per fused tick
        base_ctx = {s: len(r.prompt) + len(r.tokens_out)
                    for s, r in self.active.items()}
        active = np.zeros((ecfg.max_batch,), bool)
        for s in self.active:
            active[s] = True
        act_dev = jnp.asarray(active)
        lengths = jnp.asarray(np.where(active, self._lengths_host, 0),
                              jnp.int32)
        toks = jnp.asarray(self._next_tok)
        # inactive lanes (mid chunked-prefill slots can hold live shared
        # pages) route to the scratch page, on device, over the mirror
        tables = jnp.where(act_dev[:, None], self.paged.tables_device(),
                           self.paged.num_pages)
        seq_idx = [i for i, s in enumerate(self.paged.is_seq) if s]
        assert len(seq_idx) == 2, "fused decode expects k/v pools"
        ki, vi = seq_idx
        store = list(self.paged.store)
        # bucket the scan length to a power of two: a handful of compiled
        # horizons serve every K, and lanes freeze at idx >= k on device
        n_steps = 1 << (k - 1).bit_length()
        fn = self._fused_fn(n_steps)
        t_dev0 = time.perf_counter()
        tok_seq, emit_seq, kp, vp, new_len = fn(
            self.params, toks, store[ki], store[vi], tables, lengths,
            act_dev, jnp.asarray(k, jnp.int32))
        tok_h = np.asarray(tok_seq)      # the single per-horizon fetch
        emit_h = np.asarray(emit_seq)
        t_dev1 = time.perf_counter()
        store[ki], store[vi] = kp, vp
        li = [i for i, s in enumerate(self.paged.is_seq)
              if not s and store[i].ndim == 1]
        assert len(li) == 1
        store[li[0]] = jnp.where(act_dev, new_len, store[li[0]])
        self.paged.store = store
        finished = self._apply_fused(tok_h, emit_h, k, t_dev0, t_dev1)
        if self._tick_model is not None:
            # post-hoc per-step attribution: step j's batch is the lanes
            # that actually ran it (eos'd lanes drop out mid-horizon, as
            # they would tick-by-tick); the prefill chunk rides step 0
            for j in range(k):
                ctxs = [base_ctx[s] + j for s in base_ctx
                        if emit_h[j, s]]
                self._note_tick(len(ctxs), ctxs,
                                pf_tokens if j == 0 else 0,
                                pf_ctx if j == 0 else 0)
        t_tick1 = time.perf_counter()
        self._fused_ticks += 1
        self._fused_steps_sum += k
        self.fused_horizons.append(k)
        dev = t_dev1 - t_dev0
        self._fused_device_s += dev
        self._fused_host_s += (t_tick1 - t_tick0) - dev
        if self.tracer.enabled:
            # the span starts after the co-scheduled prefill chunk so
            # prefill/decode phases stay disjoint in trace_report
            self.tracer.emit("fused_tick", ts=t_dec0,
                             dur=t_tick1 - t_dec0, batch=len(base_ctx),
                             horizon=k, clamp=self._last_horizon_clamp,
                             device_s=dev, finished=finished)
            self._trace_gauges()
        return finished

    def _trace_gauges(self) -> None:
        args = {"active": len(self.active),
                "free_slots": len(self.free_slots)}
        if self.paged.has_seq:
            args["free_pages"] = self.paged.alloc.free_pages
            if self.paged.placement is not None:
                free = self.paged.alloc.region_free()
                slot_free = [free[r] for r in free if r >= 0]
                if slot_free:
                    args["min_region_free"] = min(slot_free)
        if self._tick_model is not None and self.modeled_time_s > 0:
            toks = (sum(len(r.tokens_out) for r in self.completed)
                    + sum(len(r.tokens_out) for r in self.active.values()))
            args["modeled_tokens_per_s"] = toks / self.modeled_time_s
        self.tracer.emit("gauge", **args)

    def _apply_fused(self, tok_seq: np.ndarray, emit_seq: np.ndarray,
                     k: int, t0: float, t1: float) -> int:
        """Host bookkeeping for one fused tick: append each lane's
        emitted tokens, advance host lengths, retire finished requests.
        ``emit_seq[j, slot]`` masks the steps a lane actually ran —
        a lane frozen by an eos mid-horizon emits nothing afterwards, so
        every append MUST stay behind the emit guard (the mirror-drift
        checker's fused-emit-guard invariant; an unguarded append
        double-counts the finished lane's last token)."""
        ecfg = self.ecfg
        times = [t0 + (j + 1) * (t1 - t0) / k for j in range(k)]
        finished = 0
        for slot, req in list(self.active.items()):
            last_t = t1
            for j in range(k):
                if not emit_seq[j, slot]:
                    continue
                req.tokens_out.append(int(tok_seq[j, slot]))
                req.token_times.append(times[j])
                last_t = times[j]
                self._lengths_host[slot] += 1
            hit_eos = (ecfg.eos_id >= 0
                       and req.tokens_out[-1] == ecfg.eos_id)
            budget = self._budget(req)
            if hit_eos or len(req.tokens_out) >= budget:
                req.finish_s = last_t
                req.finish_reason = (
                    "eos" if (hit_eos or budget < ecfg.max_new_tokens)
                    else "budget")
                self.completed.append(req)
                if self.tracer.enabled:
                    self.tracer.emit("finish", ts=last_t, slot=slot,
                                     rid=req.rid,
                                     reason=req.finish_reason,
                                     tokens=len(req.tokens_out))
                del self.active[slot]
                self._release(slot)
                finished += 1
            else:
                self._next_tok[slot] = req.tokens_out[-1]
        return finished

    def fused_report(self) -> dict:
        """Fused-tick accounting ({} before any fused tick ran)."""
        if not self._fused_ticks:
            return {}
        tot = self._fused_host_s + self._fused_device_s
        return {"fused_ticks": self._fused_ticks,
                "fused_steps_mean": (self._fused_steps_sum
                                     / self._fused_ticks),
                "host_frac": self._fused_host_s / tot if tot > 0 else 0.0}

    def reset_fused_counters(self) -> None:
        """Zero the fused-tick accounting — warmup-then-measure drivers
        call this between the compile run and the timed run so the
        report covers only the measured region."""
        self._fused_ticks = 0
        self._fused_steps_sum = 0
        self._fused_host_s = 0.0
        self._fused_device_s = 0.0
        self.fused_horizons = []


def make_engine(entry: registry.ArchEntry, ecfg: EngineConfig,
                tp: int = 1, mesh=None) -> ServingEngine:
    cls = PagedServingEngine if ecfg.paged else ServingEngine
    return cls(entry, ecfg, tp=tp, mesh=mesh)
