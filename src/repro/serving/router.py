"""Multi-replica front-end router (PR 3).

The paper's scheduling framework distributes decode work across many
compute stacks; this module is the serving-layer counterpart: a front
end that owns N engine replicas (each one a
:class:`~repro.serving.scheduler.Scheduler` around a ``ServingEngine``)
and dispatches an arrival trace across them under a pluggable policy:

* ``round_robin`` — cycle replicas in rid order;
* ``least_loaded`` — fewest resident+queued requests, ties broken by
  most free pages (both straight from ``load_report``);
* ``session_affinity`` — a session's first request is placed
  least-loaded, every later request of the same session sticks to that
  replica (KV locality for multi-turn traffic);
* ``prefix_affinity`` — probe each replica's ``PrefixIndex`` for the
  request's leading prompt pages (``prefix_residency``) and route to the
  replica already holding the most of them, so PR 2's refcounted dedup
  *compounds* on one replica instead of fragmenting a prefix group's
  pages across all of them.  Before the first holder's pages commit, a
  host-side hint map (first-page token bytes -> replica) keeps a burst
  of same-prefix arrivals together; with no signal at all it falls back
  to least-loaded.

Dispatch is a pure host-side decision; replicas then run their own
continuous-batching loops, so a preempted request always re-enters the
replica that holds its history.  The same policies are mirrored
analytically in ``core/serving_sim.py::simulate_cluster``.

Prefill/decode disaggregation (PR 10)
-------------------------------------
``tiers=(P, D)`` splits the cluster: replicas ``0..P-1`` are the
prefill tier (their engines take ``role="prefill"`` — they run prompt
chunks but never decode), ``P..P+D-1`` the decode tier.  Arrivals go to
the least-loaded prefill replica; when a request's prefill completes
the router harvests it — ``export_slot_pages`` on the source packages
the KV pages + block-table row + prefix-trie coverage as a
:class:`~repro.serving.paged_cache.PageShipment` priced by
``core/noc.py::page_ship`` — and imports it into a decode replica
chosen by prefix residency then ``min_region_free`` pressure.  Tokens
are bit-identical to a colocated run: the first token is argmaxed at
the prefill boundary on the source replica and travels with the
shipment.  A shipment that no decode replica can take is deferred in
place and retried next tick.
"""
from __future__ import annotations

import time
from dataclasses import replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.metrics import serving_registry
from repro.obs.tracer import NULL_TRACER
from repro.serving.paged_cache import num_blocks
from repro.serving.scheduler import RequestState, Scheduler

POLICIES = ("round_robin", "least_loaded", "session_affinity",
            "prefix_affinity")


class Router:
    """Front end owning N engine replicas and a dispatch policy.

    ``engines`` need only the :class:`repro.serving.replica_api.Replica`
    protocol (``admit`` / ``tick`` / ``busy`` / ``load_report`` /
    ``requeue`` / ``export_slot_pages`` / ``import_slot_pages``, plus
    ``completed`` and ``prefix_residency`` for prefix affinity) — unit
    tests drive the policies with stub replicas.
    """

    def __init__(self, engines: Sequence, policy: str = "round_robin",
                 tiers: Optional[Tuple[int, int]] = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"choose from {POLICIES}")
        if not engines:
            raise ValueError("router needs at least one replica")
        self.engines = list(engines)
        self.schedulers = [Scheduler(e) for e in self.engines]
        self.policy = policy
        self.tiers: Optional[Tuple[int, int]] = None
        self.prefill_idx: Tuple[int, ...] = ()
        self.decode_idx: Tuple[int, ...] = ()
        if tiers is not None:
            p, d = int(tiers[0]), int(tiers[1])
            if p < 1 or d < 1:
                raise ValueError("tiers needs >=1 prefill and >=1 "
                                 f"decode replica, got {p}:{d}")
            if p + d != len(engines):
                raise ValueError(f"tiers {p}:{d} must sum to the "
                                 f"{len(engines)} replicas")
            self.tiers = (p, d)
            self.prefill_idx = tuple(range(p))
            self.decode_idx = tuple(range(p, p + d))
            for i in self.prefill_idx:
                self.engines[i].role = "prefill"
            for i in self.decode_idx:
                self.engines[i].role = "decode"
        self._rr = 0
        self._sessions: Dict[int, int] = {}
        self._prefix_hint: Dict[bytes, int] = {}
        # (rid, replica) in dispatch order — deterministic policy audit
        self.dispatch_log: List[Tuple[int, int]] = []
        # (rid, src, dst) per shipped handoff — deterministic tier audit
        self.ship_log: List[Tuple[int, int, int]] = []
        self.shipments = 0
        self.shipped_pages = 0
        self.ship_cost_s = 0.0
        self._tracer = NULL_TRACER

    def set_tracer(self, tracer) -> None:
        """Attach one shared tracer: replica ``i``'s engine gets the
        ``for_replica(i)`` view (its Perfetto process id); the router's
        own dispatch events carry the chosen replica."""
        self._tracer = tracer
        for i, eng in enumerate(self.engines):
            if hasattr(eng, "set_tracer"):
                eng.set_tracer(tracer, replica=i)

    # -- policy --------------------------------------------------------
    def _load_score(self, i: int) -> Tuple[int, int, int, int]:
        rep = self.engines[i].load_report()
        backlog = rep.queue_depth + len(self.schedulers[i].pending)
        # placement-aware tiebreak: of two replicas with equal total
        # headroom, prefer the one whose scarcest per-channel region has
        # the most free pages — an affinity admission there stays
        # co-located instead of spilling across the NoC (replicas
        # without a placement map report min_region_free == free_pages,
        # so the extra component is inert for them)
        return (backlog, -rep.free_pages, -rep.min_region_free, i)

    def _least_loaded(self, among: Optional[Sequence[int]] = None) -> int:
        return min(among if among is not None
                   else range(len(self.engines)), key=self._load_score)

    def _prefix_key(self, prompt: np.ndarray) -> bytes:
        """Hint-map key: the first full page of prompt tokens (whole
        prompt when shorter than a page — the exact-tail-sharing case)."""
        page = getattr(getattr(self.engines[0], "ecfg", None),
                       "page_size", 16)
        head = np.ascontiguousarray(prompt[:page], dtype=np.int64)
        return head.tobytes()

    def select(self, req: RequestState) -> int:
        n = len(self.engines)
        if self.tiers is not None:
            # disaggregated: arrivals always land on the prefill tier;
            # the decode placement decision happens at harvest time
            return self._least_loaded(self.prefill_idx)
        if self.policy == "round_robin":
            i = self._rr % n
            self._rr += 1
            return i
        if self.policy == "least_loaded":
            return self._least_loaded()
        if self.policy == "session_affinity":
            sid = req.session if req.session is not None else req.rid
            if sid not in self._sessions:
                self._sessions[sid] = self._least_loaded()
            return self._sessions[sid]
        # prefix_affinity
        res = [eng.prefix_residency(req.prompt) for eng in self.engines]
        best = max(res)
        if best > 0:
            ties = [i for i, v in enumerate(res) if v == best]
            return ties[0] if len(ties) == 1 else self._least_loaded(ties)
        hint = self._prefix_hint.get(self._prefix_key(req.prompt))
        return hint if hint is not None else self._least_loaded()

    def dispatch(self, req: RequestState) -> int:
        i = self.select(req)
        if self.policy == "prefix_affinity":   # only reader of the hints
            self._prefix_hint[self._prefix_key(req.prompt)] = i
        self.dispatch_log.append((req.rid, i))
        if self._tracer.enabled:
            self._tracer.emit("dispatch", replica=i, rid=req.rid,
                              policy=self.policy)
        self.schedulers[i].enqueue(req)
        return i

    # -- tier handoff (prefill -> decode page shipping) ----------------
    def _decode_target(self, req: RequestState, need: int
                       ) -> Optional[int]:
        """Decode replica for a finished prefill: among replicas with a
        free slot and ``need`` free pages (conservative — prefix sharing
        on import only shrinks the bill), prefer the one already holding
        the most of the request's prefix pages, then break ties by load
        with ``min_region_free`` pressure.  ``None``: defer, retry."""
        reports = {j: self.engines[j].load_report()
                   for j in self.decode_idx}
        fit = [j for j in self.decode_idx
               if reports[j].free_slots > 0
               and reports[j].free_pages >= need]
        if not fit:
            return None
        res = {j: self.engines[j].prefix_residency(req.prompt)
               for j in fit}
        best = max(res.values())
        ties = [j for j in fit if res[j] == best] if best > 0 else fit
        return min(ties, key=self._load_score)

    def _ship_ready(self) -> int:
        """Harvest finished prefills off the prefill tier and ship each
        to its decode target.  Requests still mid chunked-prefill export
        as ``None`` (deferred); a target refusal re-imports into the
        source (which just freed exactly those pages) and retries next
        tick, so a handoff is atomic either way."""
        if self.tiers is None:
            return 0
        shipped = 0
        for i in self.prefill_idx:
            src = self.engines[i]
            page = src.ecfg.page_size
            for r in sorted(src.active.values(),
                            key=lambda r: (r.arrival_s, r.rid)):
                need = num_blocks(len(r.prompt), page)
                j = self._decode_target(r, need)
                if j is None:
                    continue        # decode tier full — defer in place
                ship = src.export_slot_pages(r.rid)
                if ship is None:
                    continue        # mid chunked-prefill — defer
                if not self.engines[j].import_slot_pages(ship):
                    ok = src.import_slot_pages(ship)
                    assert ok, "source must re-absorb a refused shipment"
                    continue
                self.shipments += 1
                self.shipped_pages += ship.n_pages
                self.ship_cost_s += ship.cost_s
                self.ship_log.append((r.rid, i, j))
                if self._tracer.enabled:
                    self._tracer.emit(
                        "ship", replica=i, rid=r.rid, pages=ship.n_pages,
                        bytes=ship.bytes_on_wire, cost_s=ship.cost_s,
                        src=i, dst=j)
                shipped += 1
        return shipped

    # -- cluster trace loop --------------------------------------------
    def run_trace(self, reqs: List[RequestState]) -> dict:
        """Dispatch the trace at arrival time and drive every replica's
        scheduling loop to completion; returns aggregate metrics."""
        n_requests = len(reqs)
        pending = sorted(reqs, key=lambda r: (r.arrival_s, r.rid))
        t0 = time.perf_counter()
        while sum(len(e.completed) for e in self.engines) < n_requests:
            now = time.perf_counter() - t0
            while pending and pending[0].arrival_s <= now:
                self.dispatch(pending.pop(0))
            for sch in self.schedulers:
                sch.tick(now)
            self._ship_ready()
            if pending and all(sch.idle() for sch in self.schedulers):
                time.sleep(max(0.0, min(0.01,
                                        pending[0].arrival_s - now)))
        wall = time.perf_counter() - t0
        return self.metrics(wall, t0)

    def metrics(self, wall: float, t0: float) -> dict:
        """Aggregate cluster report + per-replica breakdown.

        ``dedup_ratio_agg`` is the cluster-wide peak logical/physical
        page ratio (sum of per-replica peaks) — the number prefix
        affinity is supposed to push above round-robin's.
        """
        per_replica = []
        all_done: List[RequestState] = []
        logical_peak = physical_peak = 0
        reconfigs = 0
        substrate_cfgs = 0
        modeled_rate = 0.0
        util_sum, util_n = 0.0, 0
        for i, (eng, sch) in enumerate(zip(self.engines,
                                           self.schedulers)):
            m = sch.metrics(wall, t0)
            kv = eng.kv_report()
            # live co-design aggregates (replicas run in parallel, so
            # the cluster's modeled rate is the sum of per-replica rates)
            reconfigs += m.get("reconfigurations", 0)
            # each replica owns its tick model, so the cluster-level
            # figure is the busiest replica's distinct-config count
            substrate_cfgs = max(substrate_cfgs,
                                 m.get("substrate_configs", 0))
            modeled_rate += m.get("modeled_tokens_per_s", 0.0)
            if m.get("modeled_time_s", 0.0) > 0:
                util_sum += m.get("array_util_mean", 0.0)
                util_n += 1
            page = getattr(getattr(eng, "ecfg", None), "page_size", 1)
            phys = kv["peak_tokens"] // max(1, page) \
                if kv["mode"] == "paged" else 0
            logi = kv.get("logical_peak_pages", 0)
            logical_peak += logi
            physical_peak += phys
            per_replica.append({
                "replica": i, "requests": m["requests"],
                "decoded_tokens": m["decoded_tokens"],
                "preemptions": m["preemptions"],
                "kv_peak_tokens": m["kv_peak_tokens"],
                "dedup_ratio_peak": m["kv_dedup_ratio_peak"],
                "tokens_per_s": m["decoded_tokens"] / max(1e-9, wall)})
            all_done.extend(eng.completed)
        e2e = [r.finish_s - t0 - r.arrival_s for r in all_done]
        tbts = []
        for r in all_done:
            if len(r.token_times) > 1:
                tbts.extend(np.diff(r.token_times))
        toks = sum(len(r.tokens_out) for r in all_done)
        # same single-producer registry as Scheduler.metrics: exact
        # samples behind the bucketed summaries, identical statistics
        reg = serving_registry()
        e2e_h = reg.observe_all("e2e_s", e2e)
        tbt_h = reg.observe_all("tpot_s", tbts)
        return {
            "policy": self.policy,
            "replicas": len(self.engines),
            "wall_s": wall,
            "requests": len(all_done),
            "decoded_tokens": toks,
            "tokens_per_s": toks / wall if wall > 0 else 0.0,
            "e2e_p50_s": e2e_h.quantile(50),
            "e2e_p99_s": e2e_h.quantile(99),
            "tbt_mean_s": tbt_h.mean,
            "tbt_p99_s": tbt_h.quantile(99),
            "preemptions": sum(e.preemption_count for e in self.engines),
            "finish_eos": sum(1 for r in all_done
                              if r.finish_reason == "eos"),
            "finish_budget": sum(1 for r in all_done
                                 if r.finish_reason == "budget"),
            "dedup_ratio_agg": (logical_peak / physical_peak
                                if physical_peak else 1.0),
            # live co-design aggregates (0 when no replica runs codesign)
            "reconfigurations": reconfigs,
            "substrate_configs": substrate_cfgs,
            "modeled_tokens_per_s": modeled_rate,
            "array_util_mean": util_sum / util_n if util_n else 0.0,
            # disaggregation channel ("" / 0 for colocated clusters)
            "tiers": (f"{self.tiers[0]}:{self.tiers[1]}"
                      if self.tiers else ""),
            "shipments": self.shipments,
            "shipped_pages": self.shipped_pages,
            "ship_cost_s": self.ship_cost_s,
            "per_replica": per_replica,
            # bucketed cluster-level distribution summaries (live only)
            "hists": reg.summaries()["histograms"],
        }


def make_cluster(entry, ecfg, n_replicas: int, tp: int = 1,
                 policy: str = "round_robin",
                 share_compiled: bool = True,
                 tiers: Optional[Tuple[int, int]] = None) -> Router:
    """Build N identical engine replicas behind a :class:`Router`.

    Each replica gets its OWN ``EngineConfig`` copy (the paged engine
    adopts the page-rounded ``max_seq`` in place) and its own page pool /
    slots.  All replicas are initialized from the same PRNG seed, so
    their parameters are identical and — with ``share_compiled`` — the
    first replica's parameter pytree and jitted prefill/decode/extend
    callables are shared by the rest instead of re-initializing and
    recompiling per replica.

    ``tiers=(P, D)`` disaggregates the cluster (``P + D == n_replicas``;
    requires a paged config — page shipping moves block-table rows).
    """
    from repro.serving.engine import make_engine
    if tiers is not None and not ecfg.paged:
        raise ValueError("tiers requires a paged EngineConfig "
                         "(page shipping moves KV pages)")
    engines = [make_engine(entry, replace(ecfg), tp=tp)
               for _ in range(n_replicas)]
    if share_compiled:
        first = engines[0]
        for eng in engines[1:]:
            eng.params = first.params
            eng._prefill = first._prefill
            eng._decode = first._decode
            eng._extend = first._extend
    return Router(engines, policy=policy, tiers=tiers)
