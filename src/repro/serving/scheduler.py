"""Scheduling loop extracted from ``ServingEngine`` (PR 3).

The engine used to weld trace-driving, admission, and metrics into the
same class as the cache mechanics, which made it impossible to drive
more than one replica.  This module owns everything *above* a replica:

* :class:`RequestState` — one request's lifecycle record;
* trace builders — :func:`make_trace` (Poisson, optionally eos-aware via
  ``eos_rate``), :func:`make_shared_prefix_trace` (one common system
  prompt), :func:`make_grouped_prefix_trace` (N prefix groups with Zipf
  popularity skew — the multi-replica routing workload), and recorded
  replay via :func:`load_trace` / :func:`save_trace`;
* :class:`Scheduler` — the arrival-driven continuous-batching driver for
  ONE engine replica.

A replica is anything exposing the narrow interface the engines
implement:

* ``admit(req) -> bool`` — claim a slot (chunked prefill start or full
  prefill) — False when the replica is saturated;
* ``tick() -> int`` — advance one iteration (at most one prefill chunk
  co-scheduled with one decode step); returns #finished;
* ``load_report() -> LoadReport`` — ``queue_depth`` / ``free_slots`` /
  ``free_pages`` for load-balancing decisions;
* ``requeue`` (list of preempted requests), ``completed``, ``busy()``.

``serving/router.py`` builds the multi-replica front end out of one
:class:`Scheduler` per replica plus a dispatch policy.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.obs.metrics import serving_registry
from repro.obs.tracer import NULL_TRACER


@dataclass
class RequestState:
    rid: int
    prompt: np.ndarray
    arrival_s: float = 0.0
    slot: int = -1
    prefill_done_s: float = 0.0
    tokens_out: List[int] = field(default_factory=list)
    token_times: List[float] = field(default_factory=list)
    finish_s: float = 0.0
    # wall-clock instant the first token was emitted; None until then (a
    # plain 0.0 sentinel would drop a legitimate sample taken at exactly
    # t=0 from the TTFT statistics)
    first_token_s: Optional[float] = None
    preemptions: int = 0
    # eos-aware traces: per-request decode budget sampled at trace build
    # time (None: the engine's max_new_tokens applies); stopping at a
    # sampled budget below max_new_tokens is reported as an "eos" finish
    decode_len: Optional[int] = None
    # router affinity keys (None: keyed by rid / prompt bytes)
    session: Optional[int] = None
    finish_reason: str = ""

    @property
    def done(self) -> bool:
        return self.finish_s > 0.0

    def reset_generation(self) -> None:
        """Drop generated state for re-queueing after a preemption."""
        self.slot = -1
        self.tokens_out = []
        self.token_times = []
        self.prefill_done_s = 0.0
        self.first_token_s = None
        self.finish_reason = ""


# ---------------------------------------------------------------------------
# Trace builders
# ---------------------------------------------------------------------------
def _decode_lens(rng, n: int, eos_rate: Optional[float]
                 ) -> List[Optional[int]]:
    """Geometric early-stop lengths: each decode step "emits eos" with
    probability ``eos_rate``."""
    if not eos_rate:
        return [None] * n
    if not 0.0 < eos_rate <= 1.0:
        raise ValueError(f"eos_rate must be in (0, 1], got {eos_rate}")
    return [int(v) for v in rng.geometric(eos_rate, size=n)]


def make_trace(vocab: int, *, rate_req_s: float, n_requests: int,
               prompt_len: int, seed: int = 0,
               prompt_lens: Optional[np.ndarray] = None,
               eos_rate: Optional[float] = None,
               sessions: Optional[np.ndarray] = None
               ) -> List[RequestState]:
    """Deterministic Poisson trace; identical across engines for a seed.

    ``prompt_lens`` overrides the constant ``prompt_len`` per request
    (skewed-length traces); ``eos_rate`` samples per-request early-stop
    decode lengths (geometric — each step stops with that probability);
    ``sessions`` attaches session ids for affinity routing.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_req_s, size=n_requests)
    arrivals = np.cumsum(gaps)
    if prompt_lens is None:
        prompt_lens = np.full(n_requests, prompt_len, np.int64)
    prompts = [rng.integers(0, vocab, size=int(prompt_lens[i])
                            ).astype(np.int32) for i in range(n_requests)]
    stops = _decode_lens(rng, n_requests, eos_rate)
    return [RequestState(i, prompts[i], arrival_s=float(arrivals[i]),
                         decode_len=stops[i],
                         session=(int(sessions[i]) if sessions is not None
                                  else None))
            for i in range(n_requests)]


def make_shared_prefix_trace(vocab: int, *, rate_req_s: float,
                             n_requests: int, prefix_len: int,
                             tail_len: int, seed: int = 0,
                             eos_rate: Optional[float] = None
                             ) -> List[RequestState]:
    """Poisson trace where every prompt is one common prefix plus a unique
    tail — the shared-system-prompt workload prefix sharing exists for.
    ``prefix_len=0`` degenerates to fully unique prompts.  Deterministic
    per seed, so the same trace can be replayed through dense, paged, and
    sharing engines for token-exact comparison."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_req_s, size=n_requests)
    arrivals = np.cumsum(gaps)
    prefix = rng.integers(0, vocab, size=prefix_len).astype(np.int32)
    stops = _decode_lens(rng, n_requests, eos_rate)
    reqs = []
    for i in range(n_requests):
        tail = rng.integers(0, vocab, size=tail_len).astype(np.int32)
        reqs.append(RequestState(i, np.concatenate([prefix, tail]),
                                 arrival_s=float(arrivals[i]),
                                 decode_len=stops[i]))
    return reqs


def make_grouped_prefix_trace(vocab: int, *, rate_req_s: float,
                              n_requests: int, n_groups: int,
                              prefix_len: int, tail_len: int,
                              skew: float = 1.0, seed: int = 0,
                              eos_rate: Optional[float] = None
                              ) -> List[RequestState]:
    """Multi-tenant shared-prefix trace: ``n_groups`` distinct system
    prompts with Zipf(``skew``) popularity; each request samples a group
    and carries that group's ``prefix_len``-token prefix plus a unique
    tail.  ``session`` is set to the group id, so ``session_affinity``
    and ``prefix_affinity`` routing agree on the ideal placement — this
    is the workload the front-end router's dedup-compounding exists for.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_req_s, size=n_requests)
    arrivals = np.cumsum(gaps)
    prefixes = [rng.integers(0, vocab, size=prefix_len).astype(np.int32)
                for _ in range(n_groups)]
    weights = 1.0 / np.arange(1, n_groups + 1) ** skew
    weights /= weights.sum()
    groups = rng.choice(n_groups, size=n_requests, p=weights)
    stops = _decode_lens(rng, n_requests, eos_rate)
    reqs = []
    for i in range(n_requests):
        g = int(groups[i])
        tail = rng.integers(0, vocab, size=tail_len).astype(np.int32)
        reqs.append(RequestState(i, np.concatenate([prefixes[g], tail]),
                                 arrival_s=float(arrivals[i]),
                                 decode_len=stops[i], session=g))
    return reqs


def save_trace(reqs: List[RequestState], path: str) -> None:
    """Record a trace (arrivals / prompts / decode budgets / sessions) to
    JSON for later replay with :func:`load_trace`."""
    out = [{"rid": r.rid, "arrival_s": r.arrival_s,
            "prompt": [int(t) for t in r.prompt],
            "decode_len": r.decode_len, "session": r.session}
           for r in reqs]
    with open(path, "w") as f:
        json.dump({"requests": out}, f)


def load_trace(path: str, vocab: Optional[int] = None,
               seed: int = 0) -> List[RequestState]:
    """Replay a recorded trace from JSON.

    Each entry carries ``arrival_s`` plus either explicit ``prompt``
    tokens or a ``prompt_len`` (tokens then drawn deterministically from
    ``seed`` — ``vocab`` required); optional ``decode_len`` (early-stop
    budget) and ``session`` (affinity key) pass straight through.
    """
    with open(path) as f:
        data = json.load(f)
    entries = data["requests"] if isinstance(data, dict) else data
    rng = np.random.default_rng(seed)
    reqs = []
    for i, d in enumerate(entries):
        if "prompt" in d:
            prompt = np.asarray(d["prompt"], np.int32)
        else:
            if vocab is None:
                raise ValueError(
                    "trace entries with prompt_len need vocab to draw "
                    "tokens")
            prompt = rng.integers(0, vocab,
                                  size=int(d["prompt_len"])
                                  ).astype(np.int32)
        dl = d.get("decode_len")
        reqs.append(RequestState(
            int(d.get("rid", i)), prompt,
            arrival_s=float(d.get("arrival_s", 0.0)),
            decode_len=None if dl is None else int(dl),
            session=d.get("session")))
    return reqs


# ---------------------------------------------------------------------------
# Single-replica driver
# ---------------------------------------------------------------------------
class Scheduler:
    """Arrival-driven continuous-batching driver for one engine replica.

    Owns the pending queue and the wall clock; the engine owns slots,
    caches, and preemption.  ``run_trace`` reproduces the seed engine's
    scheduling bit-for-bit: preempted requests re-enter before new
    arrivals, at most one prefill chunk is co-scheduled per decode
    iteration, and admission stops at the first refusal (FIFO order is
    never reshuffled).  The router drives the same object incrementally
    via ``enqueue`` + ``tick(now)``.
    """

    def __init__(self, engine):
        self.engine = engine
        self.pending: List[RequestState] = []

    @property
    def tracer(self):
        """The engine's (replica-bound) tracer; stub engines used by the
        policy unit tests don't carry one, so fall back to the null."""
        return getattr(self.engine, "tracer", NULL_TRACER)

    # -- incremental interface (used by the router) --------------------
    def enqueue(self, reqs) -> None:
        if isinstance(reqs, RequestState):
            reqs = [reqs]
        tracer = self.tracer
        if tracer.enabled:
            for r in reqs:
                tracer.emit("arrival", rid=r.rid, arrival_s=r.arrival_s,
                            prompt_len=len(r.prompt))
        self.pending.extend(reqs)
        self.pending.sort(key=lambda r: (r.arrival_s, r.rid))

    @property
    def backlog(self) -> int:
        """Requests queued but not yet resident on the replica."""
        return len(self.pending) + len(self.engine.requeue)

    def idle(self) -> bool:
        return not self.engine.busy() and not self.backlog

    def tick(self, now: float) -> int:
        """One scheduling iteration at wall-time ``now``: re-admit
        preempted requests first, admit arrived pending requests, then
        advance the replica (one prefill chunk + one decode step)."""
        eng = self.engine
        tracer = self.tracer
        while eng.requeue:          # preempted requests re-enter first
            if not eng.admit(eng.requeue[0]):
                break
            r = eng.requeue.pop(0)
            if tracer.enabled:
                tracer.emit("admit", rid=r.rid, slot=r.slot,
                            requeued=True)
        while self.pending and self.pending[0].arrival_s <= now \
                and not eng.requeue:
            if not eng.admit(self.pending[0]):
                break
            r = self.pending.pop(0)
            if tracer.enabled:
                tracer.emit("admit", rid=r.rid, slot=r.slot,
                            requeued=False)
        return eng.tick()

    # -- standalone trace loop ------------------------------------------
    def run_trace(self, reqs: List[RequestState]) -> dict:
        """Drive an explicit request trace to completion and report."""
        n_requests = len(reqs)
        self.enqueue(reqs)
        eng = self.engine
        t0 = time.perf_counter()
        while len(eng.completed) < n_requests:
            now = time.perf_counter() - t0
            self.tick(now)
            if not eng.busy() and self.pending:
                time.sleep(max(0.0, min(0.01,
                                        self.pending[0].arrival_s - now)))
        wall = time.perf_counter() - t0
        return self.metrics(wall, t0)

    def metrics(self, wall: float, t0: float) -> dict:
        eng = self.engine
        tbts, ttfts = [], []
        for r in eng.completed:
            if len(r.token_times) > 1:
                tbts.extend(np.diff(r.token_times))
            if r.first_token_s is not None:
                ttfts.append(r.first_token_s - t0 - r.arrival_s)
        toks = sum(len(r.tokens_out) for r in eng.completed)
        reasons = [r.finish_reason for r in eng.completed]
        kv = eng.kv_report()
        # live co-design channel ({} on engines without it, incl. stubs)
        cd = getattr(eng, "codesign_report", dict)()
        # fused decode-loop channel ({} on per-tick / dense engines)
        fr = getattr(eng, "fused_report", dict)()
        # single producer for every statistical value below: histograms
        # retain the exact samples, so mean/quantile match the old inline
        # np.mean/np.percentile math bit-for-bit
        reg = serving_registry()
        tbt_h = reg.observe_all("tpot_s", tbts)
        ttft_h = reg.observe_all("ttft_s", ttfts)
        reg.observe_all("gather_cost_s",
                        getattr(eng, "gather_cost_samples", []))
        reg.observe_all("fused_horizon",
                        getattr(eng, "fused_horizons", []))
        reg.counter("requests").inc(len(eng.completed))
        reg.counter("decoded_tokens").inc(toks)
        reg.counter("preemptions").inc(eng.preemption_count)
        reg.counter("finish_eos").inc(
            sum(1 for x in reasons if x == "eos"))
        reg.counter("finish_budget").inc(
            sum(1 for x in reasons if x == "budget"))
        return {"wall_s": wall, "requests": len(eng.completed),
                "decoded_tokens": toks,
                # an empty / all-preempted trace can complete at wall == 0
                "tokens_per_s": toks / wall if wall > 0 else 0.0,
                "tbt_mean_s": tbt_h.mean,
                "tbt_p99_s": tbt_h.quantile(99),
                "ttft_mean_s": ttft_h.mean,
                "tpot_mean_s": tbt_h.mean,
                "preemptions": reg.counter("preemptions").value,
                "finish_eos": reg.counter("finish_eos").value,
                "finish_budget": reg.counter("finish_budget").value,
                "kv_mode": kv["mode"],
                "kv_reserved_tokens": kv["reserved_tokens"],
                "kv_peak_tokens": kv["peak_tokens"],
                "kv_logical_peak_pages": kv.get("logical_peak_pages", 0),
                "kv_shared_pages": kv.get("shared_pages", 0),
                "kv_dedup_ratio_peak": kv.get("dedup_ratio_peak", 1.0),
                "cow_forks": kv.get("cow_forks", 0),
                "defrag_runs": kv.get("defrag_runs", 0),
                "prefill_skipped_tokens":
                    kv.get("prefill_skipped_tokens", 0),
                "kv_migrated_pages": kv.get("migrated_pages", 0),
                "kv_migration_cost_s": kv.get("migration_cost_s", 0.0),
                # stack-aware placement (engines with a placement map)
                "placement_policy": kv.get("placement_policy", "none"),
                "kv_gather_cost_mean_s": kv.get("gather_cost_mean_s", 0.0),
                "kv_gather_concentration":
                    kv.get("gather_concentration_mean", 1.0),
                "kv_region_peak": kv.get("region_peak", {}),
                # live co-design (EngineConfig.codesign engines)
                "codesign_substrate": cd.get("substrate", "none"),
                "modeled_time_s": cd.get("modeled_time_s", 0.0),
                "modeled_tokens_per_s": (
                    toks / cd["modeled_time_s"]
                    if cd.get("modeled_time_s") else 0.0),
                "reconfigurations": cd.get("reconfigurations", 0),
                "substrate_configs": cd.get("substrate_configs", 0),
                "array_util_mean": cd.get("array_util_mean", 0.0),
                # fused decode loop (EngineConfig.fuse_steps > 1 engines)
                "fused_ticks": fr.get("fused_ticks", 0),
                "fused_steps_mean": fr.get("fused_steps_mean", 0.0),
                "fused_host_frac": fr.get("host_frac", 0.0),
                # bucketed distribution summaries (live path only — the
                # analytic mirrors report scalar stats, not samples)
                "hists": reg.summaries()["histograms"]}
