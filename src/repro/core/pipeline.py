"""End-to-end decode-step latency/energy for one model on one NMP device.

Builds the per-layer operator graph (projections -> attention -> FFN/MoE),
schedules every operator with the §5 framework, and aggregates time + energy
for one decode iteration (all `batch` requests advance one token).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.energy import EnergyReport
from repro.core.gemm import FP16_BYTES, Gemm
from repro.core.hw import NMPSystem
from repro.core.operators import ModelSpec, layer_ops, layer_ops_tp
from repro.core.schedule import (Mode, OpExec, ceil_div, core_exec,
                                 exec_units, schedule_attention,
                                 schedule_chain, schedule_experts,
                                 schedule_projection, unit_bw, _vector_time,
                                 _vector_ops)
from repro.core import schedule as _sched
from repro.core.gemm import Dataflow
from repro.core.energy import gemm_energy


# Fraction of the cross-device all-reduce left exposed after tile-level
# overlap with neighbouring operators (paper Fig. 9 pipelines collectives
# against expert/linear tiles; the first and last tile chunks stay exposed).
XLINK_EXPOSED = 0.25


@dataclass
class DecodeReport:
    model: str
    system: str
    batch: int
    ctx: int
    time_s: float
    energy: EnergyReport
    op_execs: List[OpExec] = field(default_factory=list)

    @property
    def tokens_per_s(self) -> float:
        return self.batch / self.time_s

    @property
    def logic_energy_per_token_j(self) -> float:
        return self.energy.logic_die_j / self.batch

    def mode_histogram(self) -> Dict[str, int]:
        hist: Dict[str, int] = {}
        for ex in self.op_execs:
            hist[ex.mode] = hist.get(ex.mode, 0) + 1
        return hist


def _schedule_batched_small(sys: NMPSystem, g: Gemm,
                            force_df=None) -> OpExec:
    """count>1 projection-like ops (e.g. MLA per-head absorbs): round-robin
    the replicas over compute units (multi-port slice-packed on SNAKE),
    no cross-PU split."""
    n_units = exec_units(sys)
    bw = unit_bw(sys)
    g1 = g.scaled(count=1)
    cands = (force_df,) if force_df else (Dataflow.IS, Dataflow.OS)
    best = None
    for df in cands:
        ex_c, pk = _sched.slice_pack_exec(sys, g1, df, g.count)
        t_c = (ceil_div(g.count, n_units * pk)
               * max(ex_c.compute_time(sys.freq_hz),
                     ex_c.memory_time(bw / pk)))
        if best is None or t_c < best[0]:
            best = (t_c, ex_c, pk)
    _, ex, pack = best
    waves = ceil_div(g.count, n_units * pack)
    t_unit = max(ex.compute_time(sys.freq_hz), ex.memory_time(bw / pack))
    vec_s = _vector_time(sys, g.nonlinear_elems * g.count)
    time_s = waves * t_unit + vec_s * 0.4
    energy = gemm_energy(sys, macs=g.macs,
                         sram_bytes=ex.sram_bytes * g.count,
                         dram_bytes=ex.dram_bytes * g.count,
                         exec_time_s=time_s,
                         vector_ops=_vector_ops(g.nonlinear_elems * g.count))
    return OpExec(op=g, mode="BATCH-RR", time_s=time_s,
                  compute_s=waves * ex.compute_time(sys.freq_hz),
                  memory_s=waves * ex.memory_time(bw), comm_s=0.0,
                  vector_s=vec_s * 0.4, energy=energy, core=ex)


def decode_step(sys: NMPSystem, spec: ModelSpec, batch: int, ctx: int,
                include_head: bool = True,
                fixed_mode: Optional[Mode] = None,
                tp: int = 1) -> DecodeReport:
    """Latency/energy of one decode iteration on a ``tp``-device NMP system.

    ``tp`` > 1 models the paper's §6.1.3 8-device tensor-parallel setup:
    every operator is Megatron-sharded across devices (attention by heads)
    and each layer pays two cross-device all-reduces of the (B, d_model)
    activation over the host-side links (Duplex/NVLink-class).  Reported
    time is per-system; energy is the per-device logic-die energy times tp.
    ``fixed_mode`` forces a single partitioning mode for every projection
    (paper Fig. 13b's fixed-strategy comparison); default searches per-op.
    """
    lo = layer_ops_tp(spec, batch, ctx, tp)
    execs: List[OpExec] = []

    # projections: chained per-op search (or fixed mode)
    chain_ops = [g for g in lo.projections if g.count == 1]
    small_ops = [g for g in lo.projections if g.count > 1]
    force_df = None
    if fixed_mode is not None:
        force_df = (Dataflow.IS if fixed_mode in _sched.IS_MODES
                    else Dataflow.OS)
    if fixed_mode is None:
        execs.extend(schedule_chain(sys, chain_ops))
    else:
        execs.extend(schedule_projection(sys, g, modes=(fixed_mode,))
                     for g in chain_ops)
    execs.extend(_schedule_batched_small(sys, g, force_df)
                 for g in small_ops)

    # attention (QK, AV pairs) — always head-parallel (§5b)
    attn = list(lo.attention)
    for i in range(0, len(attn), 2):
        execs.append(schedule_attention(sys, attn[i], attn[i + 1]))

    # MoE experts: the fixed-mode study forces their dataflow too
    if lo.experts:
        execs.append(schedule_experts(sys, list(lo.experts),
                                      lo.moe_dispatch_bytes,
                                      force_df=force_df))

    layer_time = sum(e.time_s for e in execs)
    layer_energy = sum((e.energy for e in execs), EnergyReport())

    # Cross-device TP all-reduces (attn-out + ffn-out per layer), ring over
    # the host-side links.  Off-die: charged to time, not logic-die energy.
    # The ST schedules stream output tiles into the collective as they
    # drain (Fig. 9), hiding most of it behind the next operator's tiles;
    # only XLINK_EXPOSED of the wire time + latency stays on the critical
    # path (identical treatment for every substrate under comparison).
    if tp > 1:
        ar_bytes = batch * spec.d_model * FP16_BYTES
        t_ar = 2 * (2 * (tp - 1) / tp * ar_bytes / sys.xlink_bw_bytes
                    + sys.xlink_latency_s)
        layer_time += XLINK_EXPOSED * t_ar

    total_time = layer_time * spec.num_layers
    total_energy = EnergyReport(*[getattr(layer_energy, f) * spec.num_layers
                                  for f in ("mac_j", "sram_j", "dram_j",
                                            "noc_j", "vector_j", "ctrl_j")])
    if include_head:
        head = Gemm("lm_head", m=batch, n=ceil_div(spec.vocab, tp),
                    k=spec.d_model)
        hex_ = (schedule_projection(sys, head) if fixed_mode is None
                else schedule_projection(sys, head, modes=(fixed_mode,)))
        execs.append(hex_)
        total_time += hex_.time_s
        if tp > 1:   # all-gather of the vocab-sharded logits
            total_time += ((tp - 1) / tp * batch * spec.vocab * FP16_BYTES
                           / sys.xlink_bw_bytes + sys.xlink_latency_s)
        total_energy = total_energy + hex_.energy

    if tp > 1:       # system energy = per-device logic+stack energy x tp
        total_energy = EnergyReport(*[getattr(total_energy, f) * tp
                                      for f in ("mac_j", "sram_j", "dram_j",
                                                "noc_j", "vector_j",
                                                "ctrl_j")])

    return DecodeReport(model=spec.name, system=sys.name, batch=batch,
                        ctx=ctx, time_s=total_time, energy=total_energy,
                        op_execs=execs)


def decode_sweep(sys: NMPSystem, spec: ModelSpec,
                 batches: Sequence[int], ctx: int,
                 tp: int = 1) -> List[DecodeReport]:
    return [decode_step(sys, spec, b, ctx, tp=tp) for b in batches]
