"""Energy model for the logic die + DRAM stack (paper §6.2-6.3).

Calibrated so that SNAKE at peak matches the paper's reported logic-die power
breakdown (61.8 W = 38.5 matrix + 14.2 vector + 4.4 PE control + 4.8 NoC at
800 MHz).  Energy ratios between substrates come from (a) execution time
(control/static energy integrates over it), (b) SRAM traffic (MAC trees
broadcast operands; SAs inject at boundaries and reuse in-fabric), and
(c) DRAM traffic (capacity-induced re-reads).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.hw import NMPSystem


@dataclass
class EnergyReport:
    mac_j: float = 0.0
    sram_j: float = 0.0
    dram_j: float = 0.0
    noc_j: float = 0.0
    vector_j: float = 0.0
    ctrl_j: float = 0.0

    @property
    def total_j(self) -> float:
        return (self.mac_j + self.sram_j + self.dram_j + self.noc_j
                + self.vector_j + self.ctrl_j)

    @property
    def logic_die_j(self) -> float:
        """Paper compares logic-die energy (thermal-limited component)."""
        return self.total_j - self.dram_j

    def __add__(self, o: "EnergyReport") -> "EnergyReport":
        return EnergyReport(self.mac_j + o.mac_j, self.sram_j + o.sram_j,
                            self.dram_j + o.dram_j, self.noc_j + o.noc_j,
                            self.vector_j + o.vector_j, self.ctrl_j + o.ctrl_j)


def gemm_energy(sys: NMPSystem, macs: int, sram_bytes: int, dram_bytes: int,
                exec_time_s: float, noc_bytes: int = 0,
                vector_ops: int = 0) -> EnergyReport:
    scale = getattr(sys, "mactree_fetch_energy_scale", 1.0)
    return EnergyReport(
        mac_j=macs * sys.e_mac_pj * 1e-12,
        sram_j=sram_bytes * sys.e_sram_pj_per_byte * scale * 1e-12,
        dram_j=dram_bytes * sys.e_dram_pj_per_byte * 1e-12,
        noc_j=(noc_bytes * sys.e_noc_pj_per_byte * 1e-12
               + sys.noc_idle_power_w * exec_time_s),
        vector_j=vector_ops * sys.e_vector_pj_per_op * 1e-12,
        ctrl_j=sys.ctrl_power_w * exec_time_s,
    )


def peak_power_breakdown(sys: NMPSystem) -> dict:
    """Sanity: power at 100% MAC + vector occupancy (compare paper's 61.8 W)."""
    macs_per_s = sys.pus * sys.macs_per_pu * sys.freq_hz
    vec_per_s = sys.pus * sys.cores_per_pu * sys.vector.lanes * sys.freq_hz
    # Boundary SRAM traffic at peak: every core injects (rows+cols) elems/cyc.
    sub = sys.substrate
    if hasattr(sub, "phys_rows"):
        elems = (sub.phys_rows + sub.phys_cols)
        sram_bps = sys.cores * elems * 2 * sys.freq_hz
    else:
        sram_bps = sys.pus * sub.operand_elems_per_cycle * 2 * sys.freq_hz
    return dict(
        matrix_w=macs_per_s * sys.e_mac_pj * 1e-12,
        vector_w=vec_per_s * sys.e_vector_pj_per_op * 1e-12,
        sram_w=sram_bps * sys.e_sram_pj_per_byte * 1e-12,
        ctrl_w=sys.ctrl_power_w,
        noc_w=sys.noc_idle_power_w + 3.8,  # active collective allowance
    )
