"""Stack-aware page placement: per-channel page regions + gather cost.

The paper's co-design thesis is that decode throughput on a 3D-stacked
NMP substrate is set by how well the serving layer's access pattern
matches the per-channel internal bandwidth layout: each of the 16 PUs
sits under ONE memory channel whose internal bandwidth
(``NMPSystem.dram_bw_per_pu``, ~1.35 TB/s on the Stratum-class template)
dwarfs the PU's NoC injection bandwidth (512 GB/s).  A paged KV gather
whose block table is concentrated in the issuing PU's own channel
streams at channel bandwidth; every page mapped under a *different*
channel must cross the logic-die NoC through the issuing PU's single
injection port and pay a per-segment hop latency.

This module is where that substrate fact meets the serving layer:

* :class:`PlacementMap` partitions the physical page pool into
  per-stack/per-channel *regions* (derived from ``NMPSystem.pus``), plus
  an optional *communal* region at the lowest indices that holds shared
  prefix pages — pages every slot reads, so no slot's home channel is
  favored for them;
* :func:`gather_cost` scores a block table's region histogram against
  the link bandwidths (the DMA model itself is ``core/noc.py``'s
  :func:`~repro.core.noc.page_gather`);
* ``PageAllocator`` (``serving/paged_cache.py``) consumes the map under
  one of three placement policies:

  - ``free-first`` — wherever the free list points (the legacy layout);
  - ``interleave`` — stripe a slot's pages round-robin across regions
    (maximizes aggregate write bandwidth, worst gather concentration);
  - ``affinity``   — co-locate a slot's pages in one home region,
    spilling to the emptiest other region only when home runs dry.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro.core.hw import FP16_BYTES, NMPSystem
from repro.core.noc import page_gather

#: Region id of the communal (shared-prefix) slice of the pool.
COMMUNAL = -1

#: Placement policies understood by ``PageAllocator`` / ``EngineConfig``.
PLACEMENT_POLICIES = ("free-first", "interleave", "affinity")


@dataclass(frozen=True)
class PlacementMap:
    """Static partition of page ids ``0..num_pages-1`` into regions.

    Layout: pages ``[0, communal_pages)`` form the communal region
    (:data:`COMMUNAL`); the remaining pages split into ``n_regions``
    near-equal contiguous slot regions ``0..n_regions-1`` (earlier
    regions absorb the remainder).  Contiguity is what makes
    region-preserving defrag meaningful: compaction targets stay inside
    the same physical channel.
    """

    num_pages: int
    n_regions: int
    communal_pages: int = 0

    def __post_init__(self):
        if self.num_pages <= 0:
            raise ValueError("num_pages must be positive")
        if not 0 <= self.communal_pages < self.num_pages:
            raise ValueError(
                f"communal_pages={self.communal_pages} must leave slot "
                f"pages in a {self.num_pages}-page pool")
        slot_pages = self.num_pages - self.communal_pages
        if not 1 <= self.n_regions <= slot_pages:
            raise ValueError(
                f"n_regions={self.n_regions} needs 1..{slot_pages} for "
                f"{slot_pages} slot pages")
        base, rem = divmod(slot_pages, self.n_regions)
        bounds = [self.communal_pages]
        for r in range(self.n_regions):
            bounds.append(bounds[-1] + base + (1 if r < rem else 0))
        object.__setattr__(self, "_bounds", tuple(bounds))

    @classmethod
    def from_system(cls, sys: NMPSystem, num_pages: int, *,
                    communal_frac: float = 0.0,
                    n_regions: Optional[int] = None) -> "PlacementMap":
        """Derive the partition from the substrate: one region per PU /
        memory channel, capped so every region holds at least one page.
        ``communal_frac`` of the pool is carved off for shared prefix
        pages (0 when prefix sharing is off)."""
        if not 0.0 <= communal_frac < 1.0:
            raise ValueError(f"communal_frac={communal_frac} not in [0,1)")
        communal = int(num_pages * communal_frac)
        slot_pages = num_pages - communal
        want = n_regions if n_regions is not None else sys.pus
        return cls(num_pages, max(1, min(want, slot_pages)), communal)

    # -- geometry ----------------------------------------------------------
    def regions(self) -> Tuple[int, ...]:
        """All region ids, communal (if present) first."""
        slot = tuple(range(self.n_regions))
        return ((COMMUNAL,) + slot) if self.communal_pages else slot

    def region_of(self, page: int) -> int:
        if not 0 <= page < self.num_pages:
            raise ValueError(f"page {page} out of range")
        if page < self.communal_pages:
            return COMMUNAL
        bounds = self._bounds
        lo, hi = 0, self.n_regions
        while lo + 1 < hi:                  # bisect over region bounds
            mid = (lo + hi) // 2
            if page >= bounds[mid]:
                lo = mid
            else:
                hi = mid
        return lo

    def region_pages(self, region: int) -> range:
        if region == COMMUNAL:
            return range(self.communal_pages)
        if not 0 <= region < self.n_regions:
            raise ValueError(f"region {region} out of range")
        return range(self._bounds[region], self._bounds[region + 1])

    def region_size(self, region: int) -> int:
        return len(self.region_pages(region))


# ---------------------------------------------------------------------------
# Gather cost model
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class GatherCost:
    """DMA cost of one slot's block-table gather, as issued by the PU of
    its ``home`` region."""

    home: int
    bytes_local: int
    bytes_remote: int
    remote_regions: int
    time_s: float
    concentration: float    # fraction of pages in the home region


def gather_cost(sys: NMPSystem, region_counts: Mapping[int, int],
                bytes_per_page: int,
                home: Optional[int] = None) -> GatherCost:
    """Score a block table's region histogram against the substrate.

    ``region_counts`` maps region id -> pages the slot has mapped there.
    ``home`` defaults to the majority *slot* region — the PU the
    scheduler would issue the gather from.  The communal region lives
    under its own channel, remote to every slot home, so it is never
    picked as home while any private pages exist (and its pages always
    count against concentration).

        T = B_local / BW_chan + B_remote / BW_noc + R_remote * L_hop / f

    where ``BW_chan = dram_bw_per_pu`` (per-channel internal bandwidth),
    ``BW_noc = noc_link_bw_bytes`` (the issuing PU's single injection
    port — remote bytes funnel through it serially), and ``R_remote`` is
    the number of distinct remote regions (one NoC segment set-up each).
    """
    counts = {r: int(c) for r, c in region_counts.items() if c > 0}
    total = sum(counts.values())
    if total == 0:
        return GatherCost(home if home is not None else 0, 0, 0, 0,
                          0.0, 1.0)
    if home is None:
        # majority among the slot regions, ties to the lowest id; the
        # communal region is never a home while private pages exist —
        # it lives under its own channel, remote to every slot home
        slot_regions = [r for r in counts if r != COMMUNAL]
        home = (min(slot_regions, key=lambda r: (-counts[r], r))
                if slot_regions else COMMUNAL)
    local = counts.get(home, 0) * bytes_per_page
    remote_regions = [r for r in counts if r != home]
    remote = sum(counts[r] for r in remote_regions) * bytes_per_page
    cost = page_gather(sys, local, remote, len(remote_regions))
    return GatherCost(home, local, remote, len(remote_regions),
                      cost.time_s, counts.get(home, 0) / total)


def kv_bytes_per_token(spec) -> int:
    """fp16 K+V bytes one context token holds across all layers — the
    per-page gather payload is ``page_size`` times this."""
    return 2 * spec.num_layers * spec.num_kv_heads * spec.d_head \
        * FP16_BYTES


@functools.lru_cache(maxsize=1)
def default_system() -> NMPSystem:
    """The SNAKE system template — the substrate the real-JAX engine
    scores placement against when no explicit ``NMPSystem`` is given."""
    from repro.core.hw import snake_system
    return snake_system()
