"""H100 decode baseline (paper §6.1.3: Duplex-framework GPU model).

Roofline-style per-operator model with achieved-efficiency factors for the
decode regime (small-M GEMM/GEMV leaves both the tensor cores and HBM well
below peak), kernel launch overhead, and TP=8 NVLink all-reduces per layer.
Energy is board power integrated over time (the paper compares its logic-die
energy against GPU energy the same way).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

from repro.core.gemm import Gemm
from repro.core.hw import GPUConfig, H100
from repro.core.operators import ModelSpec, layer_ops


@dataclass
class GPUDecodeReport:
    model: str
    batch: int
    ctx: int
    time_s: float
    energy_j: float          # per-op silicon + HBM + static (see GPUConfig)
    board_energy_j: float    # wall-plug board power integrated over time
    tp: int

    @property
    def tokens_per_s(self) -> float:
        return self.batch / self.time_s

    @property
    def energy_per_token_j(self) -> float:
        return self.energy_j / self.batch


def _op_time(gpu: GPUConfig, g: Gemm, tp: int) -> float:
    """One operator, weights/work sharded over `tp` GPUs."""
    flops = g.flops / tp
    bytes_ = g.min_dram_bytes / tp
    t = max(flops / (gpu.peak_flops * gpu.compute_efficiency),
            bytes_ / (gpu.hbm_bw_bytes * gpu.mem_efficiency))
    return t


def gpu_decode_step(spec: ModelSpec, batch: int, ctx: int,
                    gpu: GPUConfig = H100, tp: int = 8) -> GPUDecodeReport:
    lo = layer_ops(spec, batch, ctx)
    t_layer = 0.0
    flops = bytes_ = 0.0
    groups = 0
    for g in list(lo.projections) + list(lo.attention) + list(lo.experts):
        t_layer += _op_time(gpu, g, tp)
        flops += g.flops
        bytes_ += g.min_dram_bytes
        groups += 1
    # fused-kernel accounting: ~1 launch per op group
    t_layer += groups * gpu.kernel_overhead_s
    t_ar = 0.0
    if tp > 1:
        # TP: two all-reduces per layer (attention out + FFN out) of the
        # activation tensor, ring over NVLink.
        ar_bytes = batch * spec.d_model * 2
        t_ar = (2 * (2 * (tp - 1) / tp) * ar_bytes / gpu.nvlink_bw_bytes
                + 2 * 4e-6)
    t_layer += t_ar
    total = t_layer * spec.num_layers
    head = Gemm("lm_head", m=batch, n=spec.vocab, k=spec.d_model)
    total += _op_time(gpu, head, tp) + gpu.kernel_overhead_s
    flops = (flops * spec.num_layers + head.flops)
    bytes_ = (bytes_ * spec.num_layers + head.min_dram_bytes)
    energy = (flops * gpu.e_flop_pj * 1e-12
              + bytes_ * gpu.e_hbm_pj_per_byte * 1e-12
              + gpu.static_w * total)
    return GPUDecodeReport(model=spec.name, batch=batch, ctx=ctx,
                           time_s=total, energy_j=energy,
                           board_energy_j=gpu.power_w * max(1, tp) * total,
                           tp=tp)
