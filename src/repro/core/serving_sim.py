"""Serving-level simulator (paper §6.4, Fig. 10; Duplex-style framework).

Poisson request injection -> prefill on the xPU (H100) -> continuous-batching
decode on the device under test (NMP substrate or GPU).  Reports end-to-end
(E2E) latency and time-between-tokens (TBT) under varying request rates.

Deterministic: arrivals use an explicit seeded generator (exponential gaps).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dataflow import mean_utilization
from repro.core.gemm import ceil_div
from repro.core.gpu_model import gpu_decode_step
from repro.core.hw import H100, GPUConfig, NMPSystem
from repro.core.operators import ModelSpec
from repro.core.pipeline import decode_step
from repro.core.noc import page_ship
from repro.core.placement import (COMMUNAL, PLACEMENT_POLICIES,
                                  default_system, gather_cost,
                                  kv_bytes_per_token)
from repro.core.schedule import exec_config, shape_profile
from repro.serving.replica_api import LoadReport


@dataclass
class Request:
    rid: int
    arrival_s: float
    input_len: int
    output_len: int
    prefill_done_s: float = math.inf
    tokens_out: int = 0
    finish_s: float = math.inf
    token_times: List[float] = field(default_factory=list)
    pages_held: int = 0
    prefill_remaining: int = 0
    # cluster routing keys (simulate_cluster): which shared-prefix group
    # the prompt belongs to, and the session affinity id
    group: int = 0
    session: int = 0
    # stack-aware placement (simulate_serving placement=...): private
    # pages per channel region, and the home region chosen at admission
    region_pages: Dict[int, int] = field(default_factory=dict)
    home: int = 0

    def ctx(self) -> int:
        return self.input_len + self.tokens_out


@dataclass
class ServingReport:
    system: str
    model: str
    rate_req_s: float
    e2e_mean_s: float
    e2e_p90_s: float
    tbt_mean_s: float
    completed: int
    # paged / chunked-prefill extensions (defaults keep old call sites)
    ttft_mean_s: float = 0.0
    kv_util_mean: float = 0.0       # time-weighted used/reserved KV tokens
    kv_peak_tokens: int = 0
    max_decode_stall_s: float = 0.0  # longest gap decode waited on prefill
    preemptions: int = 0
    dedup_ratio: float = 1.0        # peak logical/physical pages (sharing)
    # stack-aware placement metrics (placement=... only)
    gather_cost_mean_s: float = 0.0  # mean per-slot block-table DMA cost
    gather_concentration: float = 1.0  # mean majority-channel page share
    region_peak_pages: Tuple[int, ...] = ()  # peak occupancy per region
    # live co-design metrics (TickLatencyModel callers only)
    reconfigurations: int = 0       # cross-tick shape-profile changes
    substrate_configs: int = 0      # distinct per-op configurations seen
    array_util_mean: float = 0.0    # mean per-tick MAC utilization
    makespan_s: float = 0.0         # modeled clock when the last request ends
    decoded_tokens: int = 0
    tokens_per_s: float = 0.0       # decoded_tokens / makespan_s
    # fused decode loop mirror (fuse_steps > 1 only)
    fused_ticks: int = 0            # boundaries that ran a k>1 horizon
    fused_steps_mean: float = 0.0   # mean horizon length over fused ticks

    def normalized_to(self, base: "ServingReport") -> Tuple[float, float]:
        return (self.e2e_mean_s / base.e2e_mean_s,
                self.tbt_mean_s / base.tbt_mean_s)


def _prefill_time(spec: ModelSpec, input_len: int,
                  gpu: GPUConfig = H100, n_gpus: int = 8) -> float:
    flops = 2 * spec.active_params() * input_len
    return flops / (gpu.peak_flops * 0.55 * n_gpus)


class DecodeLatencyModel:
    """Caches per-(batch, ctx-bucket) decode-iteration latency."""

    def __init__(self, step_fn: Callable[[int, int], float],
                 ctx_bucket: int = 1024):
        self.step_fn = step_fn
        self.ctx_bucket = ctx_bucket
        self._cache: Dict[Tuple[int, int], float] = {}

    def __call__(self, batch: int, ctx: int) -> float:
        cb = max(self.ctx_bucket,
                 ((ctx + self.ctx_bucket - 1) // self.ctx_bucket)
                 * self.ctx_bucket)
        key = (batch, cb)
        if key not in self._cache:
            self._cache[key] = self.step_fn(batch, cb)
        return self._cache[key]


def nmp_latency_model(sys: NMPSystem, spec: ModelSpec,
                      tp: int = 1) -> DecodeLatencyModel:
    return DecodeLatencyModel(
        lambda b, c: decode_step(sys, spec, b, c, tp=tp).time_s)


def gpu_latency_model(spec: ModelSpec, tp: int = 8) -> DecodeLatencyModel:
    return DecodeLatencyModel(
        lambda b, c: gpu_decode_step(spec, b, c, tp=tp).time_s)


# ---------------------------------------------------------------------------
# Live microarchitecture-scheduling co-design (composition-keyed ticks)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class TickDecision:
    """One serving tick's substrate decision: the per-operator array
    shape + dataflow configuration the §5 scheduler picked for the tick's
    actual batch composition, and the resulting modeled latency."""

    time_s: float                  # decode_s + prefill_s
    decode_s: float                # decode half of the tick
    prefill_s: float               # co-scheduled prefill-chunk half
    config: tuple                  # exec_config fingerprint (per-op)
    shapes: tuple                  # distinct logical shapes used
    util: float                    # cycle-weighted MAC utilization
    # fill/drain penalty charged because this tick's shape profile
    # differs from the previous tick's on the same stream; 0.0 on
    # non-reconfiguring ticks (and on the memoized cache entry)
    reconfig_s: float = 0.0


class TickLatencyModel:
    """Composition-keyed per-tick latency model — the live co-design loop.

    Where :class:`DecodeLatencyModel` caches a shape-blind
    ``(batch, ctx-bucket)`` scalar, this model reruns the full §5
    scheduling search (``mode_candidates`` / ``best_logical_shape``
    through :func:`~repro.core.pipeline.decode_step`) for the tick's
    actual composition — decode batch size, per-slot context lengths, a
    co-scheduled chunked-prefill span, and the MoE expert fan-out carried
    by ``spec`` — and returns the chosen substrate configuration together
    with its latency.  Results are memoized on a *reduced shape
    signature* (batch, bucketed mean context, bucketed prefill span) so
    the serving hot path stays O(1) per tick after warm-up.

    Reconfiguration accounting: a tick pays a reconfiguration when its
    :func:`~repro.core.schedule.shape_profile` differs from the previous
    tick's on the same ``stream`` (one stream per engine/replica).  A
    non-reconfigurable substrate has a single legal shape, so its count
    stays 0 by construction — the benchmark's fixed-shape baselines.

    Each reconfiguration is *priced*, not just counted: the tick's
    decision carries ``reconfig_s`` — ``reconfig_cost_s`` when given,
    else the substrate's pipeline fill/drain, ``(phys_rows + phys_cols
    - 2 + reconfig_cycles)`` cycles (SystolicArrayConfig's audit note;
    MAC trees have no systolic pipeline, so their derived cost is 0).

    Drop-in compatible with :class:`DecodeLatencyModel` call sites via
    ``__call__(batch, ctx)``; co-design-aware callers use :meth:`step`.
    """

    def __init__(self, sys: NMPSystem, spec: ModelSpec, tp: int = 1,
                 ctx_bucket: int = 256, prefill_bucket: int = 32,
                 reconfig_cost_s: Optional[float] = None):
        self.sys = sys
        self.spec = spec
        self.tp = tp
        self.ctx_bucket = ctx_bucket
        self.prefill_bucket = prefill_bucket
        self._cache: Dict[tuple, TickDecision] = {}
        self._last_shapes: Dict[object, tuple] = {}
        self.reconfigurations = 0
        self.configs_seen: set = set()
        self.reconfig_cost_s = (self._derived_reconfig_cost()
                                if reconfig_cost_s is None
                                else float(reconfig_cost_s))

    def _derived_reconfig_cost(self) -> float:
        """Fill/drain of the new configuration's systolic pipeline."""
        sub = self.sys.substrate
        rows = getattr(sub, "phys_rows", None)
        cols = getattr(sub, "phys_cols", None)
        if rows is None or cols is None:
            return 0.0          # MAC tree: no pipeline to refill
        cycles = rows + cols - 2 + getattr(sub, "reconfig_cycles", 1)
        return cycles / self.sys.freq_hz

    @staticmethod
    def _bucket(v: int, b: int) -> int:
        return max(b, ((v + b - 1) // b) * b) if v > 0 else 0

    def signature(self, batch: int, ctxs: Optional[List[int]],
                  prefill_tokens: int, prefill_ctx: int) -> tuple:
        """The reduced shape signature a tick memoizes on."""
        ctx = (int(np.mean(ctxs)) if ctxs else 0) if batch else 0
        return (batch, self._bucket(ctx, self.ctx_bucket),
                self._bucket(prefill_tokens, self.prefill_bucket),
                self._bucket(prefill_ctx, self.ctx_bucket))

    def _evaluate(self, sig: tuple) -> TickDecision:
        batch, ctx_b, pf_b, pfctx_b = sig
        execs = []
        decode_s = prefill_s = 0.0
        if batch > 0:
            rep = decode_step(self.sys, self.spec, batch, ctx_b,
                              tp=self.tp)
            decode_s = rep.time_s
            execs.extend(rep.op_execs)
        if pf_b > 0:
            # a prefill chunk of c tokens is a step with M = c rows
            # attending the chunk-end context; the lm_head is skipped
            # (only the final chunk's last token samples)
            rep = decode_step(self.sys, self.spec, pf_b,
                              max(pfctx_b, pf_b), include_head=False,
                              tp=self.tp)
            prefill_s = rep.time_s
            execs.extend(rep.op_execs)
        return TickDecision(
            time_s=decode_s + prefill_s, decode_s=decode_s,
            prefill_s=prefill_s, config=exec_config(execs),
            shapes=shape_profile(execs),
            util=mean_utilization([e.core for e in execs
                                   if e.core is not None]))

    def step(self, batch: int, ctxs: Optional[List[int]] = None,
             prefill_tokens: int = 0, prefill_ctx: int = 0,
             stream: object = 0) -> TickDecision:
        """Price one serving tick and record its substrate configuration."""
        sig = self.signature(batch, ctxs, prefill_tokens, prefill_ctx)
        d = self._cache.get(sig)
        if d is None:
            d = self._cache[sig] = self._evaluate(sig)
        last = self._last_shapes.get(stream)
        reconfigured = last is not None and last != d.shapes
        if reconfigured:
            self.reconfigurations += 1
        self._last_shapes[stream] = d.shapes
        self.configs_seen.add(d.config)
        if reconfigured and self.reconfig_cost_s > 0.0:
            # priced copy; the cached entry stays penalty-free so
            # non-reconfiguring ticks keep returning it unchanged
            return replace(d, reconfig_s=self.reconfig_cost_s)
        return d

    def __call__(self, batch: int, ctx: int) -> float:
        d = self.step(batch, [ctx] * max(1, batch))
        return d.time_s + d.reconfig_s


def nmp_tick_model(sys: NMPSystem, spec: ModelSpec, tp: int = 1,
                   ctx_bucket: int = 256,
                   reconfig_cost_s: Optional[float] = None
                   ) -> TickLatencyModel:
    return TickLatencyModel(sys, spec, tp=tp, ctx_bucket=ctx_bucket,
                            reconfig_cost_s=reconfig_cost_s)


def _pages(n_tokens: int, page_size: int) -> int:
    return ceil_div(n_tokens, page_size)


def simulate_serving(latency: DecodeLatencyModel, spec: ModelSpec,
                     rate_req_s: float, *, system: str,
                     n_requests: int = 128, input_len: int = 8192,
                     output_len: int = 1024, max_batch: int = 64,
                     seed: int = 0, cache_mode: str = "dense",
                     page_size: int = 16, num_pages: Optional[int] = None,
                     prefill_chunk: Optional[int] = None,
                     prefill_on_device: bool = False,
                     prefix_sharing: bool = False,
                     shared_prefix_len: int = 0,
                     placement: Optional[str] = None,
                     n_regions: int = 4,
                     hw: Optional[NMPSystem] = None,
                     fuse_steps: int = 1,
                     tracer=None) -> ServingReport:
    """Analytical serving simulation.

    Mirrors the real-JAX engine's two policy axes (same defaults keep the
    seed behavior bit-for-bit):

    * ``cache_mode``: ``"dense"`` reserves ``max_batch x (in+out)`` KV
      tokens; ``"paged"`` admits against a page pool (``num_pages`` of
      ``page_size`` tokens, defaulting to the dense-equivalent capacity),
      grows contexts on demand, and preempts the youngest request when the
      pool runs dry.  ``kv_util_mean`` reports time-weighted used/reserved
      KV — the Fig. 10 paged-vs-dense occupancy comparison.
    * ``prefill_on_device``: instead of the serialized external H100x8
      prefill stream, prefill work runs on the decode device itself.
      Without ``prefill_chunk`` an admission stalls the whole decode batch
      for the full prompt; with it, at most one chunk of prefill is
      co-scheduled per decode iteration (Sarathi), bounding the stall
      (reported as ``max_decode_stall_s``).
    * ``prefix_sharing`` (paged only): every request's first
      ``shared_prefix_len`` prompt tokens are a common system prompt whose
      *full* pages are resident once and mapped by every concurrent
      holder (the engine's refcounted trie, analytically).  The first
      admission materializes the communal prefix pages; later admissions
      reserve only their unshared tail, and the prefix pages free when the
      last holder releases.  ``dedup_ratio`` reports the peak
      logical/physical page ratio — the admissible-batch multiplier per
      resident page.  Tails are unique, so copy-on-write forks never
      trigger in this analytical mirror.
    * ``placement`` (paged only): mirror of the engine's stack-aware page
      placement.  The page pool splits into ``n_regions`` per-channel
      regions (plus a communal region sized exactly for the shared
      prefix, which every holder reads remotely); private pages place
      under ``free-first`` (lowest region first — the legacy free-list
      layout), ``interleave`` (striped round-robin), or ``affinity``
      (home region chosen at admission, spill to the emptiest other
      region).  Each decode iteration scores every active request's
      region histogram with ``core.placement.gather_cost`` on ``hw``
      (default: the SNAKE template) — reported as ``gather_cost_mean_s``
      / ``gather_concentration`` / ``region_peak_pages``.  Placement
      never changes admission (spill keeps success a function of the
      global free count alone), so latency/throughput stay identical
      across policies; the gather-cost metric is what separates them.
    * ``fuse_steps`` (paged only): mirror of the engine's fused decode
      loop.  Each boundary picks a horizon ``k = min(fuse_steps,
      steps-until-any-request-needs-a-new-page, min remaining decode
      budget)`` and runs ``k`` decode iterations with no admission or
      growth in between — exactly when the real engine's ``lax.scan``
      keeps the host out of the loop.  ``fused_ticks`` /
      ``fused_steps_mean`` report how often and how deep the fusion ran.
    * ``tracer``: an :class:`repro.obs.tracer.Tracer` (construct with
      ``t0=0.0``) receiving the same event schema the live engine emits,
      with timestamps on the *modeled* clock — admissions, prefill
      chunks, decode/fused-tick spans (reconfiguration charge split into
      its own ``reconfigure`` event so spans stay disjoint), preemptions,
      and finishes.  ``None`` (the default) traces nothing and the
      report is bit-identical either way.
    """
    from repro.obs.tracer import NULL_TRACER
    tr = NULL_TRACER if tracer is None else tracer
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_req_s, size=n_requests)
    arrivals = np.cumsum(gaps)
    reqs = [Request(i, float(arrivals[i]), input_len, output_len,
                    prefill_remaining=input_len if prefill_on_device else 0)
            for i in range(n_requests)]
    if tr.enabled:
        for r in reqs:
            tr.emit("arrival", rid=r.rid, ts=r.arrival_s,
                    arrival_s=r.arrival_s, prompt_len=r.input_len)

    t_pf = _prefill_time(spec, input_len)
    if not prefill_on_device:
        # --- prefill: single serialized H100x8 stream -----------------------
        t = 0.0
        for r in reqs:
            t = max(t, r.arrival_s) + t_pf
            r.prefill_done_s = t

    paged = cache_mode == "paged"
    pages_cap = (num_pages if num_pages is not None
                 else max_batch * _pages(input_len + output_len, page_size))
    if paged and pages_cap < _pages(input_len + output_len, page_size):
        raise ValueError(
            f"num_pages={pages_cap} cannot hold even one full context "
            f"({_pages(input_len + output_len, page_size)} pages)")
    if prefix_sharing and not paged:
        raise ValueError("prefix_sharing requires cache_mode='paged'")
    if shared_prefix_len > input_len:
        raise ValueError(f"shared_prefix_len={shared_prefix_len} exceeds "
                         f"input_len={input_len}")
    # only whole pages of the common prefix dedupe (tails are unique)
    shared_full = (shared_prefix_len // page_size
                   if paged and prefix_sharing else 0)
    sharing = shared_full > 0
    prefix_refs = 0                 # analytical refcount on prefix pages
    free_pages = pages_cap
    dense_reserved = max_batch * (input_len + output_len)

    # --- stack-aware placement (per-channel region pools) -------------------
    if placement is not None:
        if placement not in PLACEMENT_POLICIES:
            raise ValueError(f"unknown placement {placement!r}; "
                             f"choose from {PLACEMENT_POLICIES}")
        if not paged:
            raise ValueError("placement requires cache_mode='paged'")
    place = placement is not None
    slot_cap = pages_cap - shared_full      # communal region carved off
    n_reg = max(1, min(n_regions, slot_cap)) if place else 1
    region_cap = [slot_cap // n_reg + (1 if r < slot_cap % n_reg else 0)
                  for r in range(n_reg)]
    region_free = list(region_cap)
    region_peak = [0] * n_reg
    hw_sys = hw or default_system()
    bytes_per_page = kv_bytes_per_token(spec) * page_size
    rr_cursor = 0                   # interleave striping cursor
    gather_sum = conc_sum = 0.0
    gather_iters = 0

    def place_private(r: Request, k: int) -> None:
        """Distribute ``k`` freshly charged private pages over the slot
        regions per the placement policy (mirrors PageAllocator)."""
        nonlocal rr_cursor
        if not place or k == 0:
            return
        if placement == "interleave":       # one page per region in turn
            avail = list(region_free)
            order = []
            while len(order) < k and any(a > 0 for a in avail):
                x = rr_cursor % n_reg
                rr_cursor += 1
                if avail[x] > 0:
                    avail[x] -= 1
                    order.append(x)
            takes = [(x, 1) for x in order]
        else:
            if placement == "affinity":
                order = [r.home] + sorted(
                    (x for x in range(n_reg) if x != r.home),
                    key=lambda x: (-region_free[x], x))
            else:                           # free-first: lowest region up
                order = list(range(n_reg))
            takes, left = [], k
            for x in order:
                got = min(left, region_free[x])
                if got:
                    takes.append((x, got))
                    left -= got
        for x, got in takes:
            region_free[x] -= got
            r.region_pages[x] = r.region_pages.get(x, 0) + got
            region_peak[x] = max(region_peak[x],
                                 region_cap[x] - region_free[x])
            k -= got
        assert k == 0, "private pages exceeded the slot regions"

    def unplace(r: Request) -> None:
        if not place:
            return
        for x, cnt in r.region_pages.items():
            region_free[x] += cnt
        r.region_pages = {}

    def ready_time(r: Request) -> float:
        return r.arrival_s if prefill_on_device else r.prefill_done_s

    # --- continuous-batching decode -----------------------------------------
    clock = 0.0
    pending = sorted(reqs, key=ready_time)
    active: List[Request] = []
    done: List[Request] = []
    util_integral = 0.0
    util_time = 0.0
    kv_peak = 0
    max_stall = 0.0
    preemptions = 0
    dedup_peak = 1.0
    # live co-design: a TickLatencyModel prices each tick from its actual
    # composition (per-request contexts + the co-scheduled prefill chunk)
    # instead of the shape-blind (batch, ctx-bucket) scalar
    tick_step = getattr(latency, "step", None)
    tick_stream = object()          # fresh reconfig stream per run
    reconfigs0 = getattr(latency, "reconfigurations", 0)
    tick_util_sum = 0.0
    tick_iters = 0
    # fused decode-loop mirror (engine lax.scan horizons)
    fused_ticks_n = 0
    fused_steps_sum = 0

    def admit_pages(r: Request) -> bool:
        nonlocal free_pages, prefix_refs
        if not paged:
            return True
        need = _pages(r.input_len + 1, page_size) - shared_full
        # the first holder also materializes the communal prefix pages
        extra = shared_full if (sharing and prefix_refs == 0) else 0
        if free_pages < need + extra:
            return False
        free_pages -= need + extra
        r.pages_held = need
        if place:
            # home region = most free pages at admission, ties lowest id
            r.home = min(range(n_reg), key=lambda x: (-region_free[x], x))
            place_private(r, need)
        if sharing:
            prefix_refs += 1
        return True

    def release(r: Request) -> None:
        nonlocal free_pages, prefix_refs
        if paged:
            free_pages += r.pages_held
            r.pages_held = 0
            unplace(r)
            if sharing:
                prefix_refs -= 1
                if prefix_refs == 0:    # last holder frees the prefix
                    free_pages += shared_full

    preempted_rids: set = set()

    def preempt_youngest(exclude: Request) -> bool:
        nonlocal preemptions
        cands = [r for r in active
                 if r is not exclude and r.prefill_remaining == 0]
        if not cands:
            return False
        victim = max(cands, key=lambda r: (r.arrival_s, r.rid))
        active.remove(victim)
        release(victim)
        victim.tokens_out = 0
        victim.token_times = []
        if prefill_on_device:
            victim.prefill_remaining = victim.input_len
        else:                       # must re-prefill on the xPU stream
            victim.prefill_done_s = clock + t_pf
        pending.append(victim)
        pending.sort(key=ready_time)
        preemptions += 1
        if tr.enabled:
            preempted_rids.add(victim.rid)
            tr.emit("preempt", rid=victim.rid, ts=clock,
                    preemptions=preemptions)
        return True

    while len(done) < n_requests:
        while pending and ready_time(pending[0]) <= clock \
                and len(active) < max_batch and admit_pages(pending[0]):
            r_adm = pending.pop(0)
            active.append(r_adm)
            if tr.enabled:
                tr.emit("admit", rid=r_adm.rid, ts=clock,
                        requeued=r_adm.rid in preempted_rids)
        if not active:
            clock = max(clock, ready_time(pending[0]))
            continue

        decoding = [r for r in active if r.prefill_remaining == 0]
        # --- fused multi-step horizon (engine lax.scan mirror) --------------
        # k_h = min(fuse_steps, steps until any request crosses its page
        # coverage after the boundary's grow-to-ctx+1, min remaining
        # budget): no admission, growth, or finish happens mid-horizon
        k_h = 1
        k_clamp = "fuse_steps"
        if fuse_steps > 1 and paged and decoding:
            caps = [max(r.pages_held + shared_full,
                        _pages(r.ctx() + 1, page_size)) * page_size
                    - r.ctx() for r in decoding]
            buds = [r.output_len - r.tokens_out for r in decoding]
            k_h = max(1, min([fuse_steps] + caps + buds))
            if tr.enabled:
                # same strict-< cascade as the engine's _fused_horizon
                if min(caps) < fuse_steps:
                    k_clamp = "page_edge"
                if min(buds) < min([fuse_steps] + caps):
                    k_clamp = "budget"
            if k_h > 1:
                fused_ticks_n += 1
                fused_steps_sum += k_h
        # --- co-scheduled on-device prefill ---------------------------------
        stall = 0.0
        rc_s = 0.0                  # reconfiguration charge this tick
        step_toks = 0
        pf = next((r for r in active if r.prefill_remaining > 0), None)
        if pf is not None:
            step_toks = (pf.prefill_remaining if prefill_chunk is None
                         else min(prefill_chunk, pf.prefill_remaining))
        if tick_step is not None:
            # co-design: one scheduling decision for the whole tick —
            # the prefill chunk is priced on the decode substrate too
            dec = tick_step(len(decoding), [r.ctx() for r in decoding],
                            prefill_tokens=step_toks,
                            prefill_ctx=(pf.input_len
                                         - pf.prefill_remaining
                                         + step_toks) if pf else 0,
                            stream=tick_stream)
            it, stall = dec.decode_s + dec.reconfig_s, dec.prefill_s
            rc_s = dec.reconfig_s
            tick_util_sum += dec.util
            tick_iters += 1
        else:
            if pf is not None:
                stall = _prefill_time(spec, step_toks, n_gpus=1)
            it = (latency(len(decoding),
                          int(np.mean([r.ctx() for r in decoding])))
                  if decoding else 0.0)
        # price the horizon's trailing decode-only steps (the prefill
        # chunk rides step 0, exactly like the engine's fused tick)
        for j in range(1, k_h):
            if tick_step is not None:
                d2 = tick_step(len(decoding),
                               [r.ctx() + j for r in decoding],
                               stream=tick_stream)
                it += d2.decode_s + d2.reconfig_s
                rc_s += d2.reconfig_s
                tick_util_sum += d2.util
                tick_iters += 1
            else:
                it += latency(len(decoding),
                              int(np.mean([r.ctx() + j
                                           for r in decoding])))
        if pf is not None:
            pf.prefill_remaining -= step_toks
        clock += it + stall
        if tr.enabled:
            # disjoint modeled-clock spans: prefill chunk, then the
            # reconfiguration charge, then the decode work — per tick
            # they sum to exactly `it + stall`
            t_tick0 = clock - it - stall
            if pf is not None and step_toks:
                tr.emit("prefill_chunk", ts=t_tick0, dur=stall,
                        rid=pf.rid, tokens=step_toks,
                        pos=(pf.input_len - pf.prefill_remaining
                             - step_toks),
                        last=pf.prefill_remaining == 0)
            if rc_s > 0:
                tr.emit("reconfigure", ts=t_tick0 + stall, dur=rc_s,
                        modeled_reconfig_s=rc_s)
            if decoding:
                if k_h > 1:
                    tr.emit("fused_tick", ts=t_tick0 + stall + rc_s,
                            dur=it - rc_s, batch=len(decoding),
                            horizon=k_h, clamp=k_clamp)
                else:
                    tr.emit("decode_step", ts=t_tick0 + stall + rc_s,
                            dur=it - rc_s, batch=len(decoding))
        if decoding:                # stall only counts against hot decode
            max_stall = max(max_stall, stall)
        if pf is not None and pf.prefill_remaining == 0:
            pf.prefill_done_s = clock

        # --- occupancy accounting (resident KV over this interval) ---------
        used = sum(r.input_len - r.prefill_remaining + r.tokens_out
                   for r in active)
        reserved = ((pages_cap - free_pages) * page_size if paged
                    else dense_reserved)
        kv_peak = max(kv_peak, reserved)
        if sharing and free_pages < pages_cap:
            # logical pages mapped across block tables vs. physical pages
            logical = (sum(r.pages_held for r in active)
                       + prefix_refs * shared_full)
            dedup_peak = max(dedup_peak,
                             logical / (pages_cap - free_pages))
        dt = it + stall
        if dt > 0 and reserved > 0:
            util_integral += (used / reserved) * dt
            util_time += dt

        # --- gather-cost scoring (stack-aware placement) --------------------
        if place and decoding:
            costs, concs = [], []
            for r in decoding:
                counts = dict(r.region_pages)
                if sharing:     # every holder also reads the communal pages
                    counts[COMMUNAL] = shared_full
                gc = gather_cost(hw_sys, counts, bytes_per_page)
                costs.append(gc.time_s)
                concs.append(gc.concentration)
            gather_sum += float(np.mean(costs))
            conc_sum += float(np.mean(concs))
            gather_iters += 1

        # --- decode token(s) + on-demand page growth ------------------------
        # k_h tokens per request per boundary; the horizon rule puts all
        # growth at j == 0 and budget finishes exactly on the final step
        for r in decoding:
            if r not in active:     # preempted earlier in this iteration
                continue
            for j in range(k_h):
                if paged:
                    need = (_pages(r.ctx() + 1, page_size)
                            - r.pages_held - shared_full)
                    while need > free_pages:
                        if not preempt_youngest(exclude=r):
                            raise RuntimeError("page pool too small for "
                                               "one request")
                    free_pages -= need
                    r.pages_held += need
                    place_private(r, need)
                r.tokens_out += 1
                r.token_times.append(clock - (k_h - 1 - j) * it / k_h)
                if paged:           # growth may move the peak mid-iteration
                    kv_peak = max(kv_peak,
                                  (pages_cap - free_pages) * page_size)
                if r.tokens_out >= r.output_len:
                    r.finish_s = r.token_times[-1]
                    if tr.enabled:
                        tr.emit("finish", rid=r.rid, ts=r.finish_s,
                                reason="budget", tokens=r.tokens_out)
                    release(r)
                    active.remove(r)
                    done.append(r)
                    break

    e2e = np.array([r.finish_s - r.arrival_s for r in done])
    tbts, ttfts = [], []
    for r in done:
        tt = np.asarray(r.token_times)
        first = r.prefill_done_s
        gaps_r = np.diff(np.concatenate([[first], tt]))
        tbts.append(gaps_r.mean())
        ttfts.append(tt[0] - r.arrival_s)
    return ServingReport(system=system, model=spec.name,
                         rate_req_s=rate_req_s,
                         e2e_mean_s=float(e2e.mean()),
                         e2e_p90_s=float(np.percentile(e2e, 90)),
                         tbt_mean_s=float(np.mean(tbts)),
                         completed=len(done),
                         ttft_mean_s=float(np.mean(ttfts)),
                         kv_util_mean=(util_integral / util_time
                                       if util_time else 0.0),
                         kv_peak_tokens=int(kv_peak),
                         max_decode_stall_s=max_stall,
                         preemptions=preemptions,
                         dedup_ratio=dedup_peak,
                         gather_cost_mean_s=(gather_sum / gather_iters
                                             if gather_iters else 0.0),
                         gather_concentration=(conc_sum / gather_iters
                                               if gather_iters else 1.0),
                         region_peak_pages=(tuple(region_peak)
                                            if place else ()),
                         reconfigurations=(
                             getattr(latency, "reconfigurations", 0)
                             - reconfigs0),
                         substrate_configs=len(
                             getattr(latency, "configs_seen", ())),
                         array_util_mean=(tick_util_sum / tick_iters
                                          if tick_iters else 0.0),
                         makespan_s=clock,
                         decoded_tokens=sum(r.tokens_out for r in done),
                         tokens_per_s=(sum(r.tokens_out for r in done)
                                       / clock if clock > 0 else 0.0),
                         fused_ticks=fused_ticks_n,
                         fused_steps_mean=(fused_steps_sum / fused_ticks_n
                                           if fused_ticks_n else 0.0))


# ---------------------------------------------------------------------------
# Multi-replica cluster (the serving/router.py analytical mirror)
# ---------------------------------------------------------------------------
CLUSTER_POLICIES = ("round_robin", "least_loaded", "session_affinity",
                    "prefix_affinity")


@dataclass
class ClusterReport:
    policy: str
    replicas: int
    rate_req_s: float
    completed: int
    throughput_tok_s: float
    e2e_p50_s: float
    e2e_p99_s: float
    tbt_mean_s: float
    per_replica_util: List[float]
    per_replica_completed: List[int]
    dedup_ratio: float          # aggregate peak logical/physical pages
    preemptions: int
    # live co-design metrics (TickLatencyModel callers only)
    reconfigurations: int = 0   # cross-tick shape changes, all replicas
    substrate_configs: int = 0  # distinct per-op configurations seen
    array_util_mean: float = 0.0  # mean per-tick MAC utilization
    # prefill/decode disaggregation (tiers= callers only)
    tiers: str = ""             # "P:D"; "" for colocated clusters
    shipments: int = 0          # prefill->decode KV-page handoffs
    shipped_pages: int = 0
    ship_cost_s: float = 0.0    # modeled cross-stack link time, summed


@dataclass
class _SimShipment:
    """Analytic counterpart of ``serving.paged_cache.PageShipment``: the
    request plus the priced page movement, no arrays."""
    req: Request
    n_pages: int
    bytes_on_wire: int
    cost_s: float
    src: int = -1
    dst: int = -1


def make_cluster_trace(rate_req_s: float, n_requests: int, input_len: int,
                       output_len: int, *, n_groups: int = 4,
                       skew: float = 1.0, seed: int = 0) -> List[Request]:
    """Poisson arrivals tagged with a Zipf(``skew``)-popular prefix group
    (``session`` = group: a multi-turn tenant reusing its system prompt).
    The real-engine counterpart is
    ``serving.scheduler.make_grouped_prefix_trace``."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_req_s, size=n_requests)
    arrivals = np.cumsum(gaps)
    weights = 1.0 / np.arange(1, n_groups + 1) ** skew
    weights /= weights.sum()
    groups = rng.choice(n_groups, size=n_requests, p=weights)
    return [Request(i, float(arrivals[i]), input_len, output_len,
                    group=int(groups[i]), session=int(groups[i]))
            for i in range(n_requests)]


class _Replica:
    """One decode engine in the analytical cluster: its own clock, xPU
    prefill stream, page pool, and per-group prefix refcounts (the
    per-replica ``PrefixIndex``, analytically).

    Conforms to ``serving.replica_api.Replica`` (``admit`` / ``tick`` /
    ``busy`` / ``load_report`` / ``requeue`` / ``export_slot_pages`` /
    ``import_slot_pages``) so the analytic mirror and the live engine
    present the same surface; the mirror-drift checker pins this.
    ``role="prefill"`` replicas run prompts on their serialized xPU
    stream but never decode — finished prefills wait in ``queue`` until
    ``export_slot_pages`` ships them to a decode-tier replica.
    """

    def __init__(self, latency: DecodeLatencyModel, spec: ModelSpec,
                 max_batch: int, pages_cap: int, page_size: int,
                 shared_full: int, tracer=None, role: str = "mixed",
                 ship_sys: Optional[NMPSystem] = None,
                 page_bytes: int = 0):
        self.latency = latency
        self.spec = spec
        self.max_batch = max_batch
        self.pages_cap = pages_cap
        self.page_size = page_size
        self.shared_full = shared_full
        self.clock = 0.0
        self.busy_s = 0.0
        self.pf_stream = 0.0
        self.queue: List[Request] = []
        self.active: List[Request] = []
        self.done: List[Request] = []
        self.free_pages = pages_cap
        self.prefix_refs: Dict[int, int] = {}
        self.preemptions = 0
        self.logical_peak = 0
        self.physical_peak = 0
        # live co-design: each replica is its own reconfiguration stream
        self._tick_stream = object()
        self.tick_util_sum = 0.0
        self.tick_iters = 0
        if tracer is None:
            from repro.obs.tracer import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer
        self._preempted_rids: set = set()
        # replica_api.Replica surface: role + (always-empty here —
        # preemptions re-enter this replica's own queue directly)
        self.role = role
        self.requeue: List[Request] = []
        self.ship_sys = ship_sys
        self.page_bytes = page_bytes

    # -- replica_api.Replica protocol surface --------------------------
    def admit(self, r: Request) -> bool:
        """Protocol alias: dispatch-level admission (the queue always
        accepts; page admission happens at decode entry)."""
        self.enqueue(r)
        return True

    def tick(self) -> int:
        return int(self._step_once())

    def busy(self) -> bool:
        return bool(self.active or self.queue)

    def load_report(self) -> LoadReport:
        return LoadReport(
            active=len(self.active), prefilling=0,
            queue_depth=len(self.active) + len(self.queue),
            free_slots=self.max_batch - len(self.active),
            free_pages=self.free_pages,
            min_region_free=self.free_pages)

    def export_slot_pages(self, rid: int) -> Optional[_SimShipment]:
        """Tier handoff, analytically: pull a finished prefill out of the
        queue and price its page movement with ``noc.page_ship``.
        ``None`` while the prefill hasn't completed yet (deferral — the
        mirror of the engine's mid-chunked-prefill refusal)."""
        r = next((q for q in self.queue if q.rid == rid), None)
        if r is None:
            raise KeyError(f"request {rid} is not resident")
        if r.prefill_done_s > self.clock:
            return None
        self.queue.remove(r)
        n_pages = _pages(r.input_len, self.page_size)
        cost = page_ship(self.ship_sys or default_system(),
                         n_pages * self.page_bytes, n_pages, hops=1)
        return _SimShipment(req=r, n_pages=n_pages,
                            bytes_on_wire=cost.bytes_on_wire,
                            cost_s=cost.time_s)

    def import_slot_pages(self, shipment: _SimShipment) -> bool:
        """Receive a shipped prefill: decode cannot start until the
        pages land, so the link time extends ``prefill_done_s`` on the
        modeled clock."""
        r = shipment.req
        r.prefill_done_s += shipment.cost_s
        self.queue.append(r)
        self.queue.sort(key=lambda q: (q.prefill_done_s, q.rid))
        return True

    # -- load signals read by the dispatch policy ----------------------
    def load(self) -> Tuple[int, int]:
        return (len(self.active) + len(self.queue), -self.free_pages)

    def holds_group(self, g: int) -> bool:
        return self.prefix_refs.get(g, 0) > 0

    # -- paged admission with per-group prefix dedup -------------------
    def _admit(self, r: Request) -> bool:
        need = _pages(r.input_len + 1, self.page_size) - self.shared_full
        extra = (self.shared_full
                 if self.shared_full and not self.holds_group(r.group)
                 else 0)
        if self.free_pages < need + extra:
            return False
        self.free_pages -= need + extra
        r.pages_held = need
        if self.shared_full:
            self.prefix_refs[r.group] = \
                self.prefix_refs.get(r.group, 0) + 1
        return True

    def _release(self, r: Request) -> None:
        self.free_pages += r.pages_held
        r.pages_held = 0
        if self.shared_full:
            self.prefix_refs[r.group] -= 1
            if self.prefix_refs[r.group] == 0:
                self.free_pages += self.shared_full
                del self.prefix_refs[r.group]

    def _preempt_youngest(self, exclude: Request) -> bool:
        cands = [r for r in self.active if r is not exclude]
        if not cands:
            return False
        victim = max(cands, key=lambda r: (r.arrival_s, r.rid))
        self.active.remove(victim)
        self._release(victim)
        victim.tokens_out = 0
        victim.token_times = []
        victim.prefill_done_s = self.clock + _prefill_time(
            self.spec, victim.input_len)
        self.queue.append(victim)
        self.queue.sort(key=lambda q: (q.prefill_done_s, q.rid))
        self.preemptions += 1
        if self.tracer.enabled:
            self._preempted_rids.add(victim.rid)
            self.tracer.emit("preempt", rid=victim.rid, ts=self.clock,
                             preemptions=self.preemptions)
        return True

    def enqueue(self, r: Request) -> None:
        """Dispatch: the replica's serialized xPU stream prefills it."""
        self.pf_stream = (max(self.pf_stream, r.arrival_s)
                          + _prefill_time(self.spec, r.input_len))
        r.prefill_done_s = self.pf_stream
        self.queue.append(r)
        # a preempted victim re-queued at clock+t_pf may sit ahead of a
        # later arrival that is ready sooner; head-only admission needs
        # the queue sorted by readiness or an idle replica can livelock
        self.queue.sort(key=lambda q: (q.prefill_done_s, q.rid))

    def _note_peaks(self) -> None:
        physical = self.pages_cap - self.free_pages
        logical = (sum(r.pages_held for r in self.active)
                   + sum(self.prefix_refs.values()) * self.shared_full)
        self.physical_peak = max(self.physical_peak, physical)
        self.logical_peak = max(self.logical_peak, logical)

    def _step_once(self) -> bool:
        """Admit what's ready, run one decode iteration.  False when
        there is nothing to do at the current clock."""
        if self.role == "prefill":
            return False        # prefill tier never decodes; the
            # cluster harvester ships finished prompts off the queue
        while self.queue and self.queue[0].prefill_done_s <= self.clock \
                and len(self.active) < self.max_batch \
                and self._admit(self.queue[0]):
            r_adm = self.queue.pop(0)
            self.active.append(r_adm)
            if self.tracer.enabled:
                self.tracer.emit(
                    "admit", rid=r_adm.rid, ts=self.clock,
                    requeued=r_adm.rid in self._preempted_rids)
        if not self.active:
            return False
        tick_step = getattr(self.latency, "step", None)
        rc_s = 0.0
        if tick_step is not None:
            dec = tick_step(len(self.active),
                            [r.ctx() for r in self.active],
                            stream=self._tick_stream)
            it = dec.time_s + dec.reconfig_s
            rc_s = dec.reconfig_s
            self.tick_util_sum += dec.util
            self.tick_iters += 1
        else:
            it = self.latency(len(self.active),
                              int(np.mean([r.ctx()
                                           for r in self.active])))
        self.clock += it
        self.busy_s += it
        if self.tracer.enabled:
            if rc_s > 0:
                self.tracer.emit("reconfigure", ts=self.clock - it,
                                 dur=rc_s, modeled_reconfig_s=rc_s)
            self.tracer.emit("decode_step", ts=self.clock - it + rc_s,
                             dur=it - rc_s, batch=len(self.active))
        self._note_peaks()
        for r in list(self.active):
            if r not in self.active:    # preempted mid-iteration
                continue
            need = (_pages(r.ctx() + 1, self.page_size)
                    - r.pages_held - self.shared_full)
            while need > self.free_pages:
                if not self._preempt_youngest(exclude=r):
                    raise RuntimeError(
                        "replica page pool too small for one request")
            self.free_pages -= need
            r.pages_held += need
            r.tokens_out += 1
            r.token_times.append(self.clock)
            if r.tokens_out >= r.output_len:
                r.finish_s = self.clock
                if self.tracer.enabled:
                    self.tracer.emit("finish", rid=r.rid, ts=self.clock,
                                     reason="budget", tokens=r.tokens_out)
                self._release(r)
                self.active.remove(r)
                self.done.append(r)
        self._note_peaks()
        return True

    def advance_to(self, t: float) -> None:
        """Run the replica's loop up to wall-time ``t`` (dispatch-time
        synchronization point: load signals are current as of ``t``)."""
        if self.role == "prefill":
            # no decode loop to run; prompts progress on the serialized
            # xPU stream, which already carries its own timeline
            self.clock = max(self.clock, t)
            return
        while self.clock < t:
            if self._step_once():
                continue
            nxt = min((r.prefill_done_s for r in self.queue), default=t)
            if nxt >= t:
                self.clock = t
                return
            self.clock = max(self.clock, nxt)

    def run_to_completion(self) -> None:
        while self.active or self.queue:
            if not self._step_once():
                self.clock = max(self.clock,
                                 min(r.prefill_done_s
                                     for r in self.queue))


def simulate_cluster(latency: DecodeLatencyModel, spec: ModelSpec,
                     rate_req_s: float, *, policy: str = "round_robin",
                     n_replicas: int = 2, n_requests: int = 64,
                     input_len: int = 8192, output_len: int = 1024,
                     max_batch: int = 64, seed: int = 0,
                     page_size: int = 16, num_pages: Optional[int] = None,
                     prefix_sharing: bool = False,
                     shared_prefix_len: int = 0, n_groups: int = 4,
                     skew: float = 1.0,
                     trace: Optional[List[Request]] = None,
                     tracer=None,
                     tiers: Optional[Tuple[int, int]] = None,
                     sys: Optional[NMPSystem] = None) -> ClusterReport:
    """Analytical mirror of ``serving/router.py``: N independent paged
    decode replicas behind one dispatch policy.

    Requests are dispatched in arrival order; before each dispatch every
    replica is advanced to the arrival instant so the policy reads load
    signals as the real front end would.  Replicas then mirror
    ``simulate_serving``'s paged machinery per replica: serialized xPU
    prefill stream, continuous-batching decode via the shared latency
    model, on-demand page growth with youngest-first preemption, and —
    with ``prefix_sharing`` — per-group communal prefix pages refcounted
    per replica, so colocating a group's requests (prefix/session
    affinity) raises the aggregate dedup ratio exactly as the engine's
    trie does.

    ``dedup_ratio`` aggregates peak logical pages over peak physical
    pages across replicas; ``per_replica_util`` is busy decode time over
    the cluster makespan.

    ``tiers=(P, D)`` disaggregates the cluster exactly as
    ``Router(tiers=...)`` does: replicas ``0..P-1`` only prefill (their
    serialized xPU streams), the rest only decode.  Each finished
    prefill is shipped to the decode replica already holding its prefix
    group (ties / no residency: least-loaded), and the
    ``noc.page_ship`` link time delays decode start on the modeled
    clock (``ship`` trace events carry it as their duration).
    """
    if policy not in CLUSTER_POLICIES:
        raise ValueError(f"unknown policy {policy!r}; "
                         f"choose from {CLUSTER_POLICIES}")
    if tiers is not None:
        p_n, d_n = int(tiers[0]), int(tiers[1])
        if p_n < 1 or d_n < 1:
            raise ValueError("tiers needs >=1 prefill and >=1 decode "
                             f"replica, got {p_n}:{d_n}")
        if p_n + d_n != n_replicas:
            raise ValueError(f"tiers {p_n}:{d_n} must sum to the "
                             f"{n_replicas} replicas")
    if trace is None:
        trace = make_cluster_trace(rate_req_s, n_requests, input_len,
                                   output_len, n_groups=n_groups,
                                   skew=skew, seed=seed)
    n_requests = len(trace)
    # size the guard off the actual trace — an explicit ``trace`` may
    # carry longer contexts than the input_len/output_len defaults, and
    # an unsatisfiable admission would spin forever instead of raising
    worst = max(_pages(r.input_len + r.output_len, page_size)
                for r in trace)
    pages_cap = (num_pages if num_pages is not None
                 else max_batch * worst)
    if pages_cap < worst:
        raise ValueError("num_pages cannot hold even one full context")
    shared_full = (shared_prefix_len // page_size
                   if prefix_sharing else 0)
    # validate against the actual trace, not the input_len default —
    # a shorter explicit prompt would drive page accounting negative
    if shared_prefix_len > min(r.input_len for r in trace):
        raise ValueError("shared_prefix_len exceeds a trace prompt")
    ship_sys = sys if sys is not None else default_system()
    page_bytes = kv_bytes_per_token(spec) * page_size
    prefill_idx: Tuple[int, ...] = ()
    decode_idx: Tuple[int, ...] = tuple(range(n_replicas))
    if tiers is not None:
        prefill_idx = tuple(range(tiers[0]))
        decode_idx = tuple(range(tiers[0], n_replicas))
    reps = [_Replica(latency, spec, max_batch, pages_cap, page_size,
                     shared_full,
                     tracer=(tracer.for_replica(i) if tracer is not None
                             else None),
                     role=("prefill" if i in prefill_idx else "mixed"),
                     ship_sys=ship_sys, page_bytes=page_bytes)
            for i in range(n_replicas)]
    reconfigs0 = getattr(latency, "reconfigurations", 0)

    rr = 0
    sessions: Dict[int, int] = {}
    hints: Dict[int, int] = {}

    def least_loaded(among=None) -> int:
        idxs = among if among is not None else range(n_replicas)
        return min(idxs, key=lambda i: reps[i].load() + (i,))

    def select(r: Request) -> int:
        nonlocal rr
        if tiers is not None:
            # disaggregated: arrivals land on the prefill tier; decode
            # placement happens at the ship point below
            return least_loaded(prefill_idx)
        if policy == "round_robin":
            i = rr % n_replicas
            rr += 1
            return i
        if policy == "least_loaded":
            return least_loaded()
        if policy == "session_affinity":
            if r.session not in sessions:
                sessions[r.session] = least_loaded()
            return sessions[r.session]
        holders = [i for i in range(n_replicas)
                   if reps[i].holds_group(r.group)]
        if holders:
            return (holders[0] if len(holders) == 1
                    else least_loaded(holders))
        if r.group in hints:
            return hints[r.group]
        return least_loaded()

    for req in sorted(trace, key=lambda r: (r.arrival_s, r.rid)):
        for rep in reps:
            rep.advance_to(req.arrival_s)
        i = select(req)
        hints[req.group] = i
        if tracer is not None and tracer.enabled:
            tracer.emit("dispatch", replica=i, rid=req.rid,
                        ts=req.arrival_s, policy=policy)
        reps[i].enqueue(req)

    shipments = shipped_pages = 0
    ship_cost = 0.0
    if tiers is not None:
        # tier handoff: ship each finished prefill, in completion order,
        # to the decode replica holding its prefix group (mirror of
        # Router._ship_ready's residency-then-pressure choice), advancing
        # the decode tier to the ship instant so load signals are read
        # exactly when the real harvester would read them
        ready = sorted(((r, i) for i in prefill_idx
                        for r in reps[i].queue),
                       key=lambda pair: (pair[0].prefill_done_s,
                                         pair[0].rid))
        for r, i in ready:
            t_ready = r.prefill_done_s
            reps[i].advance_to(t_ready)
            for j in decode_idx:
                reps[j].advance_to(t_ready)
            holders = [j for j in decode_idx
                       if reps[j].holds_group(r.group)]
            j = (holders[0] if len(holders) == 1
                 else least_loaded(holders if holders else decode_idx))
            ship = reps[i].export_slot_pages(r.rid)
            assert ship is not None and reps[j].import_slot_pages(ship)
            shipments += 1
            shipped_pages += ship.n_pages
            ship_cost += ship.cost_s
            if tracer is not None and tracer.enabled:
                tracer.emit("ship", replica=i, rid=r.rid, ts=t_ready,
                            dur=ship.cost_s, pages=ship.n_pages,
                            bytes=ship.bytes_on_wire,
                            cost_s=ship.cost_s, src=i, dst=j)
    for rep in reps:
        rep.run_to_completion()

    all_done = [r for rep in reps for r in rep.done]
    assert len(all_done) == n_requests
    wall = max(max((r.finish_s for r in all_done)),
               max(r.arrival_s for r in all_done))
    e2e = np.array([r.finish_s - r.arrival_s for r in all_done])
    tbts = [float(np.diff(np.concatenate(
                [[r.prefill_done_s], np.asarray(r.token_times)])).mean())
            for r in all_done]
    logical = sum(rep.logical_peak for rep in reps)
    physical = sum(rep.physical_peak for rep in reps)
    return ClusterReport(
        policy=policy, replicas=n_replicas, rate_req_s=rate_req_s,
        completed=len(all_done),
        throughput_tok_s=sum(r.tokens_out for r in all_done) / wall,
        e2e_p50_s=float(np.percentile(e2e, 50)),
        e2e_p99_s=float(np.percentile(e2e, 99)),
        tbt_mean_s=float(np.mean(tbts)),
        per_replica_util=[rep.busy_s / wall for rep in reps],
        per_replica_completed=[len(rep.done) for rep in reps],
        dedup_ratio=(logical / physical if physical else 1.0),
        preemptions=sum(rep.preemptions for rep in reps),
        reconfigurations=(getattr(latency, "reconfigurations", 0)
                          - reconfigs0),
        substrate_configs=len(getattr(latency, "configs_seen", ())),
        array_util_mean=(sum(rep.tick_util_sum for rep in reps)
                         / max(1, sum(rep.tick_iters for rep in reps))
                         if any(rep.tick_iters for rep in reps) else 0.0),
        tiers=(f"{tiers[0]}:{tiers[1]}" if tiers is not None else ""),
        shipments=shipments, shipped_pages=shipped_pages,
        ship_cost_s=ship_cost)
