"""Serving-level simulator (paper §6.4, Fig. 10; Duplex-style framework).

Poisson request injection -> prefill on the xPU (H100) -> continuous-batching
decode on the device under test (NMP substrate or GPU).  Reports end-to-end
(E2E) latency and time-between-tokens (TBT) under varying request rates.

Deterministic: arrivals use an explicit seeded generator (exponential gaps).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.gpu_model import gpu_decode_step
from repro.core.hw import H100, GPUConfig, NMPSystem
from repro.core.operators import ModelSpec
from repro.core.pipeline import decode_step


@dataclass
class Request:
    rid: int
    arrival_s: float
    input_len: int
    output_len: int
    prefill_done_s: float = math.inf
    tokens_out: int = 0
    finish_s: float = math.inf
    token_times: List[float] = field(default_factory=list)


@dataclass
class ServingReport:
    system: str
    model: str
    rate_req_s: float
    e2e_mean_s: float
    e2e_p90_s: float
    tbt_mean_s: float
    completed: int

    def normalized_to(self, base: "ServingReport") -> Tuple[float, float]:
        return (self.e2e_mean_s / base.e2e_mean_s,
                self.tbt_mean_s / base.tbt_mean_s)


def _prefill_time(spec: ModelSpec, input_len: int,
                  gpu: GPUConfig = H100, n_gpus: int = 8) -> float:
    flops = 2 * spec.active_params() * input_len
    return flops / (gpu.peak_flops * 0.55 * n_gpus)


class DecodeLatencyModel:
    """Caches per-(batch, ctx-bucket) decode-iteration latency."""

    def __init__(self, step_fn: Callable[[int, int], float],
                 ctx_bucket: int = 1024):
        self.step_fn = step_fn
        self.ctx_bucket = ctx_bucket
        self._cache: Dict[Tuple[int, int], float] = {}

    def __call__(self, batch: int, ctx: int) -> float:
        cb = max(self.ctx_bucket,
                 ((ctx + self.ctx_bucket - 1) // self.ctx_bucket)
                 * self.ctx_bucket)
        key = (batch, cb)
        if key not in self._cache:
            self._cache[key] = self.step_fn(batch, cb)
        return self._cache[key]


def nmp_latency_model(sys: NMPSystem, spec: ModelSpec,
                      tp: int = 1) -> DecodeLatencyModel:
    return DecodeLatencyModel(
        lambda b, c: decode_step(sys, spec, b, c, tp=tp).time_s)


def gpu_latency_model(spec: ModelSpec, tp: int = 8) -> DecodeLatencyModel:
    return DecodeLatencyModel(
        lambda b, c: gpu_decode_step(spec, b, c, tp=tp).time_s)


def simulate_serving(latency: DecodeLatencyModel, spec: ModelSpec,
                     rate_req_s: float, *, system: str,
                     n_requests: int = 128, input_len: int = 8192,
                     output_len: int = 1024, max_batch: int = 64,
                     seed: int = 0) -> ServingReport:
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_req_s, size=n_requests)
    arrivals = np.cumsum(gaps)
    reqs = [Request(i, float(arrivals[i]), input_len, output_len)
            for i in range(n_requests)]

    # --- prefill: single serialized H100x8 stream ---------------------------
    t_pf = _prefill_time(spec, input_len)
    t = 0.0
    for r in reqs:
        t = max(t, r.arrival_s) + t_pf
        r.prefill_done_s = t

    # --- continuous-batching decode -----------------------------------------
    clock = 0.0
    pending = sorted(reqs, key=lambda r: r.prefill_done_s)
    active: List[Request] = []
    done: List[Request] = []
    pi = 0
    while len(done) < n_requests:
        while pi < n_requests and pending[pi].prefill_done_s <= clock \
                and len(active) < max_batch:
            active.append(pending[pi])
            pi += 1
        if not active:
            clock = pending[pi].prefill_done_s
            continue
        ctx = int(np.mean([r.input_len + r.tokens_out for r in active]))
        it = latency(len(active), ctx)
        clock += it
        still: List[Request] = []
        for r in active:
            r.tokens_out += 1
            r.token_times.append(clock)
            if r.tokens_out >= r.output_len:
                r.finish_s = clock
                done.append(r)
            else:
                still.append(r)
        active = still

    e2e = np.array([r.finish_s - r.arrival_s for r in done])
    tbts = []
    for r in done:
        tt = np.asarray(r.token_times)
        first = r.prefill_done_s
        gaps_r = np.diff(np.concatenate([[first], tt]))
        tbts.append(gaps_r.mean())
    return ServingReport(system=system, model=spec.name,
                         rate_req_s=rate_req_s,
                         e2e_mean_s=float(e2e.mean()),
                         e2e_p90_s=float(np.percentile(e2e, 90)),
                         tbt_mean_s=float(np.mean(tbts)),
                         completed=len(done))
