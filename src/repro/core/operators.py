"""Decode-step operator extraction (paper §5b, Table 1).

A ``ModelSpec`` describes one transformer-family LLM at the granularity the
NMP scheduler cares about; ``decode_ops`` expands one decode step (one new
token for each of ``batch`` requests against ``ctx`` cached tokens) into the
list of GEMMs + vector stages that the multi-PU scheduler maps.

Conventions:
* fp16 everywhere (paper evaluates IEEE 754 FP16).
* GQA: attention score/value GEMMs are batched per (request, kv-head) with
  M = group size (Hq / Hkv) — grouping is what lifts decode attention's M.
* MLA (DeepSeek): decode uses the absorbed form — per request one
  M=Hq, K=(d_c + d_rope), N=ctx score GEMM and one M=Hq, K=ctx, N=d_c value
  GEMM against the compressed KV cache.
* MoE: uniform expert routing (paper follows Duplex); per-expert token count
  M_e = batch * topk / E, all E experts active when batch*topk >= E.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional, Tuple

from repro.core.gemm import Gemm, OpClass, ceil_div


@dataclass(frozen=True)
class MoESpec:
    num_experts: int
    topk: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0


@dataclass(frozen=True)
class MLASpec:
    d_compressed: int = 512
    d_rope: int = 64
    d_q_lora: int = 1536


@dataclass(frozen=True)
class ModelSpec:
    name: str
    num_layers: int
    d_model: int
    d_ff: int
    num_q_heads: int
    num_kv_heads: int
    vocab: int
    d_head: Optional[int] = None
    gated_ffn: bool = True
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None

    def __post_init__(self):
        if self.d_head is None:
            object.__setattr__(self, "d_head",
                               self.d_model // self.num_q_heads)

    @property
    def group_size(self) -> int:
        return self.num_q_heads // self.num_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.moe is not None

    # ---- parameter counts (for roofline / sanity) --------------------------
    def params(self) -> int:
        H, Dh = self.d_model, self.d_head
        attn = H * (self.num_q_heads * Dh) + 2 * H * (self.num_kv_heads * Dh) \
            + (self.num_q_heads * Dh) * H
        if self.mla is not None:
            c, r, ql = (self.mla.d_compressed, self.mla.d_rope,
                        self.mla.d_q_lora)
            attn = (H * (c + r) + H * ql + ql * self.num_q_heads * (Dh + r)
                    + c * self.num_q_heads * 2 * Dh
                    + self.num_q_heads * Dh * H)
        if self.is_moe:
            e = self.moe
            ffn_mults = 3 if self.gated_ffn else 2
            ffn = (e.num_experts * ffn_mults * H * e.d_ff_expert
                   + e.num_shared_experts * ffn_mults * H * e.d_ff_shared
                   + H * e.num_experts)
        else:
            ffn = (3 if self.gated_ffn else 2) * H * self.d_ff
        return self.num_layers * (attn + ffn) + 2 * self.vocab * H

    def active_params(self) -> int:
        """Per-token active parameters (MoE: only routed experts)."""
        if not self.is_moe:
            return self.params()
        e = self.moe
        H = self.d_model
        ffn_mults = 3 if self.gated_ffn else 2
        full = self.params()
        all_expert = self.num_layers * e.num_experts * ffn_mults * H * e.d_ff_expert
        active_expert = self.num_layers * e.topk * ffn_mults * H * e.d_ff_expert
        return full - all_expert + active_expert


# ---------------------------------------------------------------------------
# Operator extraction
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LayerOps:
    """Ordered operator list for one decoder layer's decode step."""

    projections: Tuple[Gemm, ...]      # scheduled via the 4-mode framework
    attention: Tuple[Gemm, ...]        # head-parallel (QK, AV)
    experts: Tuple[Gemm, ...]          # MoE expert GEMMs (PU-distributed)
    moe_dispatch_bytes: int = 0        # all-to-all token traffic over NoC


def _attention_ops(spec: ModelSpec, batch: int, ctx: int) -> List[Gemm]:
    Dh = spec.d_head
    if spec.mla is not None:
        c, r = spec.mla.d_compressed, spec.mla.d_rope
        qk = Gemm("attn.qk", m=spec.num_q_heads, n=ctx, k=c + r, count=batch,
                  op_class=OpClass.ATTENTION_QK,
                  nonlinear_elems=spec.num_q_heads * ctx,
                  weight_reuse_across_count=False)
        av = Gemm("attn.av", m=spec.num_q_heads, n=c, k=ctx, count=batch,
                  op_class=OpClass.ATTENTION_AV,
                  weight_reuse_across_count=False)
        return [qk, av]
    g = spec.group_size
    qk = Gemm("attn.qk", m=g, n=ctx, k=Dh, count=batch * spec.num_kv_heads,
              op_class=OpClass.ATTENTION_QK, nonlinear_elems=g * ctx,
              weight_reuse_across_count=False)
    av = Gemm("attn.av", m=g, n=Dh, k=ctx, count=batch * spec.num_kv_heads,
              op_class=OpClass.ATTENTION_AV,
              weight_reuse_across_count=False)
    return [qk, av]


def _proj_ops(spec: ModelSpec, batch: int) -> List[Gemm]:
    H, Dh = spec.d_model, spec.d_head
    ops: List[Gemm] = []
    if spec.mla is not None:
        mla = spec.mla
        c, r, ql = mla.d_compressed, mla.d_rope, mla.d_q_lora
        ops.append(Gemm("proj.kv_down", m=batch, n=c + r, k=H))
        ops.append(Gemm("proj.q_down", m=batch, n=ql, k=H))
        ops.append(Gemm("proj.q_up", m=batch, n=spec.num_q_heads * (Dh + r),
                        k=ql))
        # absorbed W_UK fold: q_nope @ W_UK^T per head
        ops.append(Gemm("proj.q_absorb", m=batch, n=c, k=Dh,
                        count=spec.num_q_heads, weight_reuse_across_count=False))
        ops.append(Gemm("proj.o_up", m=batch, n=Dh, k=c,
                        count=spec.num_q_heads, weight_reuse_across_count=False))
        ops.append(Gemm("proj.o", m=batch, n=H, k=spec.num_q_heads * Dh))
    else:
        n_qkv = (spec.num_q_heads + 2 * spec.num_kv_heads) * Dh
        ops.append(Gemm("proj.qkv", m=batch, n=n_qkv, k=H,
                        nonlinear_elems=n_qkv * batch))  # rope+cache update
        ops.append(Gemm("proj.o", m=batch, n=H, k=spec.num_q_heads * Dh,
                        nonlinear_elems=batch * H))      # residual add
    return ops


def _ffn_ops(spec: ModelSpec, batch: int) -> Tuple[List[Gemm], List[Gemm], int]:
    """Returns (dense projections, expert gemms, dispatch bytes)."""
    H = spec.d_model
    if not spec.is_moe:
        ups = []
        if spec.gated_ffn:
            ups.append(Gemm("ffn.up_gate", m=batch, n=2 * spec.d_ff, k=H,
                            nonlinear_elems=batch * spec.d_ff))
        else:
            ups.append(Gemm("ffn.up", m=batch, n=spec.d_ff, k=H,
                            nonlinear_elems=batch * spec.d_ff))
        down = Gemm("ffn.down", m=batch, n=H, k=spec.d_ff,
                    nonlinear_elems=batch * H)
        return ups + [down], [], 0

    e = spec.moe
    ops: List[Gemm] = [Gemm("moe.router", m=batch, n=e.num_experts, k=H,
                            nonlinear_elems=batch * e.num_experts)]
    if e.num_shared_experts:
        fs = e.d_ff_shared * e.num_shared_experts
        if spec.gated_ffn:
            ops.append(Gemm("moe.shared.up_gate", m=batch, n=2 * fs, k=H,
                            nonlinear_elems=batch * fs))
        ops.append(Gemm("moe.shared.down", m=batch, n=H, k=fs))
    tokens = batch * e.topk
    active = min(e.num_experts, tokens)
    m_e = max(1, round(tokens / e.num_experts))
    experts: List[Gemm] = []
    if spec.gated_ffn:
        experts.append(Gemm("moe.exp.up_gate", m=m_e, n=2 * e.d_ff_expert,
                            k=H, count=active, op_class=OpClass.EXPERT_FFN,
                            nonlinear_elems=m_e * e.d_ff_expert,
                            weight_reuse_across_count=False))
    else:
        experts.append(Gemm("moe.exp.up", m=m_e, n=e.d_ff_expert, k=H,
                            count=active, op_class=OpClass.EXPERT_FFN,
                            weight_reuse_across_count=False))
    experts.append(Gemm("moe.exp.down", m=m_e, n=H, k=e.d_ff_expert,
                        count=active, op_class=OpClass.EXPERT_FFN,
                        nonlinear_elems=m_e * H,
                        weight_reuse_across_count=False))
    dispatch = 2 * tokens * H * 2  # to-expert + back, fp16
    return ops, experts, dispatch


def layer_ops(spec: ModelSpec, batch: int, ctx: int) -> LayerOps:
    proj = _proj_ops(spec, batch)
    attn = _attention_ops(spec, batch, ctx)
    ffn, experts, dispatch = _ffn_ops(spec, batch)
    return LayerOps(projections=tuple(proj + ffn), attention=tuple(attn),
                    experts=tuple(experts), moe_dispatch_bytes=dispatch)


# ---------------------------------------------------------------------------
# Multi-device tensor parallelism (paper §6.1.3: 8-device system, TP=8)
# ---------------------------------------------------------------------------
_COL_PARALLEL = ("qkv", "q_down", "kv_down", "q_up", "up_gate", "up",
                 "router")
_ROW_PARALLEL = ("o", "down")


def _tp_proj(g: Gemm, tp: int) -> Gemm:
    """Megatron-style split: column-parallel ops shard N, row-parallel shard
    K (paired so each layer needs only the attn-out + ffn-out all-reduces).
    Expert FFNs stay TP-sharded the same way (paper §6.1.3 retains TP for
    MoE layers); per-head ops (count>1, MLA absorb/up) divide the heads."""
    leaf = g.name.split(".")[-1]
    if g.count > 1 and g.op_class != OpClass.EXPERT_FFN:
        return g.scaled(count=max(1, ceil_div(g.count, tp)))
    if leaf in _ROW_PARALLEL:
        return g.split_k(tp)
    # default: shard the fat N dim; the local nonlinear epilogue shards too
    return replace(g, n=max(1, ceil_div(g.n, tp)),
                   nonlinear_elems=ceil_div(g.nonlinear_elems, tp))


def _tp_attn(g: Gemm, tp: int) -> Gemm:
    """Head-parallel: (request, kv-head) units divide across devices; MLA
    (count=batch, m=heads) splits the head M dim instead."""
    if g.count > 1 and g.count % tp == 0:
        return g.scaled(count=g.count // tp)
    return replace(g, m=max(1, ceil_div(g.m, tp)),
                   nonlinear_elems=ceil_div(g.nonlinear_elems, tp))


def layer_ops_tp(spec: ModelSpec, batch: int, ctx: int, tp: int) -> LayerOps:
    """Per-device operator list under tp-way tensor parallelism."""
    lo = layer_ops(spec, batch, ctx)
    if tp <= 1:
        return lo
    proj = tuple(_tp_proj(g, tp) for g in lo.projections)
    attn = tuple(_tp_attn(g, tp) for g in lo.attention)
    experts = tuple(_tp_proj(g, tp) for g in lo.experts)
    return LayerOps(projections=proj, attention=attn, experts=experts,
                    moe_dispatch_bytes=ceil_div(lo.moe_dispatch_bytes, tp))


def decode_ops(spec: ModelSpec, batch: int, ctx: int,
               include_head: bool = True) -> List[Gemm]:
    """Flat per-layer-weighted operator list for one decode step."""
    lo = layer_ops(spec, batch, ctx)
    per_layer = list(lo.projections) + list(lo.attention) + list(lo.experts)
    ops = [g.scaled(count=g.count * spec.num_layers) for g in per_layer]
    if include_head:
        ops.append(Gemm("lm_head", m=batch, n=spec.vocab, k=spec.d_model))
    return ops


# ---------------------------------------------------------------------------
# Paper Table 1 models
# ---------------------------------------------------------------------------
OPT_66B = ModelSpec("OPT-66B", num_layers=64, d_model=9216, d_ff=36864,
                    num_q_heads=72, num_kv_heads=72, vocab=50272,
                    gated_ffn=False)
LLAMA3_70B = ModelSpec("LLaMA3-70B", num_layers=80, d_model=8192, d_ff=28672,
                       num_q_heads=64, num_kv_heads=8, vocab=128256)
MIXTRAL_8X22B = ModelSpec("Mixtral-8x22B", num_layers=56, d_model=6144,
                          d_ff=16384, num_q_heads=48, num_kv_heads=8,
                          vocab=32768,
                          moe=MoESpec(num_experts=8, topk=2, d_ff_expert=16384))
QWEN3_30B_A3B = ModelSpec("Qwen3-30B-A3B", num_layers=48, d_model=2048,
                          d_ff=768, num_q_heads=32, num_kv_heads=4,
                          vocab=151936, d_head=128,
                          moe=MoESpec(num_experts=128, topk=8, d_ff_expert=768))
DEEPSEEK_236B = ModelSpec("DeepSeek-236B", num_layers=60, d_model=5120,
                          d_ff=12288, num_q_heads=128, num_kv_heads=128,
                          vocab=102400, d_head=128,
                          moe=MoESpec(num_experts=160, topk=8,
                                      d_ff_expert=1536,
                                      num_shared_experts=2, d_ff_shared=1536),
                          mla=MLASpec(d_compressed=512, d_rope=64,
                                      d_q_lora=1536))

PAPER_MODELS = {m.name: m for m in
                (OPT_66B, LLAMA3_70B, MIXTRAL_8X22B, QWEN3_30B_A3B,
                 DEEPSEEK_236B)}
