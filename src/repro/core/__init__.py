"""SNAKE core: 3D-stacked NMP compute-substrate + scheduling models."""
from repro.core.dataflow import (CoreExec, best_logical_shape, mactree_gemm,
                                 sa_gemm, sa_gemm_auto, sa_gemm_best)
from repro.core.energy import EnergyReport, gemm_energy, peak_power_breakdown
from repro.core.gemm import Dataflow, Gemm, OpClass, ceil_div, pad_to
from repro.core.gpu_model import GPUDecodeReport, gpu_decode_step
from repro.core.hw import (H100, FP16_BYTES, BufferConfig, GPUConfig,
                           MacTreeConfig, NMPSystem, SystolicArrayConfig,
                           area_model, fixed_sa_system, mactree_system,
                           snake_system)
from repro.core.operators import (DEEPSEEK_236B, LLAMA3_70B, MIXTRAL_8X22B,
                                  OPT_66B, PAPER_MODELS, QWEN3_30B_A3B,
                                  MLASpec, ModelSpec, MoESpec, decode_ops,
                                  layer_ops)
from repro.core.pipeline import DecodeReport, decode_step, decode_sweep
from repro.core.schedule import (Mode, OpExec, mode_candidates,
                                 schedule_attention, schedule_chain,
                                 schedule_experts, schedule_projection)
from repro.core.serving_sim import (ServingReport, gpu_latency_model,
                                    nmp_latency_model, simulate_serving)

__all__ = [n for n in dir() if not n.startswith("_")]
