"""Lightweight logic-die NoC model (paper §4.1).

The NoC connects the 16 PUs and is used only for coarse-grained collectives
(all-reduce / all-gather / reduce-scatter) and MoE token dispatch.  We model
ring collectives over the per-PU injection bandwidth (how such lightweight
meshes are actually scheduled), plus a per-stage hop latency.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.hw import NMPSystem


@dataclass(frozen=True)
class CollectiveCost:
    bytes_on_wire: int   # total bytes crossing NoC links
    time_s: float


def all_reduce(sys: NMPSystem, payload_bytes: int) -> CollectiveCost:
    """Ring all-reduce of a payload replicated per PU: 2(P-1)/P bytes/PU."""
    p = sys.pus
    per_pu = 2 * (p - 1) / p * payload_bytes
    t = (per_pu / sys.noc_link_bw_bytes
         + 2 * (p - 1) * sys.noc_latency_cycles / sys.freq_hz)
    return CollectiveCost(int(per_pu * p), t)


def reduce_scatter(sys: NMPSystem, payload_bytes: int) -> CollectiveCost:
    p = sys.pus
    per_pu = (p - 1) / p * payload_bytes
    t = (per_pu / sys.noc_link_bw_bytes
         + (p - 1) * sys.noc_latency_cycles / sys.freq_hz)
    return CollectiveCost(int(per_pu * p), t)


def all_gather(sys: NMPSystem, shard_bytes: int) -> CollectiveCost:
    """Each PU holds `shard_bytes`; result is P * shard_bytes everywhere."""
    p = sys.pus
    per_pu = (p - 1) * shard_bytes
    t = (per_pu / sys.noc_link_bw_bytes
         + (p - 1) * sys.noc_latency_cycles / sys.freq_hz)
    return CollectiveCost(per_pu * p, t)


def all_to_all(sys: NMPSystem, total_bytes: int) -> CollectiveCost:
    """Token dispatch: every PU exchanges (P-1)/P of its 1/P share."""
    p = sys.pus
    per_pu = total_bytes / p * (p - 1) / p
    t = (per_pu / sys.noc_link_bw_bytes
         + (p - 1) * sys.noc_latency_cycles / sys.freq_hz)
    return CollectiveCost(int(per_pu * p), t)


def page_gather(sys: NMPSystem, local_bytes: float, remote_bytes: float,
                remote_segments: int) -> CollectiveCost:
    """Paged KV gather DMA, issued by ONE PU.

    Pages under the issuing PU's own memory channel stream at that
    channel's internal bandwidth (``dram_bw_per_pu``); pages under other
    channels must cross the NoC and all funnel through the issuing PU's
    single injection port (``noc_link_bw_bytes``), serialized, plus one
    per-segment hop latency for each distinct remote channel touched.
    This is the asymmetry stack-aware placement exists to exploit: on
    the Stratum-class template the channel-internal path is ~2.4x the
    injection port, so a block table concentrated in one region beats
    the same table striped across the die.
    """
    if local_bytes < 0 or remote_bytes < 0 or remote_segments < 0:
        raise ValueError("gather byte counts must be non-negative")
    t = (local_bytes / sys.dram_bw_per_pu
         + remote_bytes / sys.noc_link_bw_bytes
         + remote_segments * sys.noc_latency_cycles / sys.freq_hz)
    return CollectiveCost(int(remote_bytes), t)


def page_ship(sys: NMPSystem, payload_bytes: float, segments: int,
              hops: int = 1) -> CollectiveCost:
    """KV pages shipped between stacks: the cross-stack generalization of
    :func:`page_gather` that prices prefill->decode tier handoff.

    The source stack gathers the pages exactly as ``page_gather`` would
    for an all-remote block table (``segments`` distinct page extents
    funneling through one injection port); the payload then crosses
    ``hops`` inter-stack links at the device interconnect bandwidth
    (``xlink_bw_bytes``, one ``xlink_latency_s`` setup per hop) and is
    scattered into the destination pool at that stack's channel-internal
    bandwidth.  ``hops=0`` degrades *exactly* to the intra-stack gather —
    the same primitive prices spilled-page migration and defrag moves
    inside one pool, so there is a single page-movement cost path.
    """
    if hops < 0:
        raise ValueError("hop count must be non-negative")
    base = page_gather(sys, 0, payload_bytes, segments)
    if hops == 0:
        return base
    t = (base.time_s
         + payload_bytes / sys.xlink_bw_bytes
         + hops * sys.xlink_latency_s
         + payload_bytes / sys.dram_bw_per_pu)
    return CollectiveCost(int(payload_bytes), t)
