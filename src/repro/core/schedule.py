"""Multi-PU scheduling via spatial and spatio-temporal partitioning (§5).

For every projection/FFN/expert GEMM the scheduler evaluates the paper's four
partitioning modes and picks the fastest:

  IS-S  : K split spatially across PUs, N temporal         -> all-reduce(MxN)
  IS-ST : IS-S + N blocked in time (overlaps the reduce)
  OS-S  : N split spatially across PUs, K temporal         -> all-gather(MxN)
  OS-ST : OS-S + K blocked in time (overlaps gather/vector)

M is never split across PUs (weight replication cost, §3.1).  Attention
QK/AV use head-level parallelism with softmax interleaving; MoE experts are
PU-distributed with all-to-all token dispatch.  Output-layout chaining lets
an OS-S producer feed an IS-S consumer without the all-gather (the consumer's
spatial K split matches the producer's N shard).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import List, Optional, Sequence, Tuple

from repro.core import noc
from repro.core.dataflow import (CoreExec, best_logical_shape, mactree_gemm,
                                 sa_gemm)
from repro.core.energy import EnergyReport, gemm_energy
from repro.core.gemm import Dataflow, Gemm, OpClass, ceil_div
from repro.core.hw import (FP16_BYTES, MacTreeConfig, NMPSystem,
                           SystolicArrayConfig)


class Mode(Enum):
    IS_S = "IS-S"
    IS_ST = "IS-ST"
    OS_S = "OS-S"
    OS_ST = "OS-ST"


IS_MODES = (Mode.IS_S, Mode.IS_ST)
OS_MODES = (Mode.OS_S, Mode.OS_ST)
ST_BLOCKS = 4                      # temporal blocks in ST modes
OVERLAP_FRACTION = {Dataflow.OS: 0.6, Dataflow.IS: 0.2}  # tile-level (§5b)
VECTOR_OPS_PER_ELEM = 6.0          # avg lane-ops per nonlinear element


@dataclass(frozen=True)
class OpExec:
    """System-level execution report for one operator."""

    op: Gemm
    mode: str
    time_s: float
    compute_s: float               # array-occupancy component (per unit)
    memory_s: float                # DRAM-supply component (per unit)
    comm_s: float                  # exposed collective time
    vector_s: float                # exposed vector/nonlinear time
    energy: EnergyReport
    core: Optional[CoreExec] = None
    out_layout: str = "replicated"  # or "n_sharded"

    @property
    def stalled(self) -> bool:
        return self.memory_s > self.compute_s


# ---------------------------------------------------------------------------
# Substrate helpers
# ---------------------------------------------------------------------------
def _is_sa(sys: NMPSystem) -> bool:
    return isinstance(sys.substrate, SystolicArrayConfig)


def exec_units(sys: NMPSystem) -> int:
    """Independent compute units system-wide (SA cores or MAC-tree PUs)."""
    return sys.cores if _is_sa(sys) else sys.pus


def unit_bw(sys: NMPSystem) -> float:
    return sys.dram_bw_per_core if _is_sa(sys) else sys.dram_bw_per_pu


def core_exec(sys: NMPSystem, g: Gemm, dataflow: Dataflow) -> CoreExec:
    if _is_sa(sys):
        sa = sys.substrate
        rows, cols = best_logical_shape(sa, g.m)
        return sa_gemm(g, rows, cols, dataflow, sa.buffers,
                       sa.pipelined_fills)
    return mactree_gemm(g, sys.substrate)


def _vector_time(sys: NMPSystem, elems: float, pus_active: int = 0) -> float:
    pus_active = pus_active or sys.pus
    lanes = sys.vector.lanes * (sys.cores_per_pu if _is_sa(sys) else 1)
    rate = pus_active * lanes * sys.freq_hz
    return elems * VECTOR_OPS_PER_ELEM / rate


def _vector_ops(elems: float) -> float:
    return elems * VECTOR_OPS_PER_ELEM


# ---------------------------------------------------------------------------
# Projection scheduling: the 4-mode search
# ---------------------------------------------------------------------------
def _mode_exec(sys: NMPSystem, g: Gemm, mode: Mode,
               consumer_chains_k: bool = False) -> OpExec:
    """Evaluate one (projection GEMM, mode) pair on the full system."""
    p = sys.pus
    df = Dataflow.IS if mode in IS_MODES else Dataflow.OS
    # --- spatial split across PUs ------------------------------------------
    g_pu = g.split_k(p) if df == Dataflow.IS else g.split_n(p)
    # --- within a PU, cores split the temporal dimension --------------------
    combine_elems = 0.0
    if _is_sa(sys):
        c = sys.cores_per_pu
        if df == Dataflow.IS:
            g_core = g_pu.split_n(c)
        else:
            # OS temporal = K; per-core partials summed by the vector core.
            g_core = g_pu.split_k(c)
            combine_elems = (c - 1) * g.m * g_pu.n
    else:
        g_core = g_pu
    core = core_exec(sys, g_core, df)
    bw = unit_bw(sys)
    compute_s = core.compute_time(sys.freq_hz)
    memory_s = core.memory_time(bw)
    linear_s = max(compute_s, memory_s)

    # --- collectives ---------------------------------------------------------
    out_bytes = g.m * g.n * FP16_BYTES
    if df == Dataflow.IS:
        cc = noc.all_reduce(sys, out_bytes)
        out_layout = "replicated"
    else:
        if consumer_chains_k:
            cc = noc.CollectiveCost(0, 0.0)
            out_layout = "n_sharded"
        else:
            cc = noc.all_gather(sys, out_bytes // p)
            out_layout = "replicated"

    vec_s = _vector_time(sys, g.nonlinear_elems + combine_elems)
    tail = cc.time_s + vec_s

    if mode in (Mode.IS_S, Mode.OS_S):
        ov = OVERLAP_FRACTION[df]
        exposed_tail = cc.time_s + vec_s * (1 - ov)
        time_s = linear_s + exposed_tail
        comm_exposed, vec_exposed = cc.time_s, vec_s * (1 - ov)
    else:
        # ST: temporal blocking pipelines linear against (comm + vector).
        fill_overhead = 0.0
        if core.spatial_tiles and _is_sa(sys):
            r, c_ = core.logical_shape
            tiles = (1 if sys.substrate.pipelined_fills
                     else core.spatial_tiles)
            fill_overhead = ((ST_BLOCKS - 1) * tiles
                             * (r + c_ - 2) / sys.freq_hz)
        time_s = (max(linear_s, tail) + min(linear_s, tail) / ST_BLOCKS
                  + fill_overhead)
        hidden = min(linear_s, tail) * (1 - 1 / ST_BLOCKS)
        comm_exposed = max(0.0, cc.time_s - hidden)
        vec_exposed = max(0.0, tail - hidden - comm_exposed)

    energy = gemm_energy(
        sys, macs=g.macs,
        sram_bytes=core.sram_bytes * exec_units(sys),
        dram_bytes=core.dram_bytes * exec_units(sys),
        exec_time_s=time_s, noc_bytes=cc.bytes_on_wire,
        vector_ops=_vector_ops(g.nonlinear_elems + combine_elems))
    return OpExec(op=g, mode=mode.value, time_s=time_s, compute_s=compute_s,
                  memory_s=memory_s, comm_s=comm_exposed, vector_s=vec_exposed,
                  energy=energy, core=core, out_layout=out_layout)


def schedule_projection(sys: NMPSystem, g: Gemm,
                        consumer_chains_k: bool = False,
                        modes: Sequence[Mode] = tuple(Mode)) -> OpExec:
    """Per-operator lightweight search over the 4 partitioning modes."""
    cands = [_mode_exec(sys, g, m, consumer_chains_k) for m in modes]
    return min(cands, key=lambda e: e.time_s)


def mode_candidates(sys: NMPSystem, g: Gemm,
                    consumer_chains_k: bool = False) -> List[OpExec]:
    return [_mode_exec(sys, g, m, consumer_chains_k) for m in Mode]


# ---------------------------------------------------------------------------
# Multi-port logical sub-array packing (§4.2.1 / §4.2.2)
#
# SNAKE provisions g = 8 weight-injection ports (4 left + 4 right boundary),
# so the physical fabric can be partitioned into up to 8 independent logical
# sub-arrays, each streaming its OWN stationary-side operand.  Small-M units
# with distinct B matrices (attention (request, kv-head) units, MoE experts,
# MLA per-head absorbs) therefore run CONCURRENTLY on one core.  Fixed-shape
# baselines have a single injection port and process one unit at a time.
# ---------------------------------------------------------------------------
WEIGHT_PORTS = 8


def slice_pack(sys: NMPSystem, m: int) -> Tuple[int, Optional[Tuple[int, int]]]:
    """(units per core, per-slice logical shape) for concurrent small-M units.

    Returns (1, None) when packing is impossible (MAC tree, fixed SA, or M
    exceeding the physical row budget of a slice)."""
    if not _is_sa(sys):
        return 1, None
    sa = sys.substrate
    if not sa.reconfigurable:
        return 1, None
    # slice rows must divide the physical fabric exactly (serpentine remap
    # concatenates whole row groups): round M up to the next legal logical
    # row count
    rows = None
    for r in sorted(sa.logical_row_options):
        if m <= r:
            rows = r
            break
    if rows is None:
        return 1, None
    slices = min(WEIGHT_PORTS, sa.phys_rows // rows)
    cols = sa.pes // (slices * rows)
    return slices, (rows, cols)


def _pack_exec(sys: NMPSystem, g1: Gemm, df: Dataflow,
               pack: int) -> CoreExec:
    sa = sys.substrate
    _, shape = slice_pack(sys, g1.m)
    rows = shape[0]
    cols = sa.pes // (pack * rows)
    return sa_gemm(g1, rows, cols, df, sa.buffers, sa.pipelined_fills)


def _best_unit_exec(sys: NMPSystem, g1: Gemm, df: Dataflow,
                    units: int = 1, n_units: Optional[int] = None
                    ) -> Tuple[CoreExec, int]:
    """Best (exec, units-per-core) between whole-array and sliced mappings.

    Packing p concurrent units on one core shares that core's DRAM supply
    p ways, so it only wins when it reduces the number of waves and the
    sliced mapping stays compute-supplied — the scheduler minimizes
    waves(p) * max(t_compute(p), t_memory(bw / p)) over legal p.
    """
    n_units = n_units or exec_units(sys)
    bw = unit_bw(sys)
    f = sys.freq_hz

    def total(ex: CoreExec, p: int) -> float:
        return ceil_div(units, n_units * p) * max(
            ex.compute_time(f), ex.memory_time(bw / p))

    base = core_exec(sys, g1, df)
    best, best_t = (base, 1), total(base, 1)
    max_slices, _ = slice_pack(sys, g1.m)
    p = 2
    while p <= max_slices:
        ex = _pack_exec(sys, g1, df, p)
        t = total(ex, p)
        if t < best_t:
            best, best_t = (ex, p), t
        p *= 2
    return best


def slice_pack_exec(sys: NMPSystem, g1: Gemm, df: Dataflow,
                    units: int = 1) -> Tuple[CoreExec, int]:
    """Public alias of the (exec, packing) selection for other schedulers."""
    return _best_unit_exec(sys, g1, df, units)


# ---------------------------------------------------------------------------
# Attention: head-level parallelism with softmax interleaving (§5b)
# ---------------------------------------------------------------------------
def schedule_attention(sys: NMPSystem, qk: Gemm, av: Gemm) -> OpExec:
    """Map (request, kv-head) units round-robin over compute units.

    Per unit: QK (IS: N=ctx temporal) -> softmax -> AV (OS: K=ctx temporal);
    the softmax of unit i overlaps the GEMMs of unit i+1 on the same core, so
    only the last softmax is exposed (vector throughput permitting).

    When there are fewer units than cores (large-M MLA attention, or few
    requests per device under TP), the M (head) dimension is further split
    across unit groups — the paper's head-level parallelism applied *within*
    one request — and, if cores still remain idle, the context dimension is
    split too (QK's N / AV's K), with the partial softmaxes merged by the
    vector core via an lse-combine (exactly the flash-decode shard merge).
    """
    assert qk.count == av.count
    units0 = qk.count
    n_units = exec_units(sys)
    bw = unit_bw(sys)
    f = sys.freq_hz
    gran = getattr(sys.substrate, "reconfig_granularity", 8)
    can_pack = _is_sa(sys) and sys.substrate.reconfigurable

    # --- joint search over (head-split, ctx-split, slice-pack) --------------
    # hgroups splits the per-unit M (head) dimension; sgroups splits the
    # context (QK's N / AV's K) with an lse-combine epilogue; pack runs that
    # many units concurrently on one core's multi-port logical sub-arrays.
    best = None
    hg_opts = [h for h in (1, 2, 4, 8, 16)
               if h == 1 or (qk.m > gran and ceil_div(qk.m, h) >= gran)]
    for hgroups in hg_opts:
        m_sub = ceil_div(qk.m, hgroups)
        for sgroups in (1, 2, 4, 8, 16, 32):
            n_sub = ceil_div(qk.n, sgroups)
            if sgroups > 1 and n_sub < 512:
                continue                      # shards too thin to amortize
            units = units0 * hgroups * sgroups
            qk1 = qk.scaled(count=1, m=m_sub, n=n_sub)
            av1 = av.scaled(count=1, m=m_sub, k=n_sub)
            packs = (1, 2, 4, 8) if can_pack else (1,)
            for pack in packs:
                if pack > 1:
                    mx, _ = slice_pack(sys, m_sub)
                    if pack > mx:
                        continue
                    eqk = _pack_exec(sys, qk1, Dataflow.IS, pack)
                    eav = _pack_exec(sys, av1, Dataflow.OS, pack)
                else:
                    eqk = core_exec(sys, qk1, Dataflow.IS)
                    eav = core_exec(sys, av1, Dataflow.OS)
                waves = ceil_div(units, n_units * pack)
                t_unit = (max(eqk.compute_time(f), eqk.memory_time(bw / pack))
                          + max(eav.compute_time(f),
                                eav.memory_time(bw / pack)))
                combine = (sgroups - 1) * units0 * hgroups * m_sub * av.n
                t = waves * t_unit + _vector_time(sys, combine)
                if best is None or t < best[0]:
                    best = (t, eqk, eav, pack, waves, t_unit, combine,
                            qk1, av1, units)
    (_, eqk, eav, pack, waves, t_unit, combine_elems, qk1, av1,
     units) = best
    qk = qk1.scaled(count=units)
    av = av1.scaled(count=units)
    # Exposed first-tile KV fetch (one head's K-block cannot hide DRAM
    # latency behind compute, §5b) — one refill per wave is exposed.
    first_fill = min(eqk.dram_bytes, sys.substrate.buffers.half("weight"))
    t_unit_first = first_fill / bw

    softmax_elems = qk1.m * qk1.n       # per-unit score-row softmax
    t_softmax = _vector_time(sys, softmax_elems, pus_active=1)
    # interleaved: exposed softmax = last unit only (plus any spill where
    # softmax is longer than the next unit's GEMM time)
    spill = max(0.0, t_softmax - t_unit) * max(0, waves - 1)
    t_combine = _vector_time(sys, combine_elems)
    time_s = waves * t_unit + t_unit_first + t_softmax + spill + t_combine

    active_units = min(units, n_units)
    dram = (eqk.dram_bytes + eav.dram_bytes) * units
    sram = (eqk.sram_bytes + eav.sram_bytes) * units
    energy = gemm_energy(sys, macs=qk.macs + av.macs, sram_bytes=sram,
                         dram_bytes=dram, exec_time_s=time_s,
                         vector_ops=_vector_ops(softmax_elems * units
                                                + combine_elems))
    compute_s = waves * (eqk.compute_time(sys.freq_hz)
                         + eav.compute_time(sys.freq_hz))
    memory_s = waves * (eqk.memory_time(bw) + eav.memory_time(bw))
    del active_units
    return OpExec(op=qk.scaled(), mode="HEAD-P", time_s=time_s,
                  compute_s=compute_s, memory_s=memory_s, comm_s=0.0,
                  vector_s=t_softmax + spill + t_combine, energy=energy,
                  core=eqk)


# ---------------------------------------------------------------------------
# MoE experts: PU-distributed with all-to-all dispatch
# ---------------------------------------------------------------------------
def schedule_experts(sys: NMPSystem, experts: Sequence[Gemm],
                     dispatch_bytes: int,
                     force_df: Optional[Dataflow] = None) -> OpExec:
    """Distribute expert GEMMs over PUs; cores split each expert's N.

    Dispatch (tokens -> expert PUs) and the weighted-sum combine ride the
    NoC; expert weight streaming is the dominant DRAM traffic (decode MoE has
    tiny per-expert M).
    """
    assert experts
    units = experts[0].count
    n_units = exec_units(sys)          # SA: cores; MAC tree: PUs
    # Experts map to compute units at unit granularity (one expert per SA
    # core / MAC-tree PU).  Only when there are fewer active experts than
    # units is each expert's N split across a unit group so the whole die
    # stays busy (intra-operator spatial partitioning at the expert level).
    group = max(1, n_units // units) if units < n_units else 1
    eff_units = n_units // group
    bw = unit_bw(sys)
    t_wave = 0.0
    compute_s = memory_s = 0.0
    dram = sram = 0
    macs = 0
    vec_elems = 0.0
    waves = ceil_div(units, eff_units)
    for g in experts:
        g_core = g.scaled(count=1).split_n(group)
        # per-operator dataflow search (forced in the fixed-mode study);
        # §4.2.1 slice packing: tiny-M experts (decode MoE) share one core's
        # fabric across multi-port logical sub-arrays.
        cands = (force_df,) if force_df else (Dataflow.IS, Dataflow.OS)
        best = None
        for df in cands:
            ex_c, pack_c = (_best_unit_exec(sys, g_core, df, units,
                                            eff_units)
                            if group == 1
                            else (core_exec(sys, g_core, df), 1))
            t_c = (ceil_div(units, eff_units * pack_c)
                   * max(ex_c.compute_time(sys.freq_hz),
                         ex_c.memory_time(bw / pack_c)))
            if best is None or t_c < best[0]:
                best = (t_c, ex_c, pack_c)
        _, ex, pack = best
        waves = ceil_div(units, eff_units * pack)
        t_wave += max(ex.compute_time(sys.freq_hz),
                      ex.memory_time(bw / pack))
        compute_s += ex.compute_time(sys.freq_hz)
        memory_s += ex.memory_time(bw / pack)
        dram += ex.dram_bytes * group * units
        sram += ex.sram_bytes * group * units
        macs += g.macs
        vec_elems += g.nonlinear_elems * units

    cc = noc.all_to_all(sys, dispatch_bytes)
    t_vec = _vector_time(sys, vec_elems)
    # Dispatch overlaps the previous layer tail in practice; we charge it
    # here fully (conservative), combine partially overlaps expert waves.
    time_s = cc.time_s + waves * t_wave + t_vec * 0.4
    energy = gemm_energy(sys, macs=macs, sram_bytes=sram, dram_bytes=dram,
                         exec_time_s=time_s, noc_bytes=cc.bytes_on_wire,
                         vector_ops=_vector_ops(vec_elems))
    return OpExec(op=experts[0], mode="EXPERT-P", time_s=time_s,
                  compute_s=waves * compute_s, memory_s=waves * memory_s,
                  comm_s=cc.time_s, vector_s=t_vec * 0.4, energy=energy)


# ---------------------------------------------------------------------------
# Chained scheduling over an operator sequence (assembles the best combo)
# ---------------------------------------------------------------------------
def schedule_chain(sys: NMPSystem, ops: Sequence[Gemm]) -> List[OpExec]:
    """DP over output layouts: OS-S producers may skip the all-gather when
    the next projection takes the sharded dim as its K (§5b "assembles the
    corresponding best scheduling combination for the full network")."""
    n = len(ops)
    if n == 0:
        return []
    # state: output layout after op i ("replicated" | "n_sharded")
    # n_sharded is only consumable if next op's K == this op's N.
    INF = float("inf")
    best: List[dict] = [dict() for _ in range(n + 1)]
    best[0]["replicated"] = (0.0, None, None)
    for i, g in enumerate(ops):
        for layout, (t_acc, _, _) in list(best[i].items()):
            chainable = [False]
            if i + 1 < n and ops[i + 1].k == g.n and ops[i + 1].count == g.count == 1:
                chainable.append(True)
            for chain in chainable:
                for m in Mode:
                    if chain and m not in OS_MODES:
                        continue
                    ex = _mode_exec(sys, g, m, consumer_chains_k=chain)
                    # consuming a sharded input requires an IS (K-split) mode
                    if layout == "n_sharded" and m not in IS_MODES:
                        continue
                    out_l = ex.out_layout
                    t_new = t_acc + ex.time_s
                    cur = best[i + 1].get(out_l, (INF, None, None))
                    if t_new < cur[0]:
                        best[i + 1][out_l] = (t_new, (layout, m, chain), ex)
    # backtrack cheapest end state that is replicated (layer boundary)
    end = best[n].get("replicated") or min(best[n].values(), key=lambda v: v[0])
    # Reconstruct by re-walking (stores only one predecessor per state;
    # sufficient since we kept argmin transitions).
    schedule: List[OpExec] = []
    state = "replicated" if "replicated" in best[n] else list(best[n])[0]
    for i in range(n, 0, -1):
        t, pred, ex = best[i][state]
        schedule.append(ex)
        state = pred[0]
    schedule.reverse()
    del end
    return schedule


# ---------------------------------------------------------------------------
# Substrate-configuration fingerprints (live co-design loop)
# ---------------------------------------------------------------------------
def exec_config(execs: Sequence[OpExec]) -> tuple:
    """Hashable substrate-configuration fingerprint of a scheduled step:
    per operator, the partitioning mode and the logical array shape it
    ran on.  Two steps with equal fingerprints drive an identically
    configured substrate, which is what the serving tick memoizes on."""
    return tuple(
        (ex.mode,
         tuple(ex.core.logical_shape) if ex.core is not None else ())
        for ex in execs)


def shape_profile(execs: Sequence[OpExec]) -> tuple:
    """The distinct logical array shapes a scheduled step uses, sorted.
    A serving tick pays a substrate reconfiguration only when this
    profile changes between consecutive ticks — a fixed-shape array has a
    single legal shape, so its profile never changes."""
    return tuple(sorted({tuple(ex.core.logical_shape) for ex in execs
                         if ex.core is not None}))
