"""Hardware configurations for the 3D-stacked NMP substrate study.

Everything here is calibrated to the paper's §6.1/§6.2 setup:

* Stratum-class HBM3 system template: 16 processing units (PUs) on one logic
  die, each PU bound to one memory channel; effective internal DRAM bandwidth
  fixed at 24 TB/s (midpoint of Stratum's reported range); lightweight NoC for
  coarse-grained collectives only.
* Per-PU logic area budget 2.35 mm^2.  Under that budget the paper's RTL
  calibration fits:
    - MAC-tree baseline:      16x16x16  =  4,096 MACs / PU @ 1.0 GHz
    - conventional SA + VC:   4 x 48x48 =  9,216 MACs / PU @ 1.0 GHz
      (also instantiated as 4 x 8x288 with the same MAC count)
    - SNAKE (this work):      4 x 64x64 = 16,384 MACs / PU @ 0.8 GHz
  giving the paper's 2.25x / 4.00x compute-area-efficiency ratios.
* Logic-die power envelope 62 W (85C cap): 38.5 W matrix, 14.2 W vector,
  4.4 W PE control, 4.8 W NoC at peak -> used to calibrate energy constants.

The TPU v5e constants at the bottom are used by the *TPU* roofline tooling
(`repro.analysis.roofline`), not by the NMP model.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

FP16_BYTES = 2


# ---------------------------------------------------------------------------
# Buffers
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BufferConfig:
    """Per-core SRAM buffer capacities in bytes.

    ``weight`` is the boundary buffer feeding the stationary-side operand
    (paper: left/right boundary buffers, largest allocation).  ``act`` is the
    streaming-side (input under OS / output-activation under IS) buffer and
    ``out`` the banked 2R/2W output buffer shared with the vector core.
    All buffers are double-buffered: half the capacity stages the live tile,
    half prefetches the next one.
    """

    weight: int
    act: int
    out: int

    @property
    def total(self) -> int:
        return self.weight + self.act + self.out

    def half(self, which: str) -> int:
        """Usable single-buffer capacity (double buffering halves it)."""
        return getattr(self, which) // 2


# ---------------------------------------------------------------------------
# Compute substrates
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SystolicArrayConfig:
    """A fixed-shape or reconfigurable systolic array core."""

    name: str
    phys_rows: int
    phys_cols: int
    freq_ghz: float
    buffers: BufferConfig
    # Reconfigurability: legal logical row counts (serpentine remap).  A fixed
    # array has exactly one entry equal to phys_rows.
    logical_row_options: Tuple[int, ...] = ()
    reconfig_granularity: int = 8
    # Pipeline fill/drain is (rows + cols - 2) cycles per spatial tile chain.
    # Mode switch (paper 4.2.1) costs one cycle -> negligible, kept for audit.
    reconfig_cycles: int = 1
    # §4.2.4: SNAKE's decoder splits each matmul into pipelined sub-stages
    # (Weight Load / Feed First/Second / Drain) so consecutive tiles overlap
    # their fill with the previous tile's drain — only the first fill is
    # exposed.  Conventional fixed-shape SA baselines expose fill per tile.
    pipelined_fills: bool = False
    # §4.2.3: the unified systolic-vector substrate (shared 2R/2W output
    # buffer) lets vector post-processing overlap GEMM tiles; baselines with
    # a private vector core get no tile-level overlap.
    unified_vector: bool = False

    def __post_init__(self):
        if not self.logical_row_options:
            object.__setattr__(self, "logical_row_options", (self.phys_rows,))
        for r in self.logical_row_options:
            assert self.pes % r == 0, f"rows {r} must divide PE count"

    @property
    def pes(self) -> int:
        return self.phys_rows * self.phys_cols

    def logical_shapes(self) -> Tuple[Tuple[int, int], ...]:
        return tuple((r, self.pes // r) for r in self.logical_row_options)

    @property
    def reconfigurable(self) -> bool:
        return len(self.logical_row_options) > 1


@dataclass(frozen=True)
class MacTreeConfig:
    """MAC-tree compute unit (Stratum-style baseline).

    Organized as an (m x n x k) block of multipliers feeding adder trees:
    every cycle it can retire an m x n output block of depth-k partial
    reductions.  No systolic fill/drain, but operand delivery is broadcast
    (high fan-out) so per-MAC SRAM traffic is higher (`operand_fetch_ratio`
    relative to a systolic array's boundary injection).
    """

    name: str
    m: int
    n: int
    k: int
    freq_ghz: float
    buffers: BufferConfig
    # SRAM elements fetched per MAC: tree fetches m*k + k*n operands per cycle
    # for m*n*k MACs; SA injects rows+cols per cycle for rows*cols MACs.
    @property
    def pes(self) -> int:
        return self.m * self.n * self.k

    @property
    def operand_elems_per_cycle(self) -> int:
        return self.m * self.k + self.k * self.n


@dataclass(frozen=True)
class VectorCoreConfig:
    lanes: int = 512            # elementwise ops / cycle / core
    special_func_factor: float = 4.0   # exp/div etc. cost this many lane-ops


# ---------------------------------------------------------------------------
# System template (Stratum-class)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class NMPSystem:
    """One 3D-stacked NMP device: a logic die under a DRAM stack."""

    name: str
    substrate: object                  # SystolicArrayConfig | MacTreeConfig
    pus: int = 16
    cores_per_pu: int = 4
    dram_bw_bytes: float = 24e12       # effective internal bandwidth, total
    dram_bw_efficiency: float = 0.90   # bank-bundle scheduling efficiency
    noc_link_bw_bytes: float = 512e9   # per-PU NoC injection bandwidth
    noc_latency_cycles: int = 64       # per-hop/segment latency
    # Cross-device interconnect for multi-device tensor parallelism (the
    # paper's §6.1.3 8-device TP=8 system rides the Duplex host links; we
    # keep the Duplex/NVLink-class numbers).
    xlink_bw_bytes: float = 450e9      # per device, per direction
    xlink_latency_s: float = 4e-6      # per collective
    vector: VectorCoreConfig = field(default_factory=VectorCoreConfig)
    # Energy constants (pJ), calibrated against the paper's 61.8 W breakdown.
    e_mac_pj: float = 0.184            # per MAC (2 FLOPs), fp16, 7 nm
    e_sram_pj_per_byte: float = 0.08
    e_dram_pj_per_byte: float = 2.0    # 3D TSV/hybrid-bond stack access
    e_noc_pj_per_byte: float = 0.10
    e_vector_pj_per_op: float = 0.55   # calibrated: 14.2 W vector at peak
    ctrl_power_w: float = 4.4          # PE control, always-on while active
    noc_idle_power_w: float = 1.0
    mactree_fetch_energy_scale: float = 1.0

    # ---- derived -----------------------------------------------------------
    @property
    def cores(self) -> int:
        return self.pus * self.cores_per_pu

    @property
    def macs_per_pu(self) -> int:
        if isinstance(self.substrate, SystolicArrayConfig):
            return self.cores_per_pu * self.substrate.pes
        return self.substrate.pes  # MAC tree configured at PU granularity

    @property
    def freq_hz(self) -> float:
        return self.substrate.freq_ghz * 1e9

    @property
    def peak_flops(self) -> float:
        return self.pus * self.macs_per_pu * 2 * self.freq_hz

    @property
    def ridge_point(self) -> float:
        """FLOP/byte at which compute and memory times balance."""
        return self.peak_flops / self.effective_dram_bw

    @property
    def effective_dram_bw(self) -> float:
        return self.dram_bw_bytes * self.dram_bw_efficiency

    @property
    def dram_bw_per_pu(self) -> float:
        return self.effective_dram_bw / self.pus

    @property
    def dram_bw_per_core(self) -> float:
        if isinstance(self.substrate, SystolicArrayConfig):
            return self.dram_bw_per_pu / self.cores_per_pu
        return self.dram_bw_per_pu


# ---------------------------------------------------------------------------
# Concrete instances (paper §6.1.2 / §6.2)
# ---------------------------------------------------------------------------
def snake_system(**over) -> NMPSystem:
    """SNAKE: reconfigurable 64x64 serpentine array, 4/PU, 16 PUs, 0.8 GHz."""
    sa = SystolicArrayConfig(
        name="snake-64x64",
        phys_rows=64,
        phys_cols=64,
        freq_ghz=0.8,
        # Post-reallocation buffers (paper Fig. 11: buffering area shrinks
        # from 53.6% -> 28.1% of the PU; reclaimed area went to PEs).
        buffers=BufferConfig(weight=256 * 1024, act=64 * 1024, out=128 * 1024),
        logical_row_options=(8, 16, 32, 64),
        pipelined_fills=True,
        unified_vector=True,
    )
    return NMPSystem(name="SNAKE", substrate=sa, **over)


def fixed_sa_system(rows: int, cols: int, **over) -> NMPSystem:
    """Conventional fixed-shape SA + private vector core baseline @1 GHz."""
    sa = SystolicArrayConfig(
        name=f"sa-{rows}x{cols}",
        phys_rows=rows,
        phys_cols=cols,
        freq_ghz=1.0,
        # Conventional allocation: large double buffers (53.6% of PU area).
        buffers=BufferConfig(weight=512 * 1024, act=128 * 1024, out=256 * 1024),
        logical_row_options=(rows,),
    )
    return NMPSystem(name=f"SA-{rows}x{cols}", substrate=sa, **over)


def mactree_system(**over) -> NMPSystem:
    """Stratum-configured MAC-tree baseline: 16x16x16 per PU @ 1 GHz.

    Energy: the paper's RTL calibration found the MAC tree needs 8.23x the
    area of a SA at equal PE-level function; switched capacitance tracks
    area, and the broadcast/reduction networks burn additional wire energy —
    charged via a higher per-MAC energy and an SRAM fetch-energy scale.
    """
    mt = MacTreeConfig(
        name="mactree-16x16x16",
        m=16,
        n=16,
        k=16,
        freq_ghz=1.0,
        buffers=BufferConfig(weight=512 * 1024, act=128 * 1024, out=256 * 1024),
    )
    over.setdefault("e_mac_pj", 0.46)
    over.setdefault("mactree_fetch_energy_scale", 2.5)
    return NMPSystem(name="MAC-Tree", substrate=mt, **over)


@dataclass(frozen=True)
class GPUConfig:
    """H100-class decode baseline (per device)."""

    name: str = "H100"
    peak_flops: float = 989e12          # bf16/fp16 dense
    hbm_bw_bytes: float = 3.35e12
    # Decode-serving achieved efficiencies: unfused GEMV/attention kernels on
    # H100 sustain ~45-55% of HBM peak and well under half of tensor-core
    # peak at small M (vLLM/TensorRT-LLM decode profiles).
    mem_efficiency: float = 0.50        # achieved fraction on decode GEMV/GEMM
    compute_efficiency: float = 0.40    # achieved fraction of peak on decode
    nvlink_bw_bytes: float = 450e9      # per direction, per GPU
    kernel_overhead_s: float = 5e-6     # launch+sync per fused op group
    power_w: float = 550.0              # sustained decode board power
    tdp_w: float = 700.0
    # Per-op silicon/DRAM energy accounting (comparable to the NMP model's
    # logic-die + stack accounting rather than wall-plug board power):
    e_flop_pj: float = 0.5              # tensor-core + datapath, 4N-class
    e_hbm_pj_per_byte: float = 5.5      # off-chip HBM3 access
    static_w: float = 18.0              # leakage share attributed to decode


H100 = GPUConfig()

# TPU v5e constants — used ONLY by repro.analysis.roofline for the dry-run.
TPU_V5E_PEAK_FLOPS = 197e12     # bf16
TPU_V5E_HBM_BW = 819e9          # bytes/s
TPU_V5E_ICI_BW = 50e9           # bytes/s per link
TPU_V5E_HBM_GB = 16.0


def area_model() -> dict:
    """Paper Fig. 11 PU-level compute-area-efficiency calibration.

    All three designs fit the same 2.35 mm^2 PU budget; compute-area
    efficiency is MACs per budget, normalized to the MAC tree.
    """
    budget_mm2 = 2.35
    rows = {
        "MAC-Tree": dict(macs=4096, freq_ghz=1.0,
                         breakdown=dict(compute=0.285, buffers=0.49,
                                        vector=0.16, control=0.065)),
        "SA+VectorCore": dict(macs=9216, freq_ghz=1.0,
                              breakdown=dict(compute=0.30, buffers=0.536,
                                             vector=0.11, control=0.054)),
        "SNAKE": dict(macs=16384, freq_ghz=0.8,
                      breakdown=dict(compute=0.543, buffers=0.281,
                                     vector=0.088, control=0.088)),
    }
    base = rows["MAC-Tree"]["macs"]
    out = {}
    for name, r in rows.items():
        out[name] = dict(
            budget_mm2=budget_mm2,
            macs=r["macs"],
            freq_ghz=r["freq_ghz"],
            breakdown=r["breakdown"],
            compute_area_efficiency=r["macs"] / base,
        )
    return out
