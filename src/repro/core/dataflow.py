"""Single-core systolic / MAC-tree execution models (paper §3.1, Fig. 3-4).

The model is tile-level, SCALE-Sim-style: closed-form array cycles with
explicit pipeline fill/drain per spatial tile, plus a DRAM traffic model with
buffer-capacity-driven re-read multipliers, plus SRAM (boundary-injection)
traffic for the energy model.  Execution time on one core is

    t = max(array_cycles / f,  dram_bytes / bw_core) + first_fill_latency

i.e. double-buffered refill perfectly overlaps compute except for the first
tile; whichever of compute or memory supply is slower throttles the core
(this is exactly the decomposition shown in the paper's Fig. 1b).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.gemm import Dataflow, Gemm, ceil_div
from repro.core.hw import (FP16_BYTES, BufferConfig, MacTreeConfig,
                           SystolicArrayConfig)


@dataclass(frozen=True)
class CoreExec:
    """Execution report for one GEMM on one core."""

    array_cycles: int          # pure compute occupancy (incl. fill/drain)
    fill_drain_cycles: int     # portion of the above that is pipeline bubble
    dram_bytes: int            # DRAM traffic incl. capacity-induced re-reads
    sram_bytes: int            # SRAM <-> array boundary traffic
    spatial_tiles: int
    util: float                # MAC utilization of the occupied cycles
    dataflow: Dataflow
    logical_shape: tuple       # (rows, cols) used

    def compute_time(self, freq_hz: float) -> float:
        return self.array_cycles / freq_hz

    def memory_time(self, bw_bytes: float) -> float:
        return self.dram_bytes / bw_bytes

    def exec_time(self, freq_hz: float, bw_bytes: float,
                  first_fill_bytes: int = 0) -> float:
        t = max(self.compute_time(freq_hz), self.memory_time(bw_bytes))
        return t + first_fill_bytes / bw_bytes


# ---------------------------------------------------------------------------
# Systolic array
# ---------------------------------------------------------------------------
def sa_gemm(g: Gemm, rows: int, cols: int, dataflow: Dataflow,
            bufs: BufferConfig, pipelined: bool = False) -> CoreExec:
    """Model one GEMM replica on an R x C logical systolic array.

    OS: M->rows, N->cols spatial; K temporal (partials stay in PEs).
    IS: M->rows, K->cols spatial; N temporal (inputs stay in PEs); partial
        sums across K-tiles accumulate through the output buffer.

    ``pipelined`` (paper §4.2.4, SNAKE only): matmul instructions split into
    Weight Load / Feed / Drain sub-stages so consecutive tiles overlap fill
    with drain — only the first fill is exposed.  Conventional fixed-shape
    baselines expose the (rows + cols - 2)-cycle bubble on every tile.
    """
    m, n, k = g.m, g.n, g.k
    fill = rows + cols - 2

    if dataflow == Dataflow.OS:
        tm, tn = ceil_div(m, rows), ceil_div(n, cols)
        tiles = tm * tn
        fd = fill if pipelined else tiles * fill
        cycles = tiles * k + fd
        # --- DRAM traffic: choose the loop order that minimizes it.
        a_tile = rows * k * FP16_BYTES
        b_tile = k * cols * FP16_BYTES
        a_all = m * k * FP16_BYTES
        b_all = k * n * FP16_BYTES
        c_all = m * n * FP16_BYTES
        # n-inner: A_mt held if it fits the act buffer -> read once per m-row;
        # B re-read for every m-row (unless all of B fits the weight buffer).
        a_reads_ni = 1 if a_tile <= bufs.half("act") else tn
        b_reads_ni = 1 if b_all <= bufs.half("weight") else tm
        # m-inner: B_nt held if it fits weight buffer; A re-read per n-col.
        b_reads_mi = 1 if b_tile <= bufs.half("weight") else tm
        a_reads_mi = 1 if a_all <= bufs.half("act") else tn
        dram = min(a_all * a_reads_ni + b_all * b_reads_ni,
                   a_all * a_reads_mi + b_all * b_reads_mi) + c_all
        # --- SRAM boundary traffic: every tile injects its operands once and
        # drains its outputs once.
        sram = (tn * a_all) + (tm * b_all) + 2 * c_all
        util = (m * n * k) / (cycles * rows * cols) if cycles else 0.0
        return CoreExec(cycles, fd, dram, sram, tiles, util,
                        Dataflow.OS, (rows, cols))

    # ---- IS ----------------------------------------------------------------
    tm, tk = ceil_div(m, rows), ceil_div(k, cols)
    tiles = tm * tk
    fd = fill if pipelined else tiles * fill
    cycles = tiles * n + fd
    a_all = m * k * FP16_BYTES          # stationary: touched exactly once
    b_all = k * n * FP16_BYTES
    c_all = m * n * FP16_BYTES
    # B is streamed per (m,k) tile; each k-tile uses a disjoint row-block of B
    # so re-reads only happen across m-tiles.
    b_reads = 1 if (tm == 1 or b_all <= bufs.half("weight")) else tm
    # Partial sums: R x N accumulated across the Tk tiles of each m-row.
    out_rows_bytes = min(m, rows) * n * FP16_BYTES
    if tk > 1 and out_rows_bytes > bufs.half("out"):
        # Partials spill to DRAM: one extra write+read round per extra k-tile.
        partial_dram = 2 * (tk - 1) * out_rows_bytes * tm
    else:
        partial_dram = 0
    dram = a_all + b_all * b_reads + c_all + partial_dram
    sram = a_all + tm * b_all + 2 * c_all + 2 * (tk - 1) * out_rows_bytes * tm
    util = (m * n * k) / (cycles * rows * cols) if cycles else 0.0
    return CoreExec(cycles, fd, dram, sram, tiles, util,
                    Dataflow.IS, (rows, cols))


def best_logical_shape(sa: SystolicArrayConfig, m: int) -> tuple:
    """Pick the serpentine logical shape for an operator's M dimension.

    SNAKE picks the narrowest legal shape whose row count covers M (padded to
    the reconfiguration granularity of 8); M larger than the widest option
    folds over the physical rows (paper §4.2.2).
    """
    shapes = sorted(sa.logical_shapes())  # ascending rows
    for r, c in shapes:
        if m <= r:
            return (r, c)
    return shapes[-1]


def sa_gemm_best(g: Gemm, sa: SystolicArrayConfig, dataflow: Dataflow) -> CoreExec:
    rows, cols = best_logical_shape(sa, g.m)
    return sa_gemm(g, rows, cols, dataflow, sa.buffers, sa.pipelined_fills)


def sa_gemm_auto(g: Gemm, sa: SystolicArrayConfig) -> CoreExec:
    """Shape + dataflow auto-selection (cycle count as the first-order key).

    Matches the paper's first-order rule: IS preferred when N > K (N goes
    temporal), OS when K >= N — both fall out of minimizing tile folds.
    The final scheduler re-evaluates with memory stalls included.
    """
    rows, cols = best_logical_shape(sa, g.m)
    os_ = sa_gemm(g, rows, cols, Dataflow.OS, sa.buffers, sa.pipelined_fills)
    is_ = sa_gemm(g, rows, cols, Dataflow.IS, sa.buffers, sa.pipelined_fills)
    # Tie-break on spatial tiles: fewer, longer-running tiles amortize
    # data-loading/startup and reduce tile switching (§3.1) — this is what
    # makes IS preferable for N > K and OS for K >= N.
    return min((os_, is_), key=lambda e: (e.array_cycles, e.spatial_tiles,
                                          e.dram_bytes))


# ---------------------------------------------------------------------------
# MAC tree
# ---------------------------------------------------------------------------
def mactree_gemm(g: Gemm, mt: MacTreeConfig) -> CoreExec:
    """MAC-tree model: per cycle, one (m x n) output block advances k steps.

    Fully pipelined (no systolic fill/drain), but dimension padding to the
    (m,n,k) organization wastes lanes — the M dimension is the painful one
    for decode — and operand delivery is broadcast: (m*k + k*n) operand
    fetches per cycle for m*n*k MACs, which the energy model charges.
    """
    tm, tn, tk = (ceil_div(g.m, mt.m), ceil_div(g.n, mt.n), ceil_div(g.k, mt.k))
    cycles = tm * tn * tk
    a_all = g.m * g.k * FP16_BYTES
    b_all = g.k * g.n * FP16_BYTES
    c_all = g.m * g.n * FP16_BYTES
    # Same capacity logic as the SA, at tree-block granularity.
    b_block = mt.k * mt.n * tk * FP16_BYTES  # one n-column strip, full K
    a_reads = 1 if a_all <= mt.buffers.half("act") else tn
    b_reads = 1 if (tm == 1 or b_all <= mt.buffers.half("weight")) else tm
    del b_block
    dram = a_all * a_reads + b_all * b_reads + c_all
    # Broadcast operand fetches: every cycle (m*k + k*n) elements from SRAM.
    sram = cycles * mt.operand_elems_per_cycle * FP16_BYTES + 2 * c_all
    util = (g.m * g.n * g.k) / (cycles * mt.pes)
    return CoreExec(cycles, 0, dram, sram, tm * tn, util,
                    Dataflow.OS, (mt.m, mt.n))


def mean_utilization(cores) -> float:
    """Array-cycle-weighted mean MAC utilization over a step's per-core
    executions (the live co-design loop's per-tick occupancy signal).
    0.0 when nothing ran on an array."""
    total = sum(c.array_cycles for c in cores)
    if total == 0:
        return 0.0
    return sum(c.util * c.array_cycles for c in cores) / total
