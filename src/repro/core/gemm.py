"""GEMM abstraction for LLM decode operators (paper §3.1, Fig. 3).

Every linear operator is abstracted as ``A[M,K] @ B[K,N] -> C[M,N]`` in fp16.
Decode operators satisfy ``M << N, K`` (M tracks the effective batch and
attention grouping), which is exactly the regime that motivates SNAKE's
shape/dataflow reconfigurability.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from enum import Enum
from typing import Optional

from repro.core.hw import FP16_BYTES


class OpClass(Enum):
    PROJECTION = "projection"      # QKV / O / FFN / router / head GEMMs
    ATTENTION_QK = "attention_qk"  # per (request, kv-group) score GEMM
    ATTENTION_AV = "attention_av"  # per (request, kv-group) value GEMM
    EXPERT_FFN = "expert_ffn"      # per-expert MoE GEMM


class Dataflow(Enum):
    OS = "OS"   # output-stationary: M,N spatial; K temporal
    IS = "IS"   # input-stationary:  M,K spatial; N temporal


@dataclass(frozen=True)
class Gemm:
    """One decode GEMM (possibly replicated ``count`` times, e.g. heads)."""

    name: str
    m: int
    n: int
    k: int
    count: int = 1
    op_class: OpClass = OpClass.PROJECTION
    # Element count of the nonlinear/vector stage consuming this GEMM's
    # output (softmax, SiLU*mul, norm...).  Used by the overlap model.
    nonlinear_elems: int = 0
    # Whether B (weights / K,V) must be (re)streamed from DRAM.  Attention
    # reads the KV cache (always DRAM); projections read weights (DRAM, but
    # shared across the `count` replicas).
    weight_reuse_across_count: bool = True

    def __post_init__(self):
        assert self.m >= 1 and self.n >= 1 and self.k >= 1 and self.count >= 1

    # ---- closed-form quantities --------------------------------------------
    @property
    def macs(self) -> int:
        return self.m * self.n * self.k * self.count

    @property
    def flops(self) -> int:
        return 2 * self.macs

    @property
    def a_bytes(self) -> int:
        return self.m * self.k * FP16_BYTES * self.count

    @property
    def b_bytes_once(self) -> int:
        """Bytes of B read once (weights shared across count if reusable)."""
        per = self.k * self.n * FP16_BYTES
        return per if self.weight_reuse_across_count else per * self.count

    @property
    def c_bytes(self) -> int:
        return self.m * self.n * FP16_BYTES * self.count

    @property
    def min_dram_bytes(self) -> int:
        """Compulsory DRAM traffic (each operand touched exactly once)."""
        return self.a_bytes + self.b_bytes_once + self.c_bytes

    @property
    def arithmetic_intensity(self) -> float:
        """FLOP per compulsory DRAM byte (paper Fig. 1a x-axis)."""
        return self.flops / self.min_dram_bytes

    def scaled(self, *, m: Optional[int] = None, n: Optional[int] = None,
               k: Optional[int] = None, count: Optional[int] = None) -> "Gemm":
        kw = {}
        if m is not None:
            kw["m"] = m
        if n is not None:
            kw["n"] = n
        if k is not None:
            kw["k"] = k
        if count is not None:
            kw["count"] = count
        return replace(self, **kw)

    def split_n(self, parts: int) -> "Gemm":
        assert parts >= 1
        return self.scaled(n=max(1, -(-self.n // parts)))

    def split_k(self, parts: int) -> "Gemm":
        assert parts >= 1
        return self.scaled(k=max(1, -(-self.k // parts)))


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: int, g: int) -> int:
    return ceil_div(x, g) * g
