"""Serving-wide observability: tracing, metrics, exporters (PR 9).

* :mod:`repro.obs.tracer` — typed lifecycle events + the no-op
  :data:`NULL_TRACER` the hot path defaults to;
* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket
  histograms, the shared :func:`pctl` quantile helper, and the
  metric-name contracts the mirror-drift checker enforces;
* :mod:`repro.obs.export` — Perfetto JSON, JSONL save/replay,
  :func:`trace_report` phase attribution.
"""
from repro.obs.export import (export_perfetto, load_jsonl, save_jsonl,
                              trace_report)
from repro.obs.metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                               MetricsRegistry, ROUTER_METRIC_CONTRACT,
                               SCHEDULER_METRIC_CONTRACT, pctl,
                               serving_registry)
from repro.obs.tracer import (EVENT_KINDS, NULL_TRACER, NullTracer,
                              TraceEvent, Tracer)

__all__ = [
    "EVENT_KINDS", "NULL_TRACER", "NullTracer", "TraceEvent", "Tracer",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "DEFAULT_BUCKETS",
    "SCHEDULER_METRIC_CONTRACT", "ROUTER_METRIC_CONTRACT", "pctl",
    "serving_registry", "export_perfetto", "save_jsonl", "load_jsonl",
    "trace_report",
]
