"""Metrics registry: counters, gauges, fixed-bucket histograms (PR 9).

This module is the single producer behind ``Scheduler.metrics`` and
``Router.metrics``: both build a :func:`serving_registry`, feed it the
run's samples, and read every statistical value they report back out of
it (the shared :func:`pctl` quantile helper replaced the duplicated
``np.percentile`` math that used to live in each).  Histograms keep the
exact sample list *alongside* the fixed bucket counts, so the reported
means/percentiles are numerically identical to the pre-registry values
while the bucketed summaries (the ``"hists"`` metrics key) stay
export-friendly.

:data:`SCHEDULER_METRIC_CONTRACT` / :data:`ROUTER_METRIC_CONTRACT` are
the registry's metric-name contracts — the exact key sets the two
``metrics()`` dicts may emit.  ``analysis/checks/mirror_spec.py``
re-exports them and the mirror-drift checker's ``metrics-registered``
pass diffs the emitted dict literals against them in both directions, so
a metric added on either side without a contract entry (or a stale
contract entry after a rename) is a CI finding.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


def pctl(xs: Sequence[float], q: float) -> float:
    """The one percentile helper (empty input -> 0.0, matching the
    legacy ad-hoc ``np.percentile`` call sites it replaced)."""
    xs = np.asarray(xs, dtype=float)
    return float(np.percentile(xs, q)) if xs.size else 0.0


class Counter:
    """Monotonic count."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v


class Histogram:
    """Fixed-bucket histogram that also retains the exact samples.

    ``buckets`` are upper bounds (le); one overflow bucket is implicit.
    ``mean`` / ``quantile`` are computed from the exact samples so the
    registry can stand behind the legacy metrics without changing a
    single reported number; ``summary()`` is the compact exportable view.
    """

    __slots__ = ("name", "buckets", "counts", "samples")

    def __init__(self, name: str, buckets: Sequence[float]):
        if not buckets:
            raise ValueError(f"histogram {name!r} needs at least 1 bucket")
        self.name = name
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.counts = [0] * (len(self.buckets) + 1)
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        v = float(v)
        self.samples.append(v)
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def mean(self) -> float:
        return float(np.mean(self.samples)) if self.samples else 0.0

    def quantile(self, q: float) -> float:
        return pctl(self.samples, q)

    def summary(self) -> dict:
        b = {f"le_{ub:g}": c for ub, c in zip(self.buckets, self.counts)}
        b["inf"] = self.counts[-1]
        return {"count": self.count, "mean": self.mean,
                "p50": self.quantile(50), "p99": self.quantile(99),
                "buckets": b}


#: Standard fixed buckets per histogram instrument (seconds unless the
#: name says otherwise).  TTFT spans prefill work, TPOT is per-token
#: decode cadence, gather cost is the modeled block-table DMA time, the
#: fused horizon counts steps per scan, e2e covers whole requests.
DEFAULT_BUCKETS: Dict[str, Tuple[float, ...]] = {
    "ttft_s": (0.001, 0.005, 0.02, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 10.0),
    "tpot_s": (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
               0.25, 1.0),
    "gather_cost_s": (1e-7, 5e-7, 1e-6, 5e-6, 1e-5, 5e-5, 1e-4, 1e-3),
    "fused_horizon": (1, 2, 4, 8, 16, 32, 64, 128),
    "e2e_s": (0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0),
}


class MetricsRegistry:
    """Name-keyed instrument store with get-or-create accessors."""

    def __init__(self):
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            if buckets is None:
                buckets = DEFAULT_BUCKETS.get(name)
            if buckets is None:
                raise ValueError(f"histogram {name!r} has no default "
                                 f"buckets; pass them explicitly")
            h = self.histograms[name] = Histogram(name, buckets)
        return h

    def observe_all(self, name: str, values: Iterable[float]) -> Histogram:
        h = self.histogram(name)
        for v in values:
            h.observe(v)
        return h

    def summaries(self) -> dict:
        return {"counters": {k: c.value
                             for k, c in sorted(self.counters.items())},
                "gauges": {k: g.value
                           for k, g in sorted(self.gauges.items())},
                "histograms": {k: h.summary()
                               for k, h in sorted(
                                   self.histograms.items())}}


def serving_registry() -> MetricsRegistry:
    """Registry pre-declaring the serving-path histogram instruments."""
    reg = MetricsRegistry()
    for name in ("ttft_s", "tpot_s", "gather_cost_s", "fused_horizon",
                 "e2e_s"):
        reg.histogram(name)
    return reg


# ---------------------------------------------------------------------------
# The metric-name contracts (enforced by checks/mirror_drift.py's
# metrics-registered pass; re-exported through checks/mirror_spec.py)
# ---------------------------------------------------------------------------
SCHEDULER_METRIC_CONTRACT: Tuple[str, ...] = (
    "wall_s", "requests", "decoded_tokens", "tokens_per_s",
    "tbt_mean_s", "tbt_p99_s", "ttft_mean_s", "tpot_mean_s",
    "preemptions", "finish_eos", "finish_budget",
    "kv_mode", "kv_reserved_tokens", "kv_peak_tokens",
    "kv_logical_peak_pages", "kv_shared_pages", "kv_dedup_ratio_peak",
    "cow_forks", "defrag_runs", "prefill_skipped_tokens",
    "kv_migrated_pages", "kv_migration_cost_s", "placement_policy",
    "kv_gather_cost_mean_s", "kv_gather_concentration", "kv_region_peak",
    "codesign_substrate", "modeled_time_s", "modeled_tokens_per_s",
    "reconfigurations", "substrate_configs", "array_util_mean",
    "fused_ticks", "fused_steps_mean", "fused_host_frac", "hists",
)

ROUTER_METRIC_CONTRACT: Tuple[str, ...] = (
    "policy", "replicas", "wall_s", "requests", "decoded_tokens",
    "tokens_per_s", "e2e_p50_s", "e2e_p99_s", "tbt_mean_s", "tbt_p99_s",
    "preemptions", "finish_eos", "finish_budget", "dedup_ratio_agg",
    "reconfigurations", "substrate_configs", "modeled_tokens_per_s",
    "array_util_mean", "tiers", "shipments", "shipped_pages",
    "ship_cost_s", "per_replica", "hists",
)
