"""Trace exporters: Perfetto JSON, JSONL save/replay, phase report (PR 9).

* :func:`export_perfetto` — Chrome-trace/Perfetto ``traceEvents`` JSON:
  one *process* per replica, one *thread* lane per slot (tid 0 is the
  engine/scheduler lane), complete events (``ph: "X"``) for spans,
  counter tracks (``ph: "C"``) fed by ``gauge`` events.  Open the file
  at https://ui.perfetto.dev (or chrome://tracing).
* :func:`save_jsonl` / :func:`load_jsonl` — lossless event log, one
  JSON object per line.  ``json`` round-trips Python floats exactly, so
  a replayed log reproduces :func:`trace_report` bit-for-bit.
* :func:`trace_report` — phase attribution: prefill vs decode vs
  ship vs reconfig vs stall.  Span events are disjoint host (or
  modeled-clock) intervals, so the phases sum to the makespan by
  construction — ``stall_s`` is the residual the engine spent idle or
  in bookkeeping.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

from repro.obs.tracer import TraceEvent

#: event kinds whose duration is decode work (per-tick or fused)
_DECODE_KINDS = ("decode_step", "fused_tick")


def export_perfetto(events: Sequence[TraceEvent],
                    path: Optional[str] = None) -> dict:
    """Build (and optionally write) a Chrome-trace JSON object.

    Timestamps/durations convert to microseconds.  Events are sorted by
    (pid, tid, ts), so every track's timestamps are monotonically
    non-decreasing — the invariant the round-trip test pins.
    """
    spans: List[dict] = []
    tracks: Dict[int, set] = {}
    for ev in events:
        pid = ev.replica
        if ev.kind == "gauge":
            # one counter track per gauge key, engine lane
            for k, v in ev.args.items():
                spans.append({"ph": "C", "name": k, "pid": pid, "tid": 0,
                              "ts": ev.ts * 1e6, "args": {k: v}})
            tracks.setdefault(pid, set()).add(0)
            continue
        args = dict(ev.args)
        if ev.rid >= 0:
            args["rid"] = ev.rid
        spans.append({"ph": "X", "name": ev.kind, "cat": "serving",
                      "pid": pid, "tid": ev.slot + 1, "ts": ev.ts * 1e6,
                      "dur": ev.dur * 1e6, "args": args})
        tracks.setdefault(pid, set()).add(ev.slot + 1)
    spans.sort(key=lambda e: (e["pid"], e["tid"], e["ts"]))
    meta: List[dict] = []
    for pid in sorted(tracks):
        meta.append({"ph": "M", "name": "process_name", "pid": pid,
                     "tid": 0, "ts": 0, "args": {"name": f"replica {pid}"}})
        for tid in sorted(tracks[pid]):
            lane = "engine" if tid == 0 else f"slot {tid - 1}"
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "ts": 0, "args": {"name": lane}})
    obj = {"traceEvents": meta + spans, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(obj, f)
    return obj


def save_jsonl(events: Sequence[TraceEvent], path: str) -> None:
    """One event per line; lossless (floats round-trip exactly)."""
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev.to_json()) + "\n")


def load_jsonl(path: str) -> List[TraceEvent]:
    out: List[TraceEvent] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(TraceEvent.from_json(json.loads(line)))
    return out


def trace_report(events: Sequence[TraceEvent]) -> dict:
    """Phase-attribution summary over one event stream.

    ``phases`` partitions the makespan: prefill-chunk spans, decode
    spans (per-tick + fused), tier-handoff page shipments (sims charge
    the modeled link time as the span duration; the engine's wall ship
    events are instantaneous and carry the modeled cost in ``args``),
    reconfiguration charge (likewise), and ``stall_s`` — the residual
    (idle waits, admission, host bookkeeping).  Because span events
    never overlap, ``sum(phases) == makespan_s`` exactly.
    """
    counts: Dict[str, int] = {}
    prefill_s = decode_s = ship_s = reconfig_s = 0.0
    t_lo, t_hi = float("inf"), float("-inf")
    finished = 0
    for ev in events:
        counts[ev.kind] = counts.get(ev.kind, 0) + 1
        if ev.kind == "prefill_chunk":
            prefill_s += ev.dur
        elif ev.kind in _DECODE_KINDS:
            decode_s += ev.dur
        elif ev.kind == "ship":
            ship_s += ev.dur
        elif ev.kind == "reconfigure":
            reconfig_s += ev.dur
        elif ev.kind == "finish":
            finished += 1
        t_lo = min(t_lo, ev.ts)
        t_hi = max(t_hi, ev.ts + ev.dur)
    makespan = (t_hi - t_lo) if counts else 0.0
    stall = max(0.0, makespan - prefill_s - decode_s - ship_s
                - reconfig_s)
    return {"makespan_s": makespan,
            "finished": finished,
            "events": dict(sorted(counts.items())),
            "phases": {"prefill_s": prefill_s, "decode_s": decode_s,
                       "ship_s": ship_s, "reconfig_s": reconfig_s,
                       "stall_s": stall}}
