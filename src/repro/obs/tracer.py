"""Structured lifecycle tracing for the serving path (PR 9).

One :class:`Tracer` collects typed :class:`TraceEvent` records from every
layer of the stack — ``Router`` (dispatch), ``Scheduler`` (arrival /
admit), ``PagedServingEngine`` (prefill chunks, decode steps, fused
ticks with their horizon-clamp reason, growth / preemption, substrate
reconfigurations, finishes), and ``PagedCache`` (CoW forks, defrag,
spilled-page migration).  The analytic mirrors in
``core/serving_sim.py`` emit the *same* event schema on the modeled
clock, so an engine trace and a sim trace can be diffed event-by-event.

Tracing must never perturb the tokens: every emitter sits behind an
``if tracer.enabled`` branch and the default :data:`NULL_TRACER` is a
no-op whose ``enabled`` attribute is a plain ``False`` — the hot path
pays one attribute load + branch when tracing is off.

Timestamps are seconds on the *emitting* clock relative to the tracer's
origin: wall ``time.perf_counter`` for the live engine (origin = first
event), the modeled clock for the sims (construct with ``t0=0.0``).
``dur`` is the span length; instantaneous events carry ``dur == 0``.
Exporters (Perfetto JSON, JSONL save/replay, ``trace_report``) live in
:mod:`repro.obs.export`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

#: The event schema.  One entry per lifecycle edge; ``args`` carries the
#: per-kind payload (documented in README "Observability"):
#:
#: arrival        request entered a scheduler queue (args: arrival_s,
#:                prompt_len)
#: dispatch       router picked a replica (args: policy)
#: admit          scheduler admission succeeded (args: requeued)
#: prefill_chunk  one prefill chunk advanced (args: tokens, pos, last)
#: decode_step    one per-tick decode iteration (args: batch, finished)
#: fused_tick     one K-step fused lax.scan tick (args: batch, horizon,
#:                clamp in {fuse_steps, page_edge, budget}, device_s)
#: grow           on-demand page growth before a decode step (args: pages)
#: preempt        youngest-first preemption (args: preemptions)
#: cow_fork       copy-on-write fork of a shared page (args: block, page)
#: defrag         page-pool compaction ran (args: moved, cost_s)
#: migrate        spilled pages re-homed (args: pages, cost_s)
#: ship           KV pages shipped between tiers at prefill handoff
#:                (args: pages, bytes, cost_s, src, dst); sims charge
#:                dur on the modeled clock
#: reconfigure    substrate shape-profile change (args: old, new,
#:                modeled_reconfig_s); sims charge dur on their clock
#: finish         request retired (args: reason, tokens)
#: gauge          per-tick counter sample (args: one value per counter
#:                track, e.g. free_pages / min_region_free /
#:                modeled_tokens_per_s)
EVENT_KINDS = (
    "arrival", "dispatch", "admit", "prefill_chunk", "decode_step",
    "fused_tick", "grow", "preempt", "cow_fork", "defrag", "migrate",
    "ship", "reconfigure", "finish", "gauge",
)


@dataclass
class TraceEvent:
    ts: float                   # seconds since tracer origin (span start)
    kind: str                   # one of EVENT_KINDS
    replica: int = 0            # Perfetto pid (one process per replica)
    slot: int = -1              # Perfetto tid - 1 (-1: engine-level lane)
    rid: int = -1               # request id (-1: not request-scoped)
    dur: float = 0.0            # span length (0: instantaneous)
    args: Dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> dict:
        return {"ts": self.ts, "kind": self.kind, "replica": self.replica,
                "slot": self.slot, "rid": self.rid, "dur": self.dur,
                "args": self.args}

    @classmethod
    def from_json(cls, d: dict) -> "TraceEvent":
        return cls(ts=d["ts"], kind=d["kind"],
                   replica=d.get("replica", 0), slot=d.get("slot", -1),
                   rid=d.get("rid", -1), dur=d.get("dur", 0.0),
                   args=d.get("args", {}))


class NullTracer:
    """No-op tracer: the hot path's default.  ``enabled`` is a plain
    class attribute so the guard is one load + branch; ``emit`` accepts
    the full signature and drops everything."""

    enabled = False

    def emit(self, kind: str, *, ts: Optional[float] = None,
             replica: Optional[int] = None, slot: int = -1, rid: int = -1,
             dur: float = 0.0, **args) -> None:
        return None

    def for_replica(self, replica: int) -> "NullTracer":
        return self

    @property
    def events(self) -> List[TraceEvent]:
        return []


NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """Collects :class:`TraceEvent` records in emission order.

    ``t0`` anchors the time origin.  ``None`` (the default) locks it to
    the first emitted event's timestamp — right for wall-clock tracing,
    where ``time.perf_counter`` values are arbitrary.  Pass ``t0=0.0``
    when emitting modeled-clock timestamps (the analytic sims).
    """

    enabled = True

    def __init__(self, t0: Optional[float] = None):
        self._t0 = t0
        self._events: List[TraceEvent] = []

    def emit(self, kind: str, *, ts: Optional[float] = None,
             replica: Optional[int] = None, slot: int = -1, rid: int = -1,
             dur: float = 0.0, **args) -> None:
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown trace event kind {kind!r} "
                             f"(know {EVENT_KINDS})")
        if ts is None:
            ts = time.perf_counter()
        if self._t0 is None:
            self._t0 = ts
        self._events.append(TraceEvent(
            ts=ts - self._t0, kind=kind,
            replica=0 if replica is None else replica,
            slot=slot, rid=rid, dur=dur, args=args))

    def for_replica(self, replica: int) -> "_BoundTracer":
        """A view of this tracer whose events default to ``replica`` —
        each engine replica gets one (its Perfetto process id)."""
        return _BoundTracer(self, replica)

    @property
    def events(self) -> List[TraceEvent]:
        return self._events


class _BoundTracer:
    """Replica-bound view over a shared :class:`Tracer`."""

    __slots__ = ("_tracer", "replica", "enabled")

    def __init__(self, tracer: Tracer, replica: int):
        self._tracer = tracer
        self.replica = replica
        self.enabled = tracer.enabled

    def emit(self, kind: str, *, ts: Optional[float] = None,
             replica: Optional[int] = None, slot: int = -1, rid: int = -1,
             dur: float = 0.0, **args) -> None:
        self._tracer.emit(kind, ts=ts,
                          replica=self.replica if replica is None
                          else replica,
                          slot=slot, rid=rid, dur=dur, **args)

    def for_replica(self, replica: int) -> "_BoundTracer":
        return _BoundTracer(self._tracer, replica)

    @property
    def events(self) -> List[TraceEvent]:
        return self._tracer.events
