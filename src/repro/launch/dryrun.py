import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import — jax locks the device
# count at first init.  A smaller placeholder count may be injected for CI:
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

For each cell this prints compiled.memory_analysis() (does it fit 16 GB/chip)
and cost_analysis() (FLOPs/bytes for the roofline), parses the collective
schedule from the partitioned HLO, and appends a JSON record consumed by
EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import json
import time
import traceback

import jax

from repro.analysis.roofline import (CollectiveProfile, RooflineReport,
                                     analytic_corrections, analyze,
                                     model_flops_for, parse_collectives)
from repro.launch.mesh import make_production_mesh, make_mesh
from repro.launch.steps import build_cell
from repro.models import registry
from repro.models.config import SHAPES, shape_applicable
from repro.optim import adamw as axw

RESULTS = os.environ.get("REPRO_DRYRUN_OUT", "benchmarks/dryrun_results")


# ---------------------------------------------------------------------------
# Scan-undercount calibration (see analysis/roofline.py for why).
#
# cost_analysis counts a while-loop body ONCE regardless of trip count, so
# the layer scan's true cost must be recovered.  Method: compile a reduced
# 2*L0-layer version of the cell twice — with scan unroll=1 and unroll=2.
# The unroll=2 build has exactly one extra body copy in the HLO, so
#     body  = c(unroll=2) - c(unroll=1)
#     total = c_full(unroll=1) + (L/L0 - 1) * body
# (L0 = the scan period: 1 layer, or the hybrid block-pattern length).
# In-layer loops (blocked attention, chunked CE) stay undercounted inside
# `body` and are corrected analytically (analysis/roofline.py).
# ---------------------------------------------------------------------------
def _calib_costs(arch: str, nl: int, unroll: int, mesh, shape,
                 seq_sharded, remat):
    cfg_full = registry.get_config(arch)
    over = {"num_layers": nl, "scan_unroll": unroll}
    if cfg_full.encoder_layers:
        over["encoder_layers"] = max(1, round(
            cfg_full.encoder_layers * nl / cfg_full.num_layers))
    entry = registry.get(arch, **over)
    jf, args = build_cell(entry, mesh, shape, seq_sharded_attn=seq_sharded,
                          ocfg=axw.AdamWConfig(), remat=remat)
    comp = jf.lower(*args).compile()
    ca = comp.cost_analysis() or {}
    prof = parse_collectives(comp.as_text(), mesh.size)
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)), prof)


def scan_corrected_report(arch: str, mesh, shape, mesh_label: str,
                          seq_sharded: bool, remat: bool, memory_stats,
                          full_costs) -> RooflineReport:
    cfg = registry.get_config(arch)
    L0 = max(1, len(cfg.block_pattern)) if cfg.block_pattern else 1
    periods = cfg.num_layers / L0
    nl = 2 * L0                           # even scan length for unroll=2
    f1, b1, p1 = _calib_costs(arch, nl, 1, mesh, shape, seq_sharded, remat)
    f2, b2, p2 = _calib_costs(arch, nl, 2, mesh, shape, seq_sharded, remat)
    ff, bf, pf = full_costs               # full model, unroll=1
    flops = ff + (periods - 1) * (f2 - f1)
    hbm = bf + (periods - 1) * (b2 - b1)
    prof = CollectiveProfile()
    prof.count = pf.count + int(round((periods - 1)
                                      * (p2.count - p1.count)))
    prof.wire_bytes = int(pf.wire_bytes
                          + (periods - 1) * (p2.wire_bytes - p1.wire_bytes))
    for op in set(pf.bytes_by_op) | set(p1.bytes_by_op) | set(p2.bytes_by_op):
        vf = pf.bytes_by_op.get(op, 0)
        v1 = p1.bytes_by_op.get(op, 0)
        v2 = p2.bytes_by_op.get(op, 0)
        prof.bytes_by_op[op] = int(vf + (periods - 1) * (v2 - v1))
    corr = analytic_corrections(cfg, shape, mesh.shape["model"], mesh.size)
    flops += corr["flops"]
    hbm += corr["bytes"]
    # Analytic floor: families whose compute sits inside SEQUENCE scans
    # (rwkv wkv recurrence, RG-LRU) stay undercounted even after the layer
    # calibration — the true compute can never be below MODEL_FLOPS.
    mf = model_flops_for(cfg, shape)
    flops = max(flops, mf / mesh.size)
    return RooflineReport(arch=arch, shape=shape.name, mesh=mesh_label,
                          n_devices=mesh.size, flops_per_device=flops,
                          hbm_bytes_per_device=hbm, collective=prof,
                          memory_stats=memory_stats,
                          model_flops=mf)


def _mesh_for(name: str):
    if os.environ.get("REPRO_DRYRUN_DEVICES"):
        n = len(jax.devices())
        if name == "multi":
            return make_mesh((2, 2, n // 4), ("pod", "data", "model")), \
                f"multi-{n}"
        return make_mesh((2, n // 2), ("data", "model")), f"single-{n}"
    if name == "multi":
        return make_production_mesh(multi_pod=True), "2x16x16"
    return make_production_mesh(multi_pod=False), "16x16"


def run_cell(arch: str, shape_name: str, mesh_name: str,
             seq_sharded: bool = False, remat: bool = True,
             calibrate: bool = True, microbatch: int = 1,
             prefill_chunk=None) -> dict:
    t0 = time.time()
    entry = registry.get(arch)
    cfg = entry.config
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": why}
        print(f"[dryrun] {arch:18s} {shape_name:12s} {mesh_name:8s} {why}")
        return rec
    mesh, mesh_label = _mesh_for(mesh_name)
    try:
        jf, args = build_cell(entry, mesh, shape,
                              seq_sharded_attn=seq_sharded,
                              ocfg=axw.AdamWConfig(), remat=remat,
                              microbatch=microbatch,
                              prefill_chunk=prefill_chunk)
        lowered = jf.lower(*args)
        compiled = lowered.compile()
        print(compiled.memory_analysis())      # proves it fits (or not)
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in ("flops", "bytes accessed")
               if ca and k in ca})
        raw = analyze(compiled, arch=arch, shape=shape_name,
                      mesh_name=mesh_label, n_devices=mesh.size,
                      model_flops=model_flops_for(cfg, shape))
        if calibrate:
            # scan-corrected roofline terms (cost_analysis counts loop
            # bodies once; unroll-differential body cost + analytic fixes)
            full_costs = (raw.flops_per_device, raw.hbm_bytes_per_device,
                          raw.collective)
            rep = scan_corrected_report(arch, mesh, shape, mesh_label,
                                        seq_sharded, remat,
                                        raw.memory_stats, full_costs)
        else:
            rep = raw
        rec = {"status": "OK", "compile_s": round(time.time() - t0, 1),
               "seq_sharded_attn": seq_sharded, "calibrated": calibrate,
               **rep.to_dict(),
               "raw_flops_per_device": raw.flops_per_device,
               "raw_hbm_bytes_per_device": raw.hbm_bytes_per_device,
               "raw_collective_wire_bytes": raw.collective.wire_bytes}
        fits = (rep.memory_stats or {}).get("fits_v5e_16gb")
        print(f"[dryrun] {arch:18s} {shape_name:12s} {mesh_label:10s} OK "
              f"compile={rec['compile_s']}s bottleneck={rep.bottleneck} "
              f"t=({rep.t_compute:.3e},{rep.t_memory:.3e},"
              f"{rep.t_collective:.3e})s fits16GB={fits}")
    except Exception as e:                      # noqa: BLE001 - report all
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": f"FAIL: {type(e).__name__}: {e}"}
        print(f"[dryrun] {arch:18s} {shape_name:12s} {mesh_name:8s} "
              f"FAILED: {e}")
        traceback.print_exc()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS + registry.EXTRA_ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--seq-sharded-attn", action="store_true",
                    help="use the shard_map lse-combine decode attention")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches (train cells)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="Sarathi-style chunked prefill (prefill cells)")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the scan-undercount calibration compiles "
                         "(multi-pod pass: compilation proof only)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    calibrate = not args.no_calibrate and args.mesh == "single"

    cells = []
    archs = registry.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    os.makedirs(args.out or RESULTS, exist_ok=True)
    out_dir = args.out or RESULTS
    records = []
    for a in archs:
        for s in shapes:
            records.append(run_cell(a, s, args.mesh,
                                    seq_sharded=args.seq_sharded_attn,
                                    calibrate=calibrate,
                                    microbatch=args.microbatch,
                                    prefill_chunk=args.prefill_chunk))
    tag = f"{args.mesh}_{archs[0] if len(archs) == 1 else 'all'}_" \
          f"{shapes[0] if len(shapes) == 1 else 'all'}"
    if args.seq_sharded_attn:
        tag += "_seqattn"
    path = os.path.join(out_dir, f"dryrun_{tag}.json")
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    print(f"[dryrun] wrote {path}")
    n_ok = sum(1 for r in records if r.get("status") == "OK")
    n_skip = sum(1 for r in records if "SKIP" in str(r.get("status")))
    print(f"[dryrun] {n_ok} OK / {n_skip} skipped / "
          f"{len(records) - n_ok - n_skip} failed of {len(records)}")
    del cells


if __name__ == "__main__":
    main()
