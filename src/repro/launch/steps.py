"""Step-function builders: pure train/prefill/serve steps + their sharding.

These are what both the real drivers (train.py / serve.py) and the multi-pod
dry-run lower.  All assembly is mesh-parameterized; tp = mesh model size.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import context
from repro.distributed.seq_attention import make_seq_sharded_attn
from repro.distributed.sharding import (batch_pspecs, cache_pspecs,
                                        fsdp_pspecs, param_pspecs, to_named,
                                        zero1_pspecs)
from repro.models import registry
from repro.models.config import SHAPES, ArchConfig, ShapeCell
from repro.optim import adamw as axw


def make_train_step(entry: registry.ArchEntry, ocfg: axw.AdamWConfig,
                    tp: int, mesh=None, microbatch: int = 1) -> Callable:
    """``microbatch`` > 1 runs gradient accumulation over that many
    sequential microbatches (f32 accumulator) — divides the activation
    working set at the cost of re-running the forward pass per slice."""
    cfg, mod = entry.config, entry.module

    def train_step(params, opt_state, batch):
        with context.use_mesh(mesh):
            if microbatch > 1:
                from repro.models import layers as _L
                _L._EMBED_CONSTRAINT[0] = False   # trace-time toggle
                mbs = jax.tree.map(
                    lambda x: x.reshape(microbatch,
                                        x.shape[0] // microbatch,
                                        *x.shape[1:]), batch)

                def acc(carry, mb):
                    lsum, gsum = carry
                    l, g = jax.value_and_grad(
                        lambda p: mod.loss(p, cfg, mb, tp=tp))(params)
                    gsum = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), gsum, g)
                    return (lsum + l, gsum), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                try:
                    (loss, grads), _ = jax.lax.scan(
                        acc, (jnp.float32(0.0), zeros), mbs)
                finally:
                    _L._EMBED_CONSTRAINT[0] = True
                loss = loss / microbatch
                grads = jax.tree.map(lambda g: g / microbatch, grads)
            else:
                loss, grads = jax.value_and_grad(
                    lambda p: mod.loss(p, cfg, batch, tp=tp))(params)
        params, opt_state, metrics = axw.update(grads, opt_state, params,
                                                ocfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(entry: registry.ArchEntry, tp: int, mesh=None,
                      max_seq: Optional[int] = None,
                      chunk: Optional[int] = None) -> Callable:
    """``chunk``: Sarathi-style chunked prefill for the transformer
    families — bounds peak activation memory to one chunk."""
    cfg, mod = entry.config, entry.module

    def prefill_step(params, inputs: Dict[str, Any]):
        with context.use_mesh(mesh):
            if cfg.family == "audio":
                return mod.prefill(params, cfg, inputs["tokens"],
                                   frames=inputs["frames"], tp=tp,
                                   max_seq=max_seq)
            if cfg.family == "vlm":
                return mod.prefill(params, cfg, None,
                                   embeds=inputs["embeds"], tp=tp,
                                   max_seq=max_seq)
            if cfg.family in ("ssm", "hybrid"):
                return mod.prefill(params, cfg, inputs["tokens"], tp=tp)
            return mod.prefill(params, cfg, inputs["tokens"], tp=tp,
                               max_seq=max_seq, chunk=chunk)

    return prefill_step


def make_serve_step(entry: registry.ArchEntry, tp: int, mesh=None,
                    seq_sharded_attn: bool = False) -> Callable:
    cfg, mod = entry.config, entry.module
    attn_fn = None
    if seq_sharded_attn and mesh is not None and cfg.family in ("dense",
                                                                "moe", "vlm"):
        attn_fn = make_seq_sharded_attn(mesh)

    def serve_step(params, cache, tokens):
        with context.use_mesh(mesh):
            if cfg.family in ("dense", "moe", "vlm") and attn_fn is not None:
                return mod.decode_step(params, cfg, tokens, cache, tp=tp,
                                       attn_fn=attn_fn)
            return mod.decode_step(params, cfg, tokens, cache, tp=tp)

    return serve_step


# ---------------------------------------------------------------------------
# Sharding assembly
# ---------------------------------------------------------------------------
def assemble_shardings(entry: registry.ArchEntry, mesh, kind: str,
                       shape: ShapeCell, ocfg: Optional[axw.AdamWConfig]
                       = None, fsdp: bool = True):
    """Returns (arg_sds, in_shardings, out_shardings) for one cell.

    ``fsdp``: additionally shard parameters over the data axes (ZeRO-3) —
    required for the 100B+ archs to fit 16 GB/chip; the layer scan re-gathers
    one layer's weights at a time.
    """
    cfg = entry.config
    tp = mesh.shape["model"]
    params_sds = jax.eval_shape(
        lambda: entry.module.init(jax.random.PRNGKey(0), cfg, tp))
    pspec = param_pspecs(params_sds, mesh)
    if fsdp:
        # FSDP re-gathers weights at every use — only worth it when the
        # TP-only residency threatens the 16 GB chip (§Perf iterations
        # 12/15).  Serving residency = params; training adds ~4x of f32
        # optimizer moments (already ZeRO-1-sharded over data, so they
        # count /dsize).
        pbytes = sum(l.size * l.dtype.itemsize
                     for l in jax.tree.leaves(params_sds)) / tp
        if kind == "train":
            import numpy as _np
            from repro.launch.mesh import data_axes
            dsize = int(_np.prod([mesh.shape[a] for a in data_axes(mesh)])
                        ) or 1
            n_par = sum(l.size for l in jax.tree.leaves(params_sds))
            resid = pbytes + 8.0 * n_par / tp / dsize   # f32 mu+nu, ZeRO-1
        else:
            resid = pbytes
        fsdp = resid > 8 * 2**30
    if fsdp:
        pspec = fsdp_pspecs(pspec, params_sds, mesh)
    psh = to_named(pspec, mesh)
    rep = NamedSharding(mesh, P())
    inputs_sds = registry.input_specs(cfg, shape, tp)
    bsh = to_named(batch_pspecs(inputs_sds, mesh), mesh)

    if kind == "train":
        ocfg = ocfg or axw.AdamWConfig()
        opt_sds = jax.eval_shape(lambda: axw.init(params_sds, ocfg))
        z1 = to_named(zero1_pspecs(pspec, params_sds, mesh), mesh)
        osh = axw.AdamWState(rep, z1, z1, z1 if ocfg.compress_grads else None)
        args = (params_sds, opt_sds, inputs_sds)
        in_sh = (psh, osh, bsh)
        out_sh = (psh, osh, rep)
        return args, in_sh, out_sh

    if kind == "prefill":
        cache_sds = registry.cache_specs(entry, shape, tp)
        csh = to_named(cache_pspecs(cache_sds, mesh), mesh)
        args = (params_sds, inputs_sds)
        in_sh = (psh, bsh)
        out_sh = (rep, csh)   # last-token logits replicated; cache sharded
        return args, in_sh, out_sh

    # decode
    cache_sds = registry.cache_specs(entry, shape, tp)
    csh = to_named(cache_pspecs(cache_sds, mesh), mesh)
    tok_sds = registry.input_specs(cfg, shape, tp)["tokens"]
    tsh = to_named(batch_pspecs({"tokens": tok_sds}, mesh), mesh)["tokens"]
    args = (params_sds, cache_sds, tok_sds)
    in_sh = (psh, csh, tsh)
    logits_spec = P()
    b = shape.global_batch
    from repro.launch.mesh import data_axes
    daxes = data_axes(mesh)
    import numpy as np
    if daxes and b % int(np.prod([mesh.shape[a] for a in daxes])) == 0:
        logits_spec = P(daxes if len(daxes) > 1 else daxes[0], None)
    out_sh = (NamedSharding(mesh, logits_spec), csh)
    return args, in_sh, out_sh


def build_cell(entry: registry.ArchEntry, mesh, shape: ShapeCell,
               seq_sharded_attn: bool = False,
               ocfg: Optional[axw.AdamWConfig] = None,
               remat: bool = True, microbatch: int = 1,
               prefill_chunk: Optional[int] = None):
    """(jit_fn, arg_sds) ready to .lower(*arg_sds) for one dry-run cell."""
    tp = mesh.shape["model"]
    kind = shape.kind
    args, in_sh, out_sh = assemble_shardings(entry, mesh, kind, shape, ocfg)
    if kind == "train":
        fn = make_train_step(entry, ocfg or axw.AdamWConfig(), tp, mesh,
                             microbatch=microbatch)
        donate = (0, 1)
    elif kind == "prefill":
        fn = make_prefill_step(entry, tp, mesh, max_seq=shape.seq_len,
                               chunk=prefill_chunk)
        donate = ()
    else:
        fn = make_serve_step(entry, tp, mesh,
                             seq_sharded_attn=seq_sharded_attn)
        donate = (1,)
    jf = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=donate)
    return jf, args
