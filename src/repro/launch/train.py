"""Training driver: data pipeline -> sharded train step -> checkpoints.

Production features exercised end-to-end (reduced configs on CPU; the same
code lowers at the 16x16 / 2x16x16 meshes via --mesh):

* GSPMD-sharded train step from ``launch.steps`` (params Megatron-split,
  optimizer states ZeRO-1 over the data axis, optional int8 error-feedback
  gradient compression for the cross-pod reduction);
* fault tolerance: atomic step-tagged checkpoints (async), resume-from-
  latest, bounded retry on transient step failures, and SIGTERM-safe final
  save;
* straggler mitigation hook: per-step wall-time EMA; steps slower than
  ``straggler_factor`` x EMA are logged and counted (on a real fleet this
  feeds the reschedule/evict policy);
* deterministic restart: the TokenPipeline is a pure function of
  (seed, step, shard), so a resumed run replays the exact token stream.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
      --steps 30 --global-batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_mesh
from repro.launch.steps import assemble_shardings, make_train_step
from repro.models import registry
from repro.models.config import ShapeCell
from repro.optim import adamw as axw


class StragglerDetector:
    """Per-step wall-time EMA; flags steps slower than factor x EMA."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.1):
        self.factor = factor
        self.alpha = alpha
        self.ema: Optional[float] = None
        self.events = 0

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        if slow:
            self.events += 1
        return slow


def train(arch: str, *, steps: int, global_batch: int, seq: int,
          ckpt_dir: Optional[str], save_every: int = 20,
          reduced: bool = True, compress_grads: bool = False,
          mesh_shape=(1, 1), log_every: int = 10, resume: bool = True,
          max_retries: int = 2, seed: int = 0,
          stop_step: Optional[int] = None) -> dict:
    """``steps`` fixes the schedule horizon; ``stop_step`` (if set) halts
    the loop early — a resumed run with the same ``steps`` then replays
    the identical trajectory (exact-resume invariant)."""
    entry = registry.get(arch, reduced=reduced) if reduced \
        else registry.get(arch)
    cfg = entry.config
    mesh = make_mesh(mesh_shape, ("data", "model"))
    shape = ShapeCell("train", seq, global_batch, "train")
    ocfg = axw.AdamWConfig(total_steps=max(steps, 10),
                           warmup_steps=min(20, steps),
                           compress_grads=compress_grads)

    _, in_sh, out_sh = assemble_shardings(entry, mesh, "train", shape, ocfg)
    step_fn = jax.jit(make_train_step(entry, ocfg, mesh.shape["model"],
                                      mesh),
                      in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=(0, 1))

    params = entry.module.init(jax.random.PRNGKey(seed), cfg,
                               mesh.shape["model"])
    opt_state = axw.init(params, ocfg)

    mgr = CheckpointManager(ckpt_dir, keep=3, async_save=True) \
        if ckpt_dir else None
    start = 0
    if mgr and resume:
        latest, tree, extra = mgr.restore_latest(
            {"params": params, "opt": opt_state})
        if latest is not None:
            params, opt_state = tree["params"], tree["opt"]
            start = int(extra.get("next_step", latest))
            print(f"[train] resumed from step {latest} -> next {start}")

    data = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                    global_batch=global_batch, seed=seed))
    strag = StragglerDetector()
    losses = []
    t_start = time.perf_counter()
    end = min(stop_step, steps) if stop_step is not None else steps
    for step in range(start, end):
        batch = {k: v for k, v in data.batch_at(step).items()
                 if k in ("tokens", "labels")}
        if cfg.family == "vlm":
            # frontend stub: tokens stand in for patch embeddings
            emb = np.asarray(
                jax.nn.one_hot(batch["tokens"] % cfg.d_model, cfg.d_model),
                np.float32)
            batch = {"embeds": emb, "labels": batch["labels"]}
        if cfg.family == "audio":
            batch["frames"] = np.zeros(
                (global_batch, cfg.encoder_frames, cfg.d_model), np.float32)
        t0 = time.perf_counter()
        for attempt in range(max_retries + 1):
            try:       # bounded retry: transient host/infra failures
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
                break
            except Exception:
                if attempt == max_retries:
                    raise
                print(f"[train] step {step} attempt {attempt} failed; "
                      f"retrying")
        dt = time.perf_counter() - t0
        if strag.observe(dt):
            print(f"[train] straggler: step {step} took {dt * 1e3:.0f}ms "
                  f"(ema {strag.ema * 1e3:.0f}ms)")
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"{dt * 1e3:.0f}ms")
        if mgr and (step + 1) % save_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state},
                     extra={"next_step": step + 1, "loss": loss})
    if mgr:
        mgr.save(end, {"params": params, "opt": opt_state},
                 extra={"next_step": end, "loss": losses[-1]})
        mgr.wait()
    wall = time.perf_counter() - t_start
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "steps": len(losses), "wall_s": wall,
            "straggler_events": strag.events}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS + registry.EXTRA_ARCH_IDS, default="yi-6b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--full", action="store_true",
                    help="full config (production mesh only)")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, global_batch=args.global_batch,
                seq=args.seq, ckpt_dir=args.ckpt_dir,
                save_every=args.save_every, reduced=not args.full,
                compress_grads=args.compress_grads,
                mesh_shape=(args.data, args.model))
    print(f"[train] done: {out}")


if __name__ == "__main__":
    main()
