"""Serving driver: continuous-batching decode on the real model.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --rate 4 --n-requests 12 --prompt-len 32     # reduced config default

``--pallas`` routes decode attention through the flash-decode Pallas kernel
(interpret mode on CPU, compiled on TPU); with ``--paged`` it becomes the
block-table read-through paged kernel.  ``--paged`` switches KV residency
to the page-pool layout (``--page-size``, ``--num-pages`` to oversubscribe)
and ``--prefill-chunk`` interleaves Sarathi prefill chunks with the hot
decode batch (written directly into block-table pages on the paged
engine).  ``--fuse-steps K`` (paged only) fuses up to K decode steps
into one device-resident ``lax.scan`` tick — the host surfaces only at
fusion-horizon boundaries; tokens are identical to per-tick dispatch.
``--prefix-sharing`` adds refcounted prompt-prefix pages with
copy-on-write; combine it with ``--shared-prefix N`` to drive a
shared-system-prompt trace (every prompt = N common tokens + a unique
tail) and watch the dedup ratio in the report.  ``--placement
{free-first,interleave,affinity}`` partitions the page pool into
per-channel regions (``--placement-regions``) and reports the
block-table gather cost against the SNAKE substrate.

``--codesign`` turns on live array-shape/dataflow co-design pricing:
every tick's actual composition (decode batch, per-slot contexts, the
in-flight prefill chunk) is scheduled on the SNAKE substrate model and
the report gains ``modeled_tokens_per_s`` / ``reconfigurations`` /
``array_util_mean`` (``--codesign-rows R`` prices a fixed RxC array
baseline instead).

Multi-replica serving (PR 3): ``--replicas N`` stands up N engine
replicas behind the front-end router and ``--router-policy`` picks the
dispatch policy (``round_robin`` / ``least_loaded`` /
``session_affinity`` / ``prefix_affinity`` — the latter routes requests
to the replica whose prefix trie already holds their leading prompt
pages).  ``--groups G`` drives a skewed multi-tenant trace (G distinct
system prompts, Zipf popularity).  ``--eos-rate`` samples per-request
early-stop decode lengths; ``--trace-file`` replays a recorded JSON
trace instead of synthesizing one.

Disaggregated serving (PR 10): ``--tiers P:D`` (with ``--paged`` and
``--replicas P+D``) splits the cluster into a prefill tier and a decode
tier; finished prefills are shipped — KV pages, block-table row, prefix
coverage — over the priced inter-stack link, and ``ship`` events land
in the ``--trace-out`` timeline.
"""
from __future__ import annotations

import argparse

from repro.models import registry
from repro.serving.engine import (EngineConfig, load_trace, make_engine,
                                  make_grouped_prefix_trace,
                                  make_shared_prefix_trace, make_trace)
from repro.serving.router import POLICIES, make_cluster


def build_trace(args, vocab: int):
    if args.trace_file:
        return load_trace(args.trace_file, vocab=vocab)
    if args.shared_prefix > 0:
        # total prompt length stays --prompt-len: N shared + unique tail
        prefix = min(args.shared_prefix, args.prompt_len - 1)
        if args.groups > 1:
            return make_grouped_prefix_trace(
                vocab, rate_req_s=args.rate, n_requests=args.n_requests,
                n_groups=args.groups, prefix_len=prefix,
                tail_len=args.prompt_len - prefix, skew=args.group_skew,
                eos_rate=args.eos_rate)
        return make_shared_prefix_trace(
            vocab, rate_req_s=args.rate, n_requests=args.n_requests,
            prefix_len=prefix, tail_len=args.prompt_len - prefix,
            eos_rate=args.eos_rate)
    return make_trace(vocab, rate_req_s=args.rate,
                      n_requests=args.n_requests,
                      prompt_len=args.prompt_len, eos_rate=args.eos_rate)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS + registry.EXTRA_ARCH_IDS, default="yi-6b")
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--pallas", action="store_true",
                    help="flash-decode Pallas kernel for decode attention")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="Sarathi-style chunked prefill")
    ap.add_argument("--paged", action="store_true",
                    help="block-table paged KV cache")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool size (oversubscribe below the dense-"
                         "equivalent capacity to exercise preemption)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="refcounted prompt-prefix page sharing + CoW")
    ap.add_argument("--fuse-steps", type=int, default=1,
                    help="decode steps fused into one device-resident "
                         "lax.scan (1: per-tick dispatch; the realized "
                         "horizon is clipped by page windows and decode "
                         "budgets, so tokens are identical either way)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="common system-prompt tokens per request "
                         "(0: fully unique prompts)")
    ap.add_argument("--defrag-threshold", type=float, default=0.5,
                    help="fragmentation fraction that triggers pool "
                         "defrag (negative disables)")
    ap.add_argument("--placement", default=None,
                    choices=["free-first", "interleave", "affinity"],
                    help="stack-aware page placement: partition the page "
                         "pool into per-channel regions and co-locate "
                         "(affinity) or stripe (interleave) each slot's "
                         "pages; free-first keeps the legacy layout but "
                         "reports its gather cost")
    ap.add_argument("--placement-regions", type=int, default=None,
                    help="per-channel regions (default: one per PU of "
                         "the SNAKE template, capped by pool size)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the front-end router")
    ap.add_argument("--router-policy", choices=POLICIES,
                    default="round_robin")
    ap.add_argument("--tiers", type=str, default=None, metavar="P:D",
                    help="disaggregate the cluster into P prefill and D "
                         "decode replicas (P+D must equal --replicas; "
                         "requires --paged): prefills are harvested at "
                         "completion and their KV pages shipped over the "
                         "priced inter-stack link to the decode tier")
    ap.add_argument("--groups", type=int, default=1,
                    help="distinct system-prompt groups (with "
                         "--shared-prefix): the prefix-affinity workload")
    ap.add_argument("--group-skew", type=float, default=1.0,
                    help="Zipf popularity skew across groups")
    ap.add_argument("--codesign", action="store_true",
                    help="price every tick's batch composition on the "
                         "SNAKE substrate model (live array-shape/"
                         "dataflow co-design) and report the modeled "
                         "throughput, reconfiguration count, and array "
                         "utilization next to the wall-clock metrics")
    ap.add_argument("--codesign-rows", type=int, default=None,
                    choices=[8, 16, 32, 64],
                    help="price a fixed rows x (4096/rows) array instead "
                         "of the reconfigurable SNAKE substrate")
    ap.add_argument("--reconfig-cost", type=float, default=None,
                    metavar="SECONDS",
                    help="modeled-clock charge per substrate "
                         "reconfiguration (shape-profile change); "
                         "default derives the pipeline fill/drain cost "
                         "from the array geometry")
    ap.add_argument("--eos-rate", type=float, default=None,
                    help="per-step early-stop probability (samples "
                         "per-request decode budgets)")
    ap.add_argument("--trace-file", type=str, default=None,
                    help="replay a recorded JSON trace "
                         "(serving.scheduler.load_trace format)")
    ap.add_argument("--trace-out", type=str, default=None,
                    metavar="PERFETTO_JSON",
                    help="write a Perfetto/chrome-trace timeline of the "
                         "run (open at https://ui.perfetto.dev); a "
                         "lossless .jsonl event log is written next to "
                         "it and the phase-attribution report is printed")
    args = ap.parse_args()
    if args.prefix_sharing and not args.paged:
        ap.error("--prefix-sharing requires --paged (the dense engine "
                 "has no page tables to share)")
    if args.router_policy == "prefix_affinity" and not args.prefix_sharing:
        ap.error("--router-policy prefix_affinity requires "
                 "--prefix-sharing (nothing resident to probe otherwise)")
    if args.placement and not args.paged:
        ap.error("--placement requires --paged (the dense cache has no "
                 "page pool to partition)")
    if args.fuse_steps > 1 and not args.paged:
        ap.error("--fuse-steps requires --paged (the fused scan runs on "
                 "the block-table decode step)")
    if args.fuse_steps < 1:
        ap.error("--fuse-steps must be >= 1")
    if args.codesign_rows and not args.codesign:
        ap.error("--codesign-rows requires --codesign")
    if args.reconfig_cost is not None and not args.codesign:
        ap.error("--reconfig-cost requires --codesign (there is no "
                 "modeled clock to charge otherwise)")
    if args.reconfig_cost is not None and args.reconfig_cost < 0:
        ap.error("--reconfig-cost must be >= 0")
    tiers = None
    if args.tiers is not None:
        try:
            p_n, d_n = (int(v) for v in args.tiers.split(":"))
        except ValueError:
            ap.error("--tiers must look like P:D, e.g. 1:3")
        if not args.paged:
            ap.error("--tiers requires --paged (page shipping moves "
                     "block-table pages)")
        if p_n < 1 or d_n < 1:
            ap.error("--tiers needs at least one replica per tier")
        if p_n + d_n != args.replicas:
            ap.error(f"--tiers {p_n}:{d_n} must sum to --replicas "
                     f"({args.replicas})")
        tiers = (p_n, d_n)

    entry = registry.get(args.arch, reduced=not args.full)
    ecfg = EngineConfig(max_batch=args.max_batch,
                        max_seq=args.prompt_len + args.max_new + 2,
                        max_new_tokens=args.max_new,
                        use_pallas_decode=args.pallas,
                        prefill_chunk=args.prefill_chunk,
                        paged=args.paged,
                        page_size=args.page_size,
                        num_pages=args.num_pages,
                        prefix_sharing=args.prefix_sharing,
                        fuse_steps=args.fuse_steps,
                        defrag_threshold=(None if args.defrag_threshold < 0
                                          else args.defrag_threshold),
                        placement=args.placement,
                        placement_regions=args.placement_regions,
                        codesign=args.codesign,
                        codesign_rows=args.codesign_rows,
                        codesign_reconfig_cost_s=args.reconfig_cost)
    reqs = build_trace(args, entry.config.vocab)
    tracer = None
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer()
    if args.replicas > 1:
        router = make_cluster(entry, ecfg, args.replicas,
                              policy=args.router_policy, tiers=tiers)
        if tracer is not None:
            router.set_tracer(tracer)
        metrics = router.run_trace(reqs)
        per = metrics.pop("per_replica")
        print(f"[serve] {args.arch} x{args.replicas} "
              f"({args.router_policy}): {metrics}")
        for rep in per:
            print(f"[serve]   replica {rep['replica']}: {rep}")
    else:
        eng = make_engine(entry, ecfg)
        if tracer is not None:
            eng.set_tracer(tracer)
        metrics = eng.run_trace(reqs)
        print(f"[serve] {args.arch}: {metrics}")
    if tracer is not None:
        from repro.obs import export_perfetto, save_jsonl, trace_report
        export_perfetto(tracer.events, args.trace_out)
        jsonl = args.trace_out + ".jsonl"
        save_jsonl(tracer.events, jsonl)
        rep = trace_report(tracer.events)
        print(f"[serve] trace: {len(tracer.events)} events -> "
              f"{args.trace_out} (+ {jsonl})")
        print(f"[serve] phases: {rep['phases']} "
              f"makespan={rep['makespan_s']:.3f}s")


if __name__ == "__main__":
    main()
