"""Serving driver: continuous-batching decode on the real model.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \
      --rate 4 --n-requests 12 --prompt-len 32     # reduced config default

``--pallas`` routes decode attention through the flash-decode Pallas kernel
(interpret mode on CPU, compiled on TPU); with ``--paged`` it becomes the
block-table read-through paged kernel.  ``--paged`` switches KV residency
to the page-pool layout (``--page-size``, ``--num-pages`` to oversubscribe)
and ``--prefill-chunk`` interleaves Sarathi prefill chunks with the hot
decode batch.  ``--prefix-sharing`` adds refcounted prompt-prefix pages
with copy-on-write; combine it with ``--shared-prefix N`` to drive a
shared-system-prompt trace (every prompt = N common tokens + a unique
tail) and watch the dedup ratio in the report.
"""
from __future__ import annotations

import argparse

from repro.models import registry
from repro.serving.engine import (EngineConfig, make_engine,
                                  make_shared_prefix_trace)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS + registry.EXTRA_ARCH_IDS, default="yi-6b")
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--pallas", action="store_true",
                    help="flash-decode Pallas kernel for decode attention")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="Sarathi-style chunked prefill")
    ap.add_argument("--paged", action="store_true",
                    help="block-table paged KV cache")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool size (oversubscribe below the dense-"
                         "equivalent capacity to exercise preemption)")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="refcounted prompt-prefix page sharing + CoW")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="common system-prompt tokens per request "
                         "(0: fully unique prompts)")
    ap.add_argument("--defrag-threshold", type=float, default=0.5,
                    help="fragmentation fraction that triggers pool "
                         "defrag (negative disables)")
    args = ap.parse_args()
    if args.prefix_sharing and not args.paged:
        ap.error("--prefix-sharing requires --paged (the dense engine "
                 "has no page tables to share)")

    entry = registry.get(args.arch, reduced=not args.full)
    ecfg = EngineConfig(max_batch=args.max_batch,
                        max_seq=args.prompt_len + args.max_new + 2,
                        max_new_tokens=args.max_new,
                        use_pallas_decode=args.pallas,
                        prefill_chunk=args.prefill_chunk,
                        paged=args.paged,
                        page_size=args.page_size,
                        num_pages=args.num_pages,
                        prefix_sharing=args.prefix_sharing,
                        defrag_threshold=(None if args.defrag_threshold < 0
                                          else args.defrag_threshold))
    eng = make_engine(entry, ecfg)
    if args.shared_prefix > 0:
        # total prompt length stays --prompt-len: N shared + unique tail
        prefix = min(args.shared_prefix, args.prompt_len - 1)
        reqs = make_shared_prefix_trace(entry.config.vocab,
                                        rate_req_s=args.rate,
                                        n_requests=args.n_requests,
                                        prefix_len=prefix,
                                        tail_len=args.prompt_len - prefix)
        metrics = eng.run_trace(reqs)
    else:
        metrics = eng.run_workload(rate_req_s=args.rate,
                                   n_requests=args.n_requests,
                                   prompt_len=args.prompt_len)
    print(f"[serve] {args.arch}: {metrics}")


if __name__ == "__main__":
    main()
