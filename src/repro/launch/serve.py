"""Serving driver: continuous-batching decode on the real model.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \
      --rate 4 --n-requests 12 --prompt-len 32

``--pallas`` routes decode attention through the flash-decode Pallas kernel
(interpret mode on CPU, compiled on TPU).
"""
from __future__ import annotations

import argparse

from repro.models import registry
from repro.serving.engine import EngineConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS + registry.EXTRA_ARCH_IDS, default="yi-6b")
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--pallas", action="store_true",
                    help="flash-decode Pallas kernel for decode attention")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="Sarathi-style chunked prefill")
    args = ap.parse_args()

    entry = registry.get(args.arch, reduced=not args.full)
    ecfg = EngineConfig(max_batch=args.max_batch,
                        max_seq=args.prompt_len + args.max_new + 2,
                        max_new_tokens=args.max_new,
                        use_pallas_decode=args.pallas,
                        prefill_chunk=args.prefill_chunk)
    eng = ServingEngine(entry, ecfg)
    metrics = eng.run_workload(rate_req_s=args.rate,
                               n_requests=args.n_requests,
                               prompt_len=args.prompt_len)
    print(f"[serve] {args.arch}: {metrics}")


if __name__ == "__main__":
    main()
