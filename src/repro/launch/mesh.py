"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before any
jax initialization.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """Version-portable mesh constructor.

    ``jax.sharding.AxisType`` (and ``make_mesh``'s ``axis_types`` kwarg)
    only exist in some JAX releases; every axis we use is Auto anyway, which
    is the default, so fall back to the plain constructor when absent.
    """
    if hasattr(jax.sharding, "AxisType"):
        try:
            return jax.make_mesh(
                shape, axes,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
        except TypeError:   # make_mesh without axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods = 512 chips.

    Axes: batch shards over ("pod", "data"); tensor/expert/sequence
    parallelism over "model".  At larger scale the pod axis generalizes to
    (n_pods, 16, 16) — collectives stay hierarchical (see README §Scale).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small host-device meshes)."""
    return _mesh(tuple(shape), tuple(axes))


def data_axes(mesh) -> tuple:
    """Axes over which the batch dimension shards."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    assert "model" in mesh.axis_names
    return "model"
