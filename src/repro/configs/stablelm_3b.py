"""StableLM-2 3B class: dense MHA (kv = q = 32).
[hf:stabilityai/stablelm-2-1_6b; unverified]  d_head = 2560/32 = 80."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, num_q_heads=32, num_kv_heads=32,
    d_head=80, d_ff=6912, vocab=50304,
    gated_ffn=True, act="silu", norm="layernorm",
)
