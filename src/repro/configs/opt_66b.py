"""OPT-66B — the paper's Table 1 dense MHA model, as a runnable JAX config
(RoPE stands in for OPT's learned positions; systems shapes unaffected).
[arXiv:2205.01068]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="opt-66b", family="dense",
    num_layers=64, d_model=9216, num_q_heads=72, num_kv_heads=72,
    d_head=128, d_ff=36864, vocab=50272,
    gated_ffn=False, act="gelu", norm="layernorm",
)
