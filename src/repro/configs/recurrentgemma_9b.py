"""RecurrentGemma-9B: RG-LRU + local attention, 1 attention per 2 recurrent.
[arXiv:2402.19427; unverified]  MQA (kv=1), d_head = 4096/16 = 256,
window 2048 — O(1)-state decode, runs the long_500k cell."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_q_heads=16, num_kv_heads=1,
    d_head=256, d_ff=12288, vocab=256000,
    block_pattern=("rec", "rec", "attn"), window=2048, lru_width=4096,
    conv_width=4, gated_ffn=True, act="gelu",
)
