"""LLaMA3-70B — the paper's Table 1 dense GQA model. [arXiv:2407.21783]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3-70b", family="dense",
    num_layers=80, d_model=8192, num_q_heads=64, num_kv_heads=8,
    d_head=128, d_ff=28672, vocab=128256, rope_theta=500000.0,
)
