"""RWKV6 'Finch' 7B: attention-free SSM with data-dependent decay.
[arXiv:2404.05892; hf]  d_ff is the channel-mix width."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_q_heads=1, num_kv_heads=1,
    d_head=64, d_ff=14336, vocab=65536,
    rwkv_head_size=64, gated_ffn=False, norm="layernorm",
)
