"""Qwen1.5-110B: dense GQA with QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_q_heads=64, num_kv_heads=8,
    d_head=128, d_ff=49152, vocab=152064,
    qkv_bias=True, gated_ffn=True, act="silu", rope_theta=1000000.0,
)
