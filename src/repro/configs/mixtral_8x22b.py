"""Mixtral-8x22B — the paper's Table 1 MoE model (8e top-2).
[arXiv:2401.04088]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_q_heads=48, num_kv_heads=8,
    d_head=128, d_ff=16384, vocab=32768,
    num_experts=8, topk=2, d_ff_expert=16384,
)
