"""Qwen2-VL-7B text backbone: M-RoPE, dynamic resolution (vision frontend
STUB per the assignment: input_specs provides precomputed patch embeddings).
[arXiv:2409.12191; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_q_heads=28, num_kv_heads=4,
    d_head=128, d_ff=18944, vocab=152064,
    qkv_bias=True, mrope=True, mrope_sections=(16, 24, 24),
    gated_ffn=True, act="silu", rope_theta=1000000.0,
)
