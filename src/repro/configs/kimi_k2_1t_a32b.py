"""Kimi K2: trillion-parameter MoE, 384 experts top-8 + 1 shared, GQA.
[arXiv:2501.kimi2; unverified]  d_head = 7168/64 = 112."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_q_heads=64, num_kv_heads=8,
    d_head=112, d_ff=2048, vocab=163840,
    num_experts=384, topk=8, d_ff_expert=2048, num_shared_experts=1,
    gated_ffn=True, act="silu", rope_theta=50000.0,
)
