"""DBRX-132B: fine-grained MoE, 16 experts top-4, GQA.
[hf:databricks/dbrx-base; unverified]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_q_heads=48, num_kv_heads=8,
    d_head=128, d_ff=10752, vocab=100352,
    num_experts=16, topk=4, d_ff_expert=10752,
    gated_ffn=True, act="silu", norm="layernorm", rope_theta=500000.0,
)
