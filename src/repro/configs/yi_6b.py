"""Yi-6B: llama-architecture dense GQA (kv=4). [arXiv:2403.04652; hf]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_q_heads=32, num_kv_heads=4,
    d_head=128, d_ff=11008, vocab=64000,
    gated_ffn=True, act="silu", rope_theta=5000000.0,
)
