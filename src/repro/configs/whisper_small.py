"""Whisper-small: encoder-decoder audio backbone, conv frontend STUB
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    num_layers=12, encoder_layers=12, d_model=768, num_q_heads=12,
    num_kv_heads=12, d_head=64, d_ff=3072, vocab=51865,
    gated_ffn=False, act="gelu", norm="layernorm", encoder_frames=1500,
    max_seq=32768,
)
