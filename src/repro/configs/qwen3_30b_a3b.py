"""Qwen3-30B-A3B — the paper's Table 1 fine-grained MoE (128e top-8).
[arXiv:2505.09388]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_q_heads=32, num_kv_heads=4,
    d_head=128, d_ff=6144, vocab=151936,
    num_experts=128, topk=8, d_ff_expert=768,
)
