"""Granite-3 8B: dense GQA kv=8. [hf:ibm-granite/granite-3.0-2b-base; hf]
vocab 49155 is padded to a TP-divisible multiple by padded_vocab()."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense",
    num_layers=40, d_model=4096, num_q_heads=32, num_kv_heads=8,
    d_head=128, d_ff=12800, vocab=49155,
    gated_ffn=True, act="silu", tie_embeddings=True,
)
