"""Deterministic synthetic data pipeline.

Every (step, data_shard) pair maps to a unique, reproducible batch of tokens
via a counter-based PRNG (threefry), so any host in a multi-host job can
produce exactly its shard without coordination — restarts and elastic
re-sharding replay identically (the property the checkpoint/resume test
pins).  A Zipf-ish marginal over the vocab makes losses behave like text
rather than uniform noise.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.2


def _zipf_cdf(vocab: int, alpha: float) -> np.ndarray:
    w = 1.0 / np.power(np.arange(1, vocab + 1, dtype=np.float64), alpha)
    return np.cumsum(w / w.sum())


class TokenPipeline:
    """Shard-deterministic synthetic token stream."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.global_batch % num_shards == 0
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        self._cdf = _zipf_cdf(cfg.vocab, cfg.zipf_alpha)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for `step` on this shard — pure function of (cfg, step, shard)."""
        ss = np.random.SeedSequence(
            [self.cfg.seed, step, self.shard, self.num_shards])
        rng = np.random.default_rng(ss)
        u = rng.random((self.local_batch, self.cfg.seq_len + 1))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        toks = np.clip(toks, 0, self.cfg.vocab - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                "mask": np.ones((self.local_batch, self.cfg.seq_len),
                                np.float32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
