"""Fault-tolerant checkpointing.

Design (multi-host-ready, exercised single-process here):
* step-tagged directories written ATOMICALLY (write to ``.tmp-<step>``, fsync
  the manifest, then ``os.rename`` — a crash mid-save never corrupts the
  latest checkpoint);
* a JSON manifest stores treedef + shapes/dtypes, arrays go to one ``.npy``
  per leaf (at multi-host scale each host writes only the shards it owns —
  the manifest is mesh-independent, so restore can RE-SHARD onto a different
  device count: elastic restart);
* ``restore_latest`` + retention GC + an async (background-thread) mode so
  the training loop never blocks on I/O.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        if self.async_save:
            self.wait()  # one in flight at a time
            host_tree = jax.tree.map(np.asarray, tree)  # snapshot now
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_tree, extra))
            self._thread.start()
        else:
            self._save_sync(step, tree, extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_sync(self, step: int, tree: Any, extra: Optional[dict]):
        tmp = os.path.join(self.dir, f".tmp-{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten_with_paths(tree)
        manifest = {"step": step, "extra": extra or {}, "leaves": {}}
        for key, leaf in flat.items():
            arr = np.asarray(leaf)
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {"file": fname,
                                       "shape": list(arr.shape),
                                       "dtype": str(arr.dtype)}
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_"):
                out.append(int(d[len("step_"):]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                shardings: Any = None) -> Tuple[Any, dict]:
        """Restore into the structure of ``like``; optionally device_put with
        ``shardings`` (same treedef) — this is where elastic re-sharding
        happens: the on-disk layout is mesh-independent."""
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like = _flatten_with_paths(like)
        flat_sh = _flatten_with_paths(shardings) if shardings is not None \
            else {k: None for k in flat_like}
        restored = {}
        for key, leaf in flat_like.items():
            info = manifest["leaves"][key]
            arr = np.load(os.path.join(d, info["file"]))
            assert list(arr.shape) == list(leaf.shape), \
                f"{key}: {arr.shape} vs {leaf.shape}"
            if flat_sh.get(key) is not None:
                restored[key] = jax.device_put(arr, flat_sh[key])
            else:
                restored[key] = jax.numpy.asarray(arr, dtype=leaf.dtype)
        # rebuild pytree in like's structure
        paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, _ in paths:
            key = "/".join(_path_str(p) for p in path)
            leaves.append(restored[key])
        return jax.tree_util.tree_unflatten(treedef, leaves), \
            manifest["extra"]

    def restore_latest(self, like: Any, shardings: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None, None
        tree, extra = self.restore(step, like, shardings)
        return step, tree, extra
